#!/usr/bin/env python3
"""Run-over-run bench delta table for the CI job summary.

Usage: bench_delta.py [--fail-over PCT] BASELINE_DIR CURRENT_JSON [...]

Each CURRENT_JSON is a BENCH_*.json report produced by a bench binary
({"bench": ..., "scenarios": [{"name", "rate_msgs_per_sec", ...}],
"gate": {...}}). The baseline directory holds the previous successful
run's reports under the same file names (downloaded as artifacts); when a
baseline file is missing the table still prints, with the delta column
empty.

With --fail-over PCT the script exits nonzero if any scenario's rate
dropped more than PCT percent against its baseline — run-over-run
erosion fails the job instead of only printing. Missing baselines never
trip the threshold (there is nothing to regress against).

Scenario and gate-ratio sets are allowed to drift between runs: a
scenario present only in the current report is marked "new", one present
only in the baseline is noted as removed, and gate keys that appeared or
disappeared are listed — none of these trip --fail-over. A PR that adds
a bench lane (or retires one) must not fail the delta job for that
reason alone.

Output is GitHub-flavored markdown on stdout.
"""

import json
import os
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def rates(report):
    if not report:
        return {}
    return {
        s.get("name", "?"): float(s.get("rate_msgs_per_sec", 0.0))
        for s in report.get("scenarios", [])
    }


def fmt_rate(r):
    return f"{r / 1e6:.3f}"


def main():
    args = sys.argv[1:]
    fail_over = None
    if args and args[0] == "--fail-over":
        if len(args) < 2:
            print("--fail-over requires a percentage", file=sys.stderr)
            return 2
        fail_over = float(args[1])
        args = args[2:]
    if len(args) < 2:
        print(
            "usage: bench_delta.py [--fail-over PCT] BASELINE_DIR CURRENT_JSON...",
            file=sys.stderr,
        )
        return 1
    baseline_dir = args[0]
    print("## Bench rates, run over run")
    print()
    any_baseline = False
    regressions = []
    for cur_path in args[1:]:
        cur = load(cur_path)
        if cur is None:
            print(f"_{cur_path}: missing or unreadable; skipped_")
            print()
            continue
        name = cur.get("bench", os.path.basename(cur_path))
        base = load(os.path.join(baseline_dir, os.path.basename(cur_path)))
        base_rates = rates(base)
        any_baseline = any_baseline or bool(base_rates)
        print(f"### {name}")
        print()
        print("| scenario | baseline Mmsg/s | current Mmsg/s | delta |")
        print("|---|---|---|---|")
        cur_rates = rates(cur)
        for scen, rate in cur_rates.items():
            prev = base_rates.get(scen)
            if prev and prev > 0.0:
                pct = (rate - prev) / prev * 100.0
                delta = f"{pct:+.1f}%"
                prev_s = fmt_rate(prev)
                if fail_over is not None and pct < -fail_over:
                    regressions.append(f"{name}/{scen} {pct:+.1f}%")
            elif base_rates and scen not in base_rates:
                # Scenario added since the baseline: nothing to regress
                # against, and not a reason to fail.
                delta, prev_s = "new", "–"
            else:
                delta, prev_s = "–", "–"
            print(f"| {scen} | {prev_s} | {fmt_rate(rate)} | {delta} |")
        removed = [s for s in base_rates if s not in cur_rates]
        if removed:
            print()
            print(f"_removed since baseline: {', '.join(sorted(removed))}_")
        gate = cur.get("gate", {})
        base_gate = (base or {}).get("gate", {})
        if gate:
            print()
            ratios = ", ".join(
                f"{k} = {v}" for k, v in gate.items() if k != "pass"
            )
            verdict = "PASS" if gate.get("pass") else "FAIL"
            print(f"gate: {verdict} ({ratios})")
        gate_new = sorted(k for k in gate if k != "pass" and k not in base_gate)
        gate_gone = sorted(k for k in base_gate if k != "pass" and k not in gate)
        if base_gate and (gate_new or gate_gone):
            notes = []
            if gate_new:
                notes.append(f"new gate keys: {', '.join(gate_new)}")
            if gate_gone:
                notes.append(f"gate keys removed: {', '.join(gate_gone)}")
            print()
            print(f"_{'; '.join(notes)}_")
        print()
    if not any_baseline:
        print("_No baseline reports found (first run on this branch?); "
              "deltas will appear from the next run._")
    if regressions:
        print(
            f"**FAIL: rate regressed more than {fail_over:g}% against the "
            f"previous run: {', '.join(regressions)}**"
        )
        for r in regressions:
            print(f"bench regression over threshold: {r}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
