//! Backend abstraction: every synchronization primitive and time source in
//! the library comes in two flavors,
//!
//!   * [`Backend::Sim`] — virtual-time DES primitives ([`crate::sim`]),
//!     used for all paper-figure experiments (deterministic, models a
//!     16-core node on a 1-core host), and
//!   * [`Backend::Native`] — real `std::sync` primitives and wallclock,
//!     used by the end-to-end examples (PJRT compute, training driver) and
//!     the concurrency stress tests.
//!
//! The MPI library, fabric, and apps are written once against `PMutex`,
//! `PAtomicU64`, `PBarrier`, `pyield`, `pnow`, `padvance` and run unchanged
//! on both backends.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::mpi::instrument::{count_lock, tag_of, LockClass};
use crate::sim;

/// Which execution substrate a component runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Deterministic virtual-time simulation of the paper's testbed.
    Sim,
    /// Real OS threads and wallclock on the host.
    Native,
}

// ---------------------------------------------------------------------------
// time
// ---------------------------------------------------------------------------

fn native_epoch() -> Instant {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Current time in nanoseconds (virtual or wallclock-since-start).
pub fn pnow(backend: Backend) -> u64 {
    match backend {
        Backend::Sim => sim::now(),
        Backend::Native => native_epoch().elapsed().as_nanos() as u64,
    }
}

/// Charge `ns` of *modeled* cost. In the simulation this advances virtual
/// time; natively it is free (the real work being modeled actually runs).
pub fn padvance(backend: Backend, ns: u64) {
    if backend == Backend::Sim {
        sim::advance(ns);
    }
}

/// Spend `ns` of *compute* (busy-target knobs, modeled application work).
/// Advances virtual time in sim; busy-spins natively.
pub fn pcompute(backend: Backend, ns: u64) {
    match backend {
        Backend::Sim => sim::advance(ns),
        Backend::Native => {
            let start = Instant::now();
            while (start.elapsed().as_nanos() as u64) < ns {
                std::hint::spin_loop();
            }
        }
    }
}

/// Cooperative yield for polling loops.
pub fn pyield(backend: Backend) {
    match backend {
        Backend::Sim => sim::yield_now(),
        Backend::Native => std::thread::yield_now(),
    }
}

// ---------------------------------------------------------------------------
// mutex
// ---------------------------------------------------------------------------

enum MutexImpl<T: Send> {
    Native(Mutex<T>),
    Sim(sim::SimMutex<T>),
}

/// Dual-backend mutex.
pub struct PMutex<T: Send> {
    inner: MutexImpl<T>,
}

impl<T: Send> PMutex<T> {
    pub fn new(backend: Backend, value: T) -> Self {
        let inner = match backend {
            Backend::Native => MutexImpl::Native(Mutex::new(value)),
            Backend::Sim => MutexImpl::Sim(sim::SimMutex::new(value)),
        };
        PMutex { inner }
    }

    /// Sim-only: place the lock word on an explicit modeled cache line
    /// (false-sharing experiments, Fig. 8). No-op for native mutexes.
    pub fn on_line(self, line: std::sync::Arc<sim::CacheLine>) -> Self {
        match self.inner {
            MutexImpl::Sim(m) => PMutex { inner: MutexImpl::Sim(m.on_line(line)) },
            native => PMutex { inner: native },
        }
    }

    /// Unclassed acquisition (scratch users, tests). Inside `mpi/` every
    /// call site must use [`PMutex::lock_class`] instead — enforced by
    /// `scripts/lint_lock_discipline.py`.
    pub fn lock(&self) -> PMutexGuard<'_, T> {
        match &self.inner {
            MutexImpl::Native(m) => {
                PMutexGuard::Native(m.lock().unwrap_or_else(|e| e.into_inner()))
            }
            MutexImpl::Sim(m) => PMutexGuard::Sim(m.lock()),
        }
    }

    /// Classed acquisition: counts the Table-1 column for `class` and (in
    /// sim, under `simsan`) checks the acquisition against the declared
    /// lock hierarchy and the dynamic lock-order graph.
    #[track_caller]
    pub fn lock_class(&self, class: LockClass) -> PMutexGuard<'_, T> {
        self.lock_ordinal(class, 0)
    }

    /// Classed acquisition of one instance of a `multi` class (the shard
    /// leaves): several may be held at once when acquired in ascending
    /// `ordinal` order.
    #[track_caller]
    pub fn lock_ordinal(&self, class: LockClass, ordinal: u32) -> PMutexGuard<'_, T> {
        count_lock(class);
        match &self.inner {
            MutexImpl::Native(m) => {
                PMutexGuard::Native(m.lock().unwrap_or_else(|e| e.into_inner()))
            }
            MutexImpl::Sim(m) => PMutexGuard::Sim(m.lock_tagged(tag_of(class), ordinal)),
        }
    }

    /// Classed acquisition that deliberately skips the Table-1 count: the
    /// Global-CS fast paths take the inner lock only for host data safety
    /// (the big lock already serializes, so the modeled program performs no
    /// lock op). Ordering/hierarchy checks still apply under `simsan`.
    #[track_caller]
    pub fn lock_uncounted(&self, class: LockClass) -> PMutexGuard<'_, T> {
        match &self.inner {
            MutexImpl::Native(m) => {
                PMutexGuard::Native(m.lock().unwrap_or_else(|e| e.into_inner()))
            }
            MutexImpl::Sim(m) => PMutexGuard::Sim(m.lock_tagged(tag_of(class), 0)),
        }
    }

    pub fn try_lock(&self) -> Option<PMutexGuard<'_, T>> {
        match &self.inner {
            MutexImpl::Native(m) => match m.try_lock() {
                Ok(g) => Some(PMutexGuard::Native(g)),
                Err(std::sync::TryLockError::Poisoned(e)) => {
                    Some(PMutexGuard::Native(e.into_inner()))
                }
                Err(std::sync::TryLockError::WouldBlock) => None,
            },
            MutexImpl::Sim(m) => m.try_lock().map(PMutexGuard::Sim),
        }
    }

    /// Classed non-blocking acquisition. Counts only on success (matching
    /// the historical `try_lock`-then-count call sites); exempt from
    /// ordering checks (a try can't deadlock) but the hold is tracked.
    #[track_caller]
    pub fn try_lock_class(&self, class: LockClass) -> Option<PMutexGuard<'_, T>> {
        let g = match &self.inner {
            MutexImpl::Native(m) => match m.try_lock() {
                Ok(g) => Some(PMutexGuard::Native(g)),
                Err(std::sync::TryLockError::Poisoned(e)) => {
                    Some(PMutexGuard::Native(e.into_inner()))
                }
                Err(std::sync::TryLockError::WouldBlock) => None,
            },
            MutexImpl::Sim(m) => m.try_lock_tagged(tag_of(class)).map(PMutexGuard::Sim),
        }?;
        count_lock(class);
        Some(g)
    }
}

pub enum PMutexGuard<'a, T: Send> {
    Native(MutexGuard<'a, T>),
    Sim(sim::SimMutexGuard<'a, T>),
}

impl<T: Send> Deref for PMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match self {
            PMutexGuard::Native(g) => g,
            PMutexGuard::Sim(g) => g,
        }
    }
}

impl<T: Send> DerefMut for PMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match self {
            PMutexGuard::Native(g) => g,
            PMutexGuard::Sim(g) => g,
        }
    }
}

// ---------------------------------------------------------------------------
// atomic u64
// ---------------------------------------------------------------------------

enum AtomicImpl {
    Native(AtomicU64),
    Sim(sim::SimAtomicU64),
}

/// Dual-backend atomic counter (reference/completion counting).
pub struct PAtomicU64 {
    inner: AtomicImpl,
}

impl PAtomicU64 {
    pub fn new(backend: Backend, v: u64) -> Self {
        let inner = match backend {
            Backend::Native => AtomicImpl::Native(AtomicU64::new(v)),
            Backend::Sim => AtomicImpl::Sim(sim::SimAtomicU64::new(v)),
        };
        PAtomicU64 { inner }
    }

    pub fn load(&self) -> u64 {
        match &self.inner {
            AtomicImpl::Native(a) => a.load(Ordering::Acquire),
            AtomicImpl::Sim(a) => a.load(),
        }
    }

    pub fn store(&self, v: u64) {
        match &self.inner {
            AtomicImpl::Native(a) => a.store(v, Ordering::Release),
            AtomicImpl::Sim(a) => a.store(v),
        }
    }

    pub fn fetch_add(&self, d: u64) -> u64 {
        match &self.inner {
            AtomicImpl::Native(a) => a.fetch_add(d, Ordering::AcqRel),
            AtomicImpl::Sim(a) => a.fetch_add(d),
        }
    }

    pub fn fetch_sub(&self, d: u64) -> u64 {
        match &self.inner {
            AtomicImpl::Native(a) => a.fetch_sub(d, Ordering::AcqRel),
            AtomicImpl::Sim(a) => a.fetch_sub(d),
        }
    }
}

// ---------------------------------------------------------------------------
// barrier (thread barrier within a process, "#pragma omp barrier")
// ---------------------------------------------------------------------------

enum BarrierImpl {
    Native(NativeBarrier),
    Sim(sim::SimBarrier),
}

/// Reusable dual-backend barrier.
pub struct PBarrier {
    inner: BarrierImpl,
}

struct NativeBarrier {
    state: Mutex<(usize, u64)>, // (arrived, generation)
    cv: Condvar,
    parties: usize,
}

impl PBarrier {
    pub fn new(backend: Backend, parties: usize) -> Self {
        let inner = match backend {
            Backend::Native => BarrierImpl::Native(NativeBarrier {
                state: Mutex::new((0, 0)),
                cv: Condvar::new(),
                parties,
            }),
            Backend::Sim => BarrierImpl::Sim(sim::SimBarrier::new(parties)),
        };
        PBarrier { inner }
    }

    pub fn wait(&self) {
        match &self.inner {
            BarrierImpl::Sim(b) => b.wait(),
            BarrierImpl::Native(b) => {
                let mut g = b.state.lock().unwrap_or_else(|e| e.into_inner());
                let gen = g.1;
                g.0 += 1;
                if g.0 == b.parties {
                    g.0 = 0;
                    g.1 += 1;
                    b.cv.notify_all();
                } else {
                    while g.1 == gen {
                        g = b.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn native_mutex_works() {
        let m = Arc::new(PMutex::new(Backend::Native, 0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    *m.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*m.lock(), 4000);
    }

    #[test]
    fn native_barrier_synchronizes() {
        let b = Arc::new(PBarrier::new(Backend::Native, 3));
        let counter = Arc::new(PAtomicU64::new(Backend::Native, 0));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let b = b.clone();
            let c = counter.clone();
            handles.push(std::thread::spawn(move || {
                c.fetch_add(1);
                b.wait();
                assert_eq!(c.load(), 3);
                b.wait();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sim_mutex_via_platform() {
        let m = Arc::new(PMutex::new(Backend::Sim, 0u64));
        let mut s = sim::Sim::new(sim::CostModel::default());
        for _ in 0..2 {
            let m = m.clone();
            s.spawn_setup("t", move || {
                for _ in 0..10 {
                    *m.lock() += 1;
                }
            });
        }
        let r = s.run();
        assert_eq!(r.outcome, sim::SimOutcome::Completed);
    }

    #[test]
    fn pnow_native_monotone() {
        let a = pnow(Backend::Native);
        std::thread::sleep(std::time::Duration::from_millis(1));
        let b = pnow(Backend::Native);
        assert!(b > a);
    }
}
