//! Small self-contained utilities (offline environment: no external crates).

mod rng;
mod stats;

pub use rng::{mix64, SplitMix64};
pub use stats::{mean, percentile, stddev, Summary};
