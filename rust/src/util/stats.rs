//! Summary statistics for the bench harness.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile (`p` in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// A compact distribution summary.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min,
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
            max,
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} sd={:.3} min={:.3} p50={:.3} p99={:.3} max={:.3}",
            self.n, self.mean, self.stddev, self.min, self.p50, self.p99, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_consistent() {
        let xs = [1.0, 2.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.p50 - 2.0).abs() < 1e-12);
    }
}
