//! Deterministic PRNG (SplitMix64) — used for workload generation and the
//! hand-rolled property tests. No external `rand` crate is available in the
//! offline build environment.

/// SplitMix-style bit finalizer used wherever the library needs a cheap
/// stateless scramble (VCI selection by envelope, per-message stripe
/// hashing, matching-shard routing). One canonical copy so the mix
/// constants can never drift between call sites.
pub fn mix64(z: u64) -> u64 {
    let z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 27)
}

/// SplitMix64: tiny, fast, and passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    pub fn gen_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_respected() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = SplitMix64::new(1);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.gen_usize(8)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
