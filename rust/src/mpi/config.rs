//! Library configuration: every knob the paper ablates is here.
//!
//! # Process-wide knobs vs per-communicator defaults
//!
//! Two kinds of knob live on [`MpiConfig`]:
//!
//! * **Process-wide** (`num_vcis`, `cs_mode`, the per-VCI request/
//!   lightweight/progress options, `vci_policy`, `cache_aligned_vcis`,
//!   `global_progress_interval`, `unsafe_no_thread_safety`): these shape
//!   the library itself and cannot differ per communicator.
//! * **Per-communicator defaults** (`vci_striping`, `match_shards`,
//!   `wildcard_epoch_linger`, `rx_doorbell`, and the wildcard assertions
//!   in [`Hints`]): since the per-communicator policy layer
//!   ([`crate::mpi::policy`]), these only seed the default
//!   [`crate::mpi::CommPolicy`] every communicator (including
//!   MPI_COMM_WORLD) starts from. Individual communicators override them
//!   with MPI-4-style info keys at creation
//!   (`MpiProc::comm_dup_with_info` / `comm_split_with_info`):
//!   `vcmpi_striping=off|rr|hash`, `vcmpi_match_shards=N`,
//!   `vcmpi_wildcard_linger=N`, `vcmpi_rx_doorbell=true|false`,
//!   `mpi_assert_no_any_source`, `mpi_assert_no_any_tag`. A hot striped
//!   halo-exchange communicator and a latency-sensitive ordered
//!   communicator therefore coexist in one process — the presets below
//!   keep their exact pre-policy behavior through the default path.
//! * **Per-communicator stream key** (no `MpiConfig` counterpart — a
//!   thread binding is inherently per-comm): `vcmpi_stream=local` declares
//!   that exactly one thread drives the communicator, binding that thread
//!   to a dedicated VCI in single-writer mode so its isend/irecv/wait
//!   bypass the VCI lock and shared request cache entirely (MPIX-Stream's
//!   "serial execution stream" contract; see [`crate::mpi::vci`] for the
//!   decision table). Mutually exclusive with `vcmpi_striping`, requires
//!   `vcmpi_cs=fg`; cross-thread use is erroneous and trips a
//!   deterministic SimSan tripwire.
//! * **Per-communicator collectives keys** (no `MpiConfig` counterpart —
//!   the mapping is inherently per-comm): `vcmpi_collectives=
//!   inherit|dedicated|striped` selects how a communicator's collectives
//!   map onto the VCI pool (`dedicated` reserves a pinned lane, `striped`
//!   spreads segments by envelope hash — see `mpi::collectives` for the
//!   decision table), and `vcmpi_coll_segments=N` sets the pipeline depth
//!   of the segmented allreduce/bcast engine.
//! * **Per-window defaults** (`accumulate_ordering_none` in [`Hints`],
//!   plus `rx_doorbell` doing double duty): these seed the default
//!   [`crate::mpi::WinPolicy`] every RMA window starts from. Individual
//!   windows override them with info keys at
//!   `MpiProc::win_create_with_info`: `accumulate_ordering=none`,
//!   `vcmpi_striping=off|rr|hash`, `vcmpi_rx_doorbell`,
//!   `mpi_assert_no_locks` — so one window can stripe a single origin
//!   thread's accumulates across the pool while another stays ordered on
//!   a pinned lane.
//!
//! The consolidated info-key reference (legal values, defaults, and the
//! bench lane proving each knob) is the table in `docs/ARCHITECTURE.md`
//! (§ "Info-key reference"); the per-key parsing rules live in
//! [`crate::mpi::policy`].

/// Critical-section granularity (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CsMode {
    /// One process-wide lock around every MPI call ("state of the art").
    /// Progress loops release and reacquire it per iteration so other
    /// threads can make progress — which is exactly what serializes them.
    Global,
    /// Fine-grained: per-VCI locks + a request-class lock + per-hook locks,
    /// with atomics for reference/completion counting.
    Fg,
}

/// How communicators/windows are assigned VCIs from the pool (§5.2's
/// "mismatch in expected mapping" and the ablations in DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VciPolicy {
    /// First-come-first-served from the free pool; fall back to VCI 0 when
    /// exhausted (the paper's design).
    FirstComePool,
    /// Round-robin over the pool ignoring free/active state (CRI-style;
    /// Patinyasakdikul et al.).
    RoundRobin,
    /// Hash of the communicator/window id — stateless but collision-prone.
    Hashed,
}

/// Per-message VCI striping of a single communicator's two-sided traffic
/// (the step beyond §7's envelope hints: no wildcard assertions needed).
///
/// With striping on, `isend` picks a (possibly different) VCI for every
/// message and targets the mirror hardware context on the receiver; MPI's
/// nonovertaking rule is restored by the receiver-side reorder stage in
/// [`super::matching::MatchingState`], which admits each `(comm, source)`
/// stream to matching strictly in sender-sequence order. All processes of
/// a job must agree on this setting (it changes the wire contract), just
/// like `num_vcis`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VciStriping {
    /// No striping: a communicator funnels through its one assigned VCI
    /// (the paper's baseline behavior).
    Off,
    /// Spread messages round-robin over the pool's non-fallback VCIs
    /// (VCI 0 is the shared lane pool-exhausted communicators funnel
    /// through, so it is excluded — exactly like the §7 hinted spread).
    /// A process-wide cursor, so concurrent senders naturally fan out.
    RoundRobin,
    /// Hash of the message identity (comm, destination, stream sequence):
    /// stateless and deterministic per message. Same fallback exclusion.
    HashedByRequest,
}

/// Full configuration of one vcmpi process.
#[derive(Clone, Debug)]
pub struct MpiConfig {
    /// VCIs to create at init (1 = "original MPICH"). Limited by the
    /// node's hardware context budget at runtime.
    pub num_vcis: usize,
    pub cs_mode: CsMode,
    /// Per-VCI request caches (paper §4.3 "per-VCI request management").
    pub per_vci_req_cache: bool,
    /// Replicate the pre-completed lightweight request per VCI (vs one
    /// global lightweight request updated with atomics).
    pub per_vci_lightweight: bool,
    /// Progress polls only the VCI recorded in the request (paper §4.3
    /// "per-VCI progress") instead of all active VCIs.
    pub per_vci_progress: bool,
    /// Hybrid progress: after this many unsuccessful per-VCI progress
    /// rounds, run one *global* round over all active VCIs (correctness for
    /// Fig. 9's shared-progress patterns). `0` disables global fallback
    /// entirely — pure per-VCI progress, which is fast but INCORRECT; it
    /// exists to demonstrate the deadlock.
    pub global_progress_interval: u32,
    /// Cache-align the VCI array (Fig. 8). When false, adjacent VCIs share
    /// modeled cache lines and false sharing is charged.
    pub cache_aligned_vcis: bool,
    /// Fig. 12's "what if we dropped thread safety": skip lock acquisition
    /// and atomic charging. Only honored on the Sim backend (it would be
    /// UB natively); still semantically safe there because the DES
    /// serializes execution.
    pub unsafe_no_thread_safety: bool,
    pub vci_policy: VciPolicy,
    /// Per-message VCI striping with receiver-side seq reordering: lets a
    /// single hot communicator use the whole pool. See [`VciStriping`].
    /// **Default policy only** — per-comm `vcmpi_striping` info keys
    /// override it (see [`crate::mpi::policy`]); likewise for
    /// `match_shards`, `wildcard_epoch_linger`, `rx_doorbell`, `hints`.
    pub vci_striping: VciStriping,
    /// Per-communicator matching shards for striped traffic (rounded up to
    /// a power of two; `1` = one serialized engine per communicator, the
    /// PR-1 "home engine" behavior). Each `(comm, source rank)` stream is
    /// owned by exactly one shard, so striped arrivals match on the VCI
    /// they land on instead of funneling through the communicator's home
    /// VCI. `MPI_ANY_SOURCE` flips the communicator into a serialized
    /// wildcard epoch (see `mpi::shard`). All processes of a job must
    /// agree on this setting, like `num_vcis`.
    pub match_shards: usize,
    /// Wildcard-epoch hysteresis: stay in the serialized epoch for this
    /// many additional operations (striped arrivals or concrete posts)
    /// after the last pending wildcard receive completes (amortizes epoch
    /// flip-flapping under wildcard storms). `0` = flip back to sharded
    /// matching immediately. With a nonzero linger, a communicator that
    /// goes idle right after its last wildcard stays serialized — at zero
    /// cost — until `linger` further operations arrive.
    pub wildcard_epoch_linger: u32,
    /// Doorbell-gated striped progress: the sweep over the pool consults a
    /// per-pool "rx nonempty" bitmask maintained by the fabric and skips
    /// entirely when no VCI has pending arrivals, instead of paying an
    /// empty poll per VCI (round-robin, the PR-1 behavior).
    pub rx_doorbell: bool,
    /// Eagerly claimed hints (MPI-4.0 info-style, §7): see [`Hints`].
    pub hints: Hints,
    /// Deterministic fabric fault plan (`vcmpi_fault_plan` info/config
    /// key), parsed by `crate::fabric::FaultPlan::parse` and installed on
    /// the network before any process opens a context. `None` (the
    /// default everywhere) keeps the fabric exact: no reliability
    /// headers, no retransmit state, no per-frame fault rolls — the
    /// fault-free path pays nothing. Spec grammar:
    /// `seed=N,drop=PM,dup=PM,corrupt=PM,delay=PM[,delay_ns=N]
    /// [,timeout_ns=N][,kill=proc:ctx@ns]...` (per-mille rates).
    pub fault_plan: Option<String>,
    /// Transparent VCI lane failover (`vcmpi_lane_failover`): when a
    /// hardware context hard-fails (a `kill=` entry in the fault plan),
    /// the owning process quarantines the lane, migrates its matching
    /// and completion state to a survivor lane, and redirects both local
    /// ops and inbound wire traffic there. Off: a killed lane's waiters
    /// run into the spin-deadline diagnostic instead (the ablation arm).
    /// Irrelevant without a fault plan.
    pub lane_failover: bool,
}

/// MPI-4.0-style info hints (paper §7) plus MPI-3.1's accumulate_ordering.
#[derive(Clone, Debug, Default)]
pub struct Hints {
    /// `accumulate_ordering=none`: Accumulates need not apply in program
    /// order, so they may fan out across VCIs (paper §6.3's closing point).
    /// **Default [`crate::mpi::WinPolicy`] only** — per-window
    /// `accumulate_ordering` info keys at `win_create_with_info` override.
    pub accumulate_ordering_none: bool,
    /// `mpi_assert_no_any_source`: receives never use MPI_ANY_SOURCE, so
    /// traffic within one communicator may be spread over VCIs by rank.
    pub no_any_source: bool,
    /// `mpi_assert_no_any_tag`: receives never use MPI_ANY_TAG; combined
    /// with `no_any_source` this allows tag-level VCI spreading.
    pub no_any_tag: bool,
}

impl MpiConfig {
    /// "Original MPICH": single VCI, global critical section — the paper's
    /// state-of-the-art baseline.
    pub fn original() -> Self {
        MpiConfig {
            num_vcis: 1,
            cs_mode: CsMode::Global,
            per_vci_req_cache: false,
            per_vci_lightweight: false,
            per_vci_progress: false,
            global_progress_interval: 1,
            cache_aligned_vcis: false,
            unsafe_no_thread_safety: false,
            vci_policy: VciPolicy::FirstComePool,
            vci_striping: VciStriping::Off,
            match_shards: 1,
            wildcard_epoch_linger: 0,
            rx_doorbell: false,
            hints: Hints::default(),
            fault_plan: None,
            lane_failover: true,
        }
    }

    /// Fine-grained critical sections on a single VCI (paper §4.1's "FG").
    pub fn fg_single_vci() -> Self {
        MpiConfig { cs_mode: CsMode::Fg, ..Self::original() }
    }

    /// The fully optimized multi-VCI library (paper §4.3, "All opts").
    pub fn optimized(num_vcis: usize) -> Self {
        MpiConfig {
            num_vcis,
            cs_mode: CsMode::Fg,
            per_vci_req_cache: true,
            per_vci_lightweight: true,
            per_vci_progress: true,
            global_progress_interval: 64,
            cache_aligned_vcis: true,
            unsafe_no_thread_safety: false,
            vci_policy: VciPolicy::FirstComePool,
            vci_striping: VciStriping::Off,
            match_shards: 1,
            wildcard_epoch_linger: 0,
            rx_doorbell: false,
            hints: Hints::default(),
            fault_plan: None,
            lane_failover: true,
        }
    }

    /// The optimized library with per-message VCI striping on: one hot
    /// communicator's sends fan out across the whole pool and the receiver
    /// restores nonovertaking order per stream (round-robin selection).
    /// A single matching shard and no doorbell polling: the PR-1 "home
    /// engine" arm, kept as the sharding ablation baseline.
    pub fn striped(num_vcis: usize) -> Self {
        MpiConfig { vci_striping: VciStriping::RoundRobin, ..Self::optimized(num_vcis) }
    }

    /// Striping with per-source sharded matching and doorbell-gated
    /// progress: striped arrivals match on the VCI they land on (each
    /// `(comm, src)` stream owned by one of 8 shards; `MPI_ANY_SOURCE`
    /// serializes via the wildcard-epoch protocol), and waiters skip the
    /// pool sweep when no rx queue has pending arrivals.
    pub fn striped_sharded(num_vcis: usize) -> Self {
        MpiConfig { match_shards: 8, rx_doorbell: true, ..Self::striped(num_vcis) }
    }

    /// MPI-everywhere personality: a single-threaded process needs no
    /// thread safety at all and owns one VCI outright.
    pub fn everywhere() -> Self {
        MpiConfig {
            num_vcis: 1,
            cs_mode: CsMode::Fg,
            per_vci_req_cache: true,
            per_vci_lightweight: true,
            per_vci_progress: true,
            global_progress_interval: 64,
            cache_aligned_vcis: true,
            unsafe_no_thread_safety: true, // no threads -> no locks, like a real rank-per-core build
            vci_policy: VciPolicy::FirstComePool,
            vci_striping: VciStriping::Off,
            match_shards: 1,
            wildcard_epoch_linger: 0,
            rx_doorbell: false,
            hints: Hints::default(),
            fault_plan: None,
            lane_failover: true,
        }
    }
}

impl Default for MpiConfig {
    fn default() -> Self {
        Self::optimized(16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_where_expected() {
        let orig = MpiConfig::original();
        assert_eq!(orig.num_vcis, 1);
        assert_eq!(orig.cs_mode, CsMode::Global);
        let opt = MpiConfig::optimized(16);
        assert_eq!(opt.num_vcis, 16);
        assert_eq!(opt.cs_mode, CsMode::Fg);
        assert!(opt.per_vci_req_cache && opt.per_vci_progress && opt.cache_aligned_vcis);
        assert!(MpiConfig::everywhere().unsafe_no_thread_safety);
    }

    #[test]
    fn striping_is_off_everywhere_except_the_striped_preset() {
        assert_eq!(MpiConfig::original().vci_striping, VciStriping::Off);
        assert_eq!(MpiConfig::optimized(8).vci_striping, VciStriping::Off);
        assert_eq!(MpiConfig::everywhere().vci_striping, VciStriping::Off);
        let s = MpiConfig::striped(8);
        assert_eq!(s.vci_striping, VciStriping::RoundRobin);
        assert_eq!(s.num_vcis, 8);
        assert_eq!(s.cs_mode, CsMode::Fg, "striping rides on the optimized config");
    }

    #[test]
    fn fault_injection_is_off_in_every_preset() {
        for cfg in [
            MpiConfig::original(),
            MpiConfig::fg_single_vci(),
            MpiConfig::optimized(8),
            MpiConfig::striped_sharded(8),
            MpiConfig::everywhere(),
        ] {
            assert!(cfg.fault_plan.is_none(), "presets must keep the fabric exact");
            assert!(cfg.lane_failover, "failover defaults on (inert without a plan)");
        }
    }

    #[test]
    fn sharded_preset_extends_striped() {
        let s = MpiConfig::striped(8);
        assert_eq!(s.match_shards, 1, "plain striped keeps the PR-1 home engine");
        assert!(!s.rx_doorbell);
        let sh = MpiConfig::striped_sharded(8);
        assert_eq!(sh.vci_striping, VciStriping::RoundRobin);
        assert_eq!(sh.match_shards, 8);
        assert!(sh.rx_doorbell);
        assert_eq!(sh.wildcard_epoch_linger, 0);
    }
}
