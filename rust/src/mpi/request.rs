//! Request objects: the global pool ("request class"), per-VCI request
//! caches, and lightweight pre-completed requests (paper §4.1 and §4.3).

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

use crate::platform::{padvance, Backend, PMutex};
use crate::sim::CostModel;

use super::instrument::{HostMutex, LockClass, ModeledCounter};

/// Slab index of a real request.
pub type ReqId = u32;

/// [`ReqSlot::flags`] bit: the owning communicator's policy stripes its
/// traffic across the pool, so waits sweep the stripe lanes and frees are
/// deferred to the recorded VCI instead of taking its lock.
pub const REQ_FLAG_STRIPED: u8 = 1;
/// [`ReqSlot::flags`] bit: the owning communicator participates in
/// doorbell-gated progress sweeps.
pub const REQ_FLAG_DOORBELL: u8 = 2;
/// [`ReqSlot::flags`] bit: the request was initiated on a lane the
/// calling thread owns as a serial execution stream — `wait` drives the
/// lock-free single-writer progress path and releases the id to the
/// thread-local stream freelist instead of the shared slab.
pub const REQ_FLAG_STREAM: u8 = 4;

/// How an initiation op completed / will complete.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Completion {
    /// Not yet known (e.g. waiting for a remote event).
    Pending,
    /// Completes once virtual time reaches `t` (TX DMA done, hardware RMA).
    AtTime(u64),
    /// Complete.
    Done,
}

/// A user-visible request handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Request {
    /// Pre-completed lightweight request (immediate-completion small sends).
    /// Carries the VCI whose lightweight refcount was bumped.
    Lightweight { vci: usize },
    /// Slab-backed request.
    Real { id: ReqId, vci: usize },
}

impl Request {
    pub fn vci(&self) -> usize {
        match self {
            Request::Lightweight { vci } => *vci,
            Request::Real { vci, .. } => *vci,
        }
    }
}

/// One slab slot. Data fields use host synchronization (always correct);
/// modeled costs are charged on the MPI critical path, not here.
pub struct ReqSlot {
    /// 0 = pending, 1 = complete. Atomic updates are charged in FG mode
    /// (completion counting), free under the Global CS.
    pub completed: ModeledCounter,
    /// Completion deadline for `Completion::AtTime` (0 = none).
    pub complete_at: AtomicU64,
    /// VCI recorded for per-VCI progress (paper: +3 instructions).
    pub vci: AtomicUsize,
    /// Per-request progress/release routing derived from the owning
    /// communicator's [`crate::mpi::CommPolicy`] at initiation
    /// ([`REQ_FLAG_STRIPED`] | [`REQ_FLAG_DOORBELL`]). With per-comm
    /// policies the waiter can no longer read the progress model off the
    /// process config — a striped comm's request sweeps the pool while an
    /// ordered comm's request polls only its own VCI, in the same process.
    pub flags: AtomicU8,
    /// Received payload (recv requests) or fetched data (RMA).
    pub data: HostMutex<Option<Vec<u8>>>,
    /// Generation counter guarding against stale handles (debug aid).
    pub generation: AtomicU64,
}

impl ReqSlot {
    fn new(backend: Backend) -> Self {
        ReqSlot {
            completed: ModeledCounter::new(backend, 0),
            complete_at: AtomicU64::new(0),
            vci: AtomicUsize::new(0),
            flags: AtomicU8::new(0),
            data: HostMutex::new(None),
            generation: AtomicU64::new(0),
        }
    }
}

/// The request slab + global free pool.
pub struct RequestSlab {
    slots: Vec<ReqSlot>,
    /// The "request class" free list, guarded by its own lock in FG mode.
    free: PMutex<Vec<ReqId>>,
    /// Global lightweight pre-completed request refcount (used when per-VCI
    /// lightweight replication is off): a contended atomic by design.
    pub global_lightweight_refs: ModeledCounter,
    backend: Backend,
}

pub const DEFAULT_SLAB_CAPACITY: usize = 1 << 14;

impl RequestSlab {
    pub fn new(backend: Backend, capacity: usize) -> Self {
        RequestSlab {
            slots: (0..capacity).map(|_| ReqSlot::new(backend)).collect(),
            free: PMutex::new(backend, (0..capacity as ReqId).rev().collect()),
            global_lightweight_refs: ModeledCounter::new(backend, 0),
            backend,
        }
    }

    pub fn slot(&self, id: ReqId) -> &ReqSlot {
        &self.slots[id as usize]
    }

    /// Bounds-checked slot lookup for handles that arrive off the wire —
    /// a malformed handle must be droppable, not a panic.
    pub fn try_slot(&self, id: u64) -> Option<(ReqId, &ReqSlot)> {
        let id = ReqId::try_from(id).ok()?;
        self.slots.get(id as usize).map(|s| (id, s))
    }

    /// Allocate from the global pool, taking the request-class lock (the
    /// FG-mode cost the per-VCI cache exists to avoid). Under the Global
    /// CS the pool is accessed lock-free (the big lock already serializes),
    /// so `take_lock` is false and no lock is counted.
    pub fn alloc_global(&self, costs: &CostModel, take_lock: bool) -> ReqId {
        let id = if take_lock {
            let mut f = self.free.lock_class(LockClass::Request);
            padvance(self.backend, costs.request_pool_op);
            f.pop().expect("request slab exhausted")
        } else {
            // Global CS held (uncontended inner lock) or no-thread-safety
            // mode (paper Fig. 12 — unsafely racy in real code; here the
            // host lock keeps the data sane and charges only the
            // uncontended fast path).
            let mut f = self.free.lock_uncounted(LockClass::Request);
            padvance(self.backend, costs.request_pool_op);
            f.pop().expect("request slab exhausted")
        };
        let s = self.slot(id);
        s.completed.store(0, false);
        s.complete_at.store(0, Ordering::Release);
        s.flags.store(0, Ordering::Relaxed);
        s.generation.fetch_add(1, Ordering::AcqRel);
        *s.data.lock(LockClass::HostSlotData) = None;
        id
    }

    /// Return a request to the global pool.
    pub fn free_global(&self, id: ReqId, costs: &CostModel, take_lock: bool) {
        if take_lock {
            let mut f = self.free.lock_class(LockClass::Request);
            padvance(self.backend, costs.request_pool_op);
            f.push(id);
        } else {
            let mut f = self.free.lock_uncounted(LockClass::Request);
            padvance(self.backend, costs.request_pool_op);
            f.push(id);
        }
    }

    /// Refill a per-VCI cache: one pool-lock acquisition hands out a chunk
    /// of requests (slab style — also how MPICH batches pool traffic).
    /// Returns the ids; the caller stashes all but one in its cache.
    pub fn alloc_chunk(&self, costs: &CostModel, take_lock: bool, n: usize) -> Vec<ReqId> {
        let mut f = if take_lock {
            self.free.lock_class(LockClass::Request)
        } else {
            self.free.lock_uncounted(LockClass::Request)
        };
        padvance(self.backend, costs.request_pool_op);
        let len = f.len();
        let take = n.min(len);
        assert!(take > 0, "request slab exhausted");
        f.split_off(len - take)
    }

    /// Reset a slot freshly popped from a per-VCI cache (the cache path
    /// bypasses `alloc_global`'s reset).
    pub fn reset_slot(&self, id: ReqId) {
        let s = self.slot(id);
        s.completed.store(0, false);
        s.complete_at.store(0, Ordering::Release);
        s.flags.store(0, Ordering::Relaxed);
        s.generation.fetch_add(1, Ordering::AcqRel);
        *s.data.lock(LockClass::HostSlotData) = None;
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab() -> RequestSlab {
        RequestSlab::new(Backend::Native, 8)
    }

    #[test]
    fn alloc_free_roundtrip() {
        let s = slab();
        let c = CostModel::default();
        let a = s.alloc_global(&c, true);
        let b = s.alloc_global(&c, true);
        assert_ne!(a, b);
        s.free_global(a, &c, true);
        let a2 = s.alloc_global(&c, true);
        assert_eq!(a2, a, "LIFO free list reuses the slot");
    }

    #[test]
    fn slot_state_resets_on_alloc() {
        let s = slab();
        let c = CostModel::default();
        let a = s.alloc_global(&c, true);
        s.slot(a).completed.store(1, false);
        *s.slot(a).data.lock(LockClass::HostSlotData) = Some(vec![1, 2, 3]);
        s.free_global(a, &c, true);
        let a2 = s.alloc_global(&c, true);
        assert_eq!(a2, a);
        assert_eq!(s.slot(a2).completed.load(), 0);
        assert!(s.slot(a2).data.lock(LockClass::HostSlotData).is_none());
    }

    #[test]
    #[should_panic(expected = "request slab exhausted")]
    fn exhaustion_panics() {
        let s = slab();
        let c = CostModel::default();
        for _ in 0..9 {
            s.alloc_global(&c, true);
        }
    }

    #[test]
    fn request_handle_carries_vci() {
        assert_eq!(Request::Lightweight { vci: 3 }.vci(), 3);
        assert_eq!(Request::Real { id: 7, vci: 5 }.vci(), 5);
    }
}
