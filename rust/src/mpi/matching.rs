//! The two-sided matching engine: posted-receive and unexpected-message
//! queues with MPI's matching rules (<communicator, rank, tag> with
//! MPI_ANY_SOURCE / MPI_ANY_TAG wildcards) and nonovertaking order.
//!
//! One `MatchingState` lives inside each VCI. Without striping, all
//! traffic of the communicators mapped to that VCI funnels through it,
//! which is precisely how the standard's ordering constraints are
//! preserved (paper §2.1). With striping, additional `MatchingState`
//! instances serve as the **shards** of a per-communicator sharded engine
//! (see `mpi::shard`): one `(comm, source)` stream per shard, each shard
//! owning the full reorder + match pipeline for its streams.
//!
//! # Receiver-side reorder stage (VCI striping)
//!
//! With [`crate::mpi::VciStriping`] enabled, one communicator's messages
//! fan out across many VCIs and therefore across *independent* delivery
//! queues — the network no longer hands them to the matching engine in
//! send order. Correctness moves here: every striped envelope carries the
//! sender's per-`(comm, destination)` stream sequence, and
//! [`MatchingState::on_striped_arrival`] admits a `(comm_id, src_rank)`
//! stream to matching strictly in that order. Arrivals ahead of the next
//! expected seq park in a per-stream reorder buffer; an in-order arrival
//! is admitted and then drains any contiguous run of parked successors.
//! Duplicate sequences (already admitted or already parked — malformed or
//! replayed traffic) are dropped with a counted diagnostic rather than
//! corrupting the stream. Out-of-stripe control traffic (CTS / DATA /
//! acks / RMA active messages) never enters this stage.
//!
//! The stage guarantees exactly the ordering MPI demands and no more:
//! admission order per stream equals send order, so the unexpected queue
//! and posted-queue scans below see striped traffic exactly as if it had
//! arrived on a single VCI.
//!
//! # Sharded matching and the wildcard-epoch state machine
//!
//! PR 1 ran this stage on the communicator's *home* VCI, re-serializing
//! the receive side. Now the stage runs inside one of the communicator's
//! matching shards — `shard(hash(comm, src))` — locked by whichever VCI
//! polled the envelope, so different sources match concurrently. The
//! wildcard state machine (implemented in `mpi::shard`) has two states:
//!
//! * **Sharded** (no `MPI_ANY_SOURCE` pending): concrete-source receives
//!   and striped arrivals route to their stream's shard; the only shared
//!   cost is an atomic mode load.
//! * **Serialized epoch**: posting a wildcard receive drains every shard
//!   into the home shard (stream order preserved — a stream never spans
//!   shards) and routes all traffic there, restoring single-engine
//!   semantics so the wildcard can match any source. The epoch ends when
//!   the last pending wildcard completes (plus an optional
//!   `wildcard_epoch_linger` hysteresis), splitting the home shard's
//!   state back out by source.
//!
//! Transitions migrate queue and reorder-stage state with
//! [`MatchingState::take_parts`] / [`MatchingState::absorb_parts`]; both
//! directions preserve per-stream queue order and `next_seq` continuity,
//! which is all MPI's nonovertaking rule observes.

use std::collections::hash_map::Entry;
use std::collections::{BTreeMap, HashMap, VecDeque};

use super::request::ReqId;

/// Source matching pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    Any,
    Rank(usize),
}

/// Tag matching pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tag {
    Any,
    Value(i32),
}

/// A posted (pending) receive.
#[derive(Clone, Debug)]
pub struct PostedRecv {
    pub comm_id: u64,
    pub src: Src,
    pub tag: Tag,
    pub req: ReqId,
}

/// Sender-side info needed to respond to a matched message.
#[derive(Clone, Copy, Debug)]
pub struct SenderInfo {
    pub src_proc: usize,
    pub src_ctx: usize,
    /// Sender's request handle for acks / rendezvous CTS.
    pub send_handle: u64,
}

/// How the payload arrives.
#[derive(Clone, Debug)]
pub enum Arrival {
    /// Eager: data travelled with the envelope.
    Eager { data: Vec<u8>, needs_ack: bool },
    /// Rendezvous request-to-send: data still at the sender.
    Rts,
}

/// An arrived-but-unmatched message.
#[derive(Clone, Debug)]
pub struct UnexpectedMsg {
    pub comm_id: u64,
    pub src_rank: usize,
    pub tag: i32,
    pub seq: u64,
    pub sender: SenderInfo,
    pub arrival: Arrival,
}

/// Per-stream sequencing state for the striped-traffic reorder stage.
pub(crate) struct StreamOrder {
    /// Next sender sequence number to admit (sender counters start at 1).
    next_seq: u64,
    /// Ahead-of-order arrivals parked until the gap fills, keyed by seq.
    parked: BTreeMap<u64, UnexpectedMsg>,
}

impl StreamOrder {
    fn new() -> Self {
        StreamOrder { next_seq: 1, parked: BTreeMap::new() }
    }
}

/// Matching queues for one VCI.
#[derive(Default)]
pub struct MatchingState {
    posted: VecDeque<PostedRecv>,
    unexpected: VecDeque<UnexpectedMsg>,
    /// Reorder stage: one sequencing record per striped (comm_id, src_rank)
    /// stream homed on this VCI.
    streams: HashMap<(u64, usize), StreamOrder>,
    /// Striped arrivals dropped for carrying an already-admitted or
    /// already-parked sequence number (duplicate / malformed traffic).
    dup_seq_drops: u64,
}

fn envelope_matches(p: &PostedRecv, comm_id: u64, src_rank: usize, tag: i32) -> bool {
    p.comm_id == comm_id
        && match p.src {
            Src::Any => true,
            Src::Rank(r) => r == src_rank,
        }
        && match p.tag {
            Tag::Any => true,
            Tag::Value(t) => t == tag,
        }
}

impl MatchingState {
    pub fn new() -> Self {
        Self::default()
    }

    /// An envelope arrived: match it against the posted queue (in post
    /// order — MPI's matching rule) or append it to the unexpected queue.
    /// On a match, both the posted receive and the message are returned.
    pub fn on_arrival(&mut self, msg: UnexpectedMsg) -> Option<(PostedRecv, UnexpectedMsg)> {
        if let Some(pos) = self
            .posted
            .iter()
            .position(|p| envelope_matches(p, msg.comm_id, msg.src_rank, msg.tag))
        {
            self.posted.remove(pos).map(|p| (p, msg))
        } else {
            self.unexpected.push_back(msg);
            None
        }
    }

    /// A receive is being posted: search the unexpected queue first (in
    /// arrival order), otherwise append to the posted queue.
    pub fn on_post(&mut self, recv: PostedRecv) -> Option<UnexpectedMsg> {
        if let Some(pos) = self
            .unexpected
            .iter()
            .position(|m| envelope_matches(&recv, m.comm_id, m.src_rank, m.tag))
        {
            // Nonovertaking: among queued messages matching this pattern,
            // consume the earliest-arrived (lowest position; FIFO per
            // stream implies lowest seq). `position()` guarantees it; the
            // debug check makes the invariant explicit.
            debug_assert!(!self.unexpected.iter().take(pos).any(|m| envelope_matches(
                &recv,
                m.comm_id,
                m.src_rank,
                m.tag
            )));
            let msg = self.unexpected.remove(pos).unwrap();
            Some(msg)
        } else {
            self.posted.push_back(recv);
            None
        }
    }

    /// A *striped* envelope arrived: run the reorder stage, then hand every
    /// newly admissible message to [`MatchingState::on_arrival`]. Returns
    /// the (posted, message) pairs that matched — possibly several, because
    /// an in-order arrival can unpark a contiguous run of successors.
    ///
    /// Ordering contract: for a given `(comm_id, src_rank)` stream,
    /// admission happens exactly once per sequence number and strictly in
    /// increasing sequence order. Arrivals ahead of the next expected seq
    /// are parked; duplicates are dropped and counted (see
    /// [`MatchingState::dup_seq_drops`]).
    pub fn on_striped_arrival(
        &mut self,
        msg: UnexpectedMsg,
    ) -> Vec<(PostedRecv, UnexpectedMsg)> {
        let stream = self
            .streams
            .entry((msg.comm_id, msg.src_rank))
            .or_insert_with(StreamOrder::new);
        if msg.seq < stream.next_seq || stream.parked.contains_key(&msg.seq) {
            self.dup_seq_drops += 1;
            super::instrument::record_dup_seq_drop();
            return Vec::new();
        }
        if msg.seq > stream.next_seq {
            stream.parked.insert(msg.seq, msg);
            return Vec::new();
        }
        // In order: admit it, then drain the contiguous parked run.
        let mut ready = vec![msg];
        stream.next_seq += 1;
        while let Some(next) = stream.parked.remove(&stream.next_seq) {
            ready.push(next);
            stream.next_seq += 1;
        }
        ready.into_iter().filter_map(|m| self.on_arrival(m)).collect()
    }

    pub fn posted_len(&self) -> usize {
        self.posted.len()
    }

    pub fn unexpected_len(&self) -> usize {
        self.unexpected.len()
    }

    /// Striped arrivals currently parked waiting for a sequence gap.
    pub fn reorder_parked(&self) -> usize {
        self.streams.values().map(|s| s.parked.len()).sum()
    }

    /// Duplicate-sequence striped arrivals dropped so far.
    pub fn dup_seq_drops(&self) -> u64 {
        self.dup_seq_drops
    }

    /// Next sequence number the reorder stage will admit for a stream
    /// (1 if the stream has never been seen). Test/debug aid.
    pub fn next_expected_seq(&self, comm_id: u64, src_rank: usize) -> u64 {
        self.streams.get(&(comm_id, src_rank)).map_or(1, |s| s.next_seq)
    }

    // ---- state migration (wildcard-epoch transitions, `mpi::shard`) ----

    /// Move every posted receive, unexpected message, and reorder-stream
    /// record out of this engine (the duplicate-drop counter stays — it is
    /// a diagnostic of this engine, not of the traffic).
    pub(crate) fn take_parts(&mut self) -> MatchingParts {
        MatchingParts {
            posted: std::mem::take(&mut self.posted),
            unexpected: std::mem::take(&mut self.unexpected),
            streams: std::mem::take(&mut self.streams),
        }
    }

    /// Append another engine's state behind this engine's own. Within each
    /// `(comm, src)` stream both queue order and reorder-stage continuity
    /// are preserved because a stream lives wholly in one engine at a time;
    /// cross-stream interleaving is not an MPI-visible order.
    ///
    /// A stream present in BOTH engines is only reachable from engine
    /// adoption — epoch flips move each stream whole. Between the
    /// adoption's table swap and its stop-the-world drain
    /// (`CommMatch::retire_into`), new arrivals land in the successor
    /// while the retired engine still holds the stream's earlier state,
    /// which is then migrated here. Each sequence number is delivered
    /// once and admission is strictly sequential, so the two records
    /// never admitted the same seq, and no receive can be posted before
    /// the creation call returns — the merge below therefore reconciles
    /// exactly: farthest admission point wins, parked arrivals the other
    /// engine already admitted drop as counted duplicates (replays
    /// straddling the adoption window), and any contiguous run the union
    /// completes is admitted to the unexpected queue behind the
    /// earlier-seq admissions, preserving per-stream order (the posted
    /// queue is empty in this scenario).
    pub(crate) fn absorb_parts(&mut self, parts: MatchingParts) {
        self.posted.extend(parts.posted);
        self.unexpected.extend(parts.unexpected);
        for (key, stream) in parts.streams {
            match self.streams.entry(key) {
                Entry::Vacant(e) => {
                    e.insert(stream);
                }
                Entry::Occupied(mut e) => {
                    let cur = e.get_mut();
                    if stream.next_seq > cur.next_seq {
                        cur.next_seq = stream.next_seq;
                        // Drop parked arrivals the migrated engine had
                        // already admitted (replays straddling the
                        // adoption window).
                        while let Some((&seq, _)) = cur.parked.first_key_value() {
                            if seq >= cur.next_seq {
                                break;
                            }
                            cur.parked.remove(&seq);
                            self.dup_seq_drops += 1;
                            super::instrument::record_dup_seq_drop();
                        }
                    }
                    for (seq, msg) in stream.parked {
                        if seq < cur.next_seq || cur.parked.contains_key(&seq) {
                            self.dup_seq_drops += 1;
                            super::instrument::record_dup_seq_drop();
                        } else {
                            cur.parked.insert(seq, msg);
                        }
                    }
                    while let Some(msg) = cur.parked.remove(&cur.next_seq) {
                        cur.next_seq += 1;
                        self.unexpected.push_back(msg);
                    }
                }
            }
        }
    }

    /// Is there any posted/unexpected/reorder state in this engine?
    pub(crate) fn is_idle(&self) -> bool {
        self.posted.is_empty() && self.unexpected.is_empty() && self.streams.is_empty()
    }
}

/// Matching-engine state in transit between engines (epoch flips).
pub(crate) struct MatchingParts {
    pub(crate) posted: VecDeque<PostedRecv>,
    pub(crate) unexpected: VecDeque<UnexpectedMsg>,
    pub(crate) streams: HashMap<(u64, usize), StreamOrder>,
}

impl MatchingParts {
    /// Split by source rank into `n` buckets via `route` (posted receives
    /// route by their concrete source; wildcard receives must not be in
    /// transit when splitting — epoch flip-back requires all wildcards
    /// completed). Relative order within a bucket is preserved.
    pub(crate) fn split_by_source(self, n: usize, route: impl Fn(usize) -> usize) -> Vec<Self> {
        let mut out: Vec<MatchingParts> = (0..n)
            .map(|_| MatchingParts {
                posted: VecDeque::new(),
                unexpected: VecDeque::new(),
                streams: HashMap::new(),
            })
            .collect();
        for p in self.posted {
            let idx = match p.src {
                Src::Rank(r) => route(r),
                // Unreachable by the epoch protocol; keep it in bucket 0
                // (the home shard) rather than dropping a receive.
                Src::Any => 0,
            };
            out[idx].posted.push_back(p);
        }
        for m in self.unexpected {
            let idx = route(m.src_rank);
            out[idx].unexpected.push_back(m);
        }
        for ((comm, src), s) in self.streams {
            out[route(src)].streams.insert((comm, src), s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn umsg(comm: u64, src: usize, tag: i32, seq: u64) -> UnexpectedMsg {
        UnexpectedMsg {
            comm_id: comm,
            src_rank: src,
            tag,
            seq,
            sender: SenderInfo { src_proc: src, src_ctx: 0, send_handle: 0 },
            arrival: Arrival::Eager { data: vec![], needs_ack: false },
        }
    }

    fn precv(comm: u64, src: Src, tag: Tag, req: ReqId) -> PostedRecv {
        PostedRecv { comm_id: comm, src, tag, req }
    }

    #[test]
    fn exact_match_on_arrival() {
        let mut m = MatchingState::new();
        assert!(m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).is_none());
        let hit = m.on_arrival(umsg(1, 2, 7, 1));
        assert_eq!(hit.unwrap().0.req, 10);
        assert_eq!(m.posted_len(), 0);
    }

    #[test]
    fn mismatched_envelope_goes_unexpected() {
        let mut m = MatchingState::new();
        assert!(m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).is_none());
        assert!(m.on_arrival(umsg(1, 3, 7, 1)).is_none(), "wrong src");
        assert!(m.on_arrival(umsg(1, 2, 8, 1)).is_none(), "wrong tag");
        assert!(m.on_arrival(umsg(2, 2, 7, 1)).is_none(), "wrong comm");
        assert_eq!(m.unexpected_len(), 3);
        assert_eq!(m.posted_len(), 1);
    }

    #[test]
    fn any_source_any_tag_wildcards() {
        let mut m = MatchingState::new();
        m.on_post(precv(1, Src::Any, Tag::Any, 10));
        let hit = m.on_arrival(umsg(1, 5, 99, 1));
        assert_eq!(hit.unwrap().0.req, 10);
    }

    #[test]
    fn unexpected_consumed_in_arrival_order() {
        let mut m = MatchingState::new();
        assert!(m.on_arrival(umsg(1, 2, 7, 1)).is_none());
        assert!(m.on_arrival(umsg(1, 2, 7, 2)).is_none());
        let first = m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).unwrap();
        assert_eq!(first.seq, 1, "earliest arrival matches first");
        let second = m.on_post(precv(1, Src::Any, Tag::Any, 11)).unwrap();
        assert_eq!(second.seq, 2);
    }

    #[test]
    fn posted_matched_in_post_order() {
        let mut m = MatchingState::new();
        m.on_post(precv(1, Src::Any, Tag::Any, 10));
        m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 11));
        let hit = m.on_arrival(umsg(1, 2, 7, 1));
        assert_eq!(hit.unwrap().0.req, 10, "first posted wins even vs exact match");
    }

    #[test]
    fn different_tags_may_be_consumed_out_of_seq_order() {
        // Legal MPI: recv(tag=20) posted before recv(tag=10) consumes the
        // later-sequenced message first — nonovertaking only constrains
        // messages that match the same pattern.
        let mut m = MatchingState::new();
        assert!(m.on_arrival(umsg_tag(1, 2, 10, 1)).is_none());
        assert!(m.on_arrival(umsg_tag(1, 2, 20, 2)).is_none());
        let got20 = m.on_post(precv(1, Src::Rank(2), Tag::Value(20), 11)).unwrap();
        assert_eq!(got20.seq, 2);
        let got10 = m.on_post(precv(1, Src::Rank(2), Tag::Value(10), 12)).unwrap();
        assert_eq!(got10.seq, 1);
    }

    fn umsg_tag(comm: u64, src: usize, tag: i32, seq: u64) -> UnexpectedMsg {
        umsg(comm, src, tag, seq)
    }

    // ---- reorder stage (striped traffic) ----

    #[test]
    fn striped_in_order_arrivals_admit_immediately() {
        let mut m = MatchingState::new();
        m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10));
        let hits = m.on_striped_arrival(umsg(1, 2, 7, 1));
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.req, 10);
        assert_eq!(m.next_expected_seq(1, 2), 2);
        assert_eq!(m.reorder_parked(), 0);
    }

    #[test]
    fn striped_gap_parks_until_filled_then_drains_the_run() {
        let mut m = MatchingState::new();
        // Seqs 3 and 2 arrive ahead of 1 (delivered via other VCIs first).
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 3)).is_empty());
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 2)).is_empty());
        assert_eq!(m.reorder_parked(), 2);
        assert_eq!(m.unexpected_len(), 0, "nothing admitted to matching yet");
        // The gap fills: all three admit at once, in seq order.
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 1)).is_empty(), "no recvs posted");
        assert_eq!(m.reorder_parked(), 0);
        assert_eq!(m.unexpected_len(), 3);
        assert_eq!(m.next_expected_seq(1, 2), 4);
        // Unexpected-queue order equals seq order (nonovertaking restored).
        for want in 1..=3u64 {
            let got = m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).unwrap();
            assert_eq!(got.seq, want);
        }
    }

    #[test]
    fn striped_gap_drain_matches_already_posted_recvs() {
        let mut m = MatchingState::new();
        m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10));
        m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 11));
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 2)).is_empty());
        let hits = m.on_striped_arrival(umsg(1, 2, 7, 1));
        assert_eq!(hits.len(), 2, "gap fill admits and matches the whole run");
        assert_eq!(hits[0].1.seq, 1);
        assert_eq!(hits[0].0.req, 10, "first posted gets the first-sequenced message");
        assert_eq!(hits[1].1.seq, 2);
        assert_eq!(hits[1].0.req, 11);
    }

    #[test]
    fn striped_duplicate_seqs_are_dropped_and_counted() {
        let mut m = MatchingState::new();
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 1)).is_empty());
        assert_eq!(m.dup_seq_drops(), 0);
        // Replay of an admitted seq.
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 1)).is_empty());
        assert_eq!(m.dup_seq_drops(), 1);
        assert_eq!(m.unexpected_len(), 1, "replay must not be admitted twice");
        // Duplicate of a parked (not yet admitted) seq.
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 5)).is_empty());
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 5)).is_empty());
        assert_eq!(m.dup_seq_drops(), 2);
        assert_eq!(m.reorder_parked(), 1);
    }

    #[test]
    fn absorb_parts_merges_colliding_streams_at_the_farthest_admission_point() {
        // Adoption-window shape: the retired engine admitted seqs 1-2 and
        // parked 5 before the table swap; seqs 3 and 4 then landed in the
        // successor (parked — its record started fresh). The merge must
        // admit 3..5 behind 1-2 and leave the stream continuous at 6.
        let mut retired = MatchingState::new();
        assert!(retired.on_striped_arrival(umsg(1, 2, 7, 1)).is_empty());
        assert!(retired.on_striped_arrival(umsg(1, 2, 7, 2)).is_empty());
        assert!(retired.on_striped_arrival(umsg(1, 2, 7, 5)).is_empty());
        let mut successor = MatchingState::new();
        assert!(successor.on_striped_arrival(umsg(1, 2, 7, 3)).is_empty());
        assert!(successor.on_striped_arrival(umsg(1, 2, 7, 4)).is_empty());
        assert_eq!(successor.unexpected_len(), 0, "fresh record parks everything");
        successor.absorb_parts(retired.take_parts());
        assert_eq!(successor.unexpected_len(), 5, "union completes the run");
        assert_eq!(successor.reorder_parked(), 0);
        assert_eq!(successor.next_expected_seq(1, 2), 6);
        for want in 1..=5u64 {
            let got = successor.on_post(precv(1, Src::Rank(2), Tag::Value(7), 9)).unwrap();
            assert_eq!(got.seq, want, "merged stream out of order");
        }
        assert_eq!(successor.dup_seq_drops(), 0, "no duplicates were in play");
    }

    #[test]
    fn absorb_parts_drops_already_admitted_parked_arrivals() {
        // The successor parked a seq the retired engine had already
        // admitted (a replay straddling the adoption window): it must be
        // dropped and counted, not re-admitted.
        let mut retired = MatchingState::new();
        assert!(retired.on_striped_arrival(umsg(1, 2, 7, 1)).is_empty());
        assert!(retired.on_striped_arrival(umsg(1, 2, 7, 2)).is_empty());
        let mut successor = MatchingState::new();
        assert!(
            successor.on_striped_arrival(umsg(1, 2, 7, 2)).is_empty(),
            "parks on fresh record"
        );
        successor.absorb_parts(retired.take_parts());
        assert_eq!(successor.unexpected_len(), 2, "only the admitted 1-2 survive");
        assert_eq!(successor.next_expected_seq(1, 2), 3);
        assert_eq!(successor.dup_seq_drops(), 1, "replayed seq 2 dropped and counted");
        assert_eq!(successor.reorder_parked(), 0);
    }

    #[test]
    fn striped_streams_are_independent() {
        let mut m = MatchingState::new();
        // Stream (1, src 2) is gapped; stream (1, src 3) and comm 2 flow.
        assert!(m.on_striped_arrival(umsg(1, 2, 7, 2)).is_empty());
        assert!(m.on_striped_arrival(umsg(1, 3, 7, 1)).is_empty());
        assert!(m.on_striped_arrival(umsg(2, 2, 7, 1)).is_empty());
        assert_eq!(m.unexpected_len(), 2, "other streams admit despite the gap");
        assert_eq!(m.reorder_parked(), 1);
        assert_eq!(m.next_expected_seq(1, 2), 1);
        assert_eq!(m.next_expected_seq(1, 3), 2);
        assert_eq!(m.next_expected_seq(2, 2), 2);
    }
}
