//! The two-sided matching engine: posted-receive and unexpected-message
//! queues with MPI's matching rules (<communicator, rank, tag> with
//! MPI_ANY_SOURCE / MPI_ANY_TAG wildcards) and nonovertaking order.
//!
//! One `MatchingState` lives inside each VCI: all traffic of the
//! communicators mapped to that VCI funnels through it, which is precisely
//! how the standard's ordering constraints are preserved (paper §2.1).

use std::collections::VecDeque;

use super::request::ReqId;

/// Source matching pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    Any,
    Rank(usize),
}

/// Tag matching pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tag {
    Any,
    Value(i32),
}

/// A posted (pending) receive.
#[derive(Clone, Debug)]
pub struct PostedRecv {
    pub comm_id: u64,
    pub src: Src,
    pub tag: Tag,
    pub req: ReqId,
}

/// Sender-side info needed to respond to a matched message.
#[derive(Clone, Copy, Debug)]
pub struct SenderInfo {
    pub src_proc: usize,
    pub src_ctx: usize,
    /// Sender's request handle for acks / rendezvous CTS.
    pub send_handle: u64,
}

/// How the payload arrives.
#[derive(Clone, Debug)]
pub enum Arrival {
    /// Eager: data travelled with the envelope.
    Eager { data: Vec<u8>, needs_ack: bool },
    /// Rendezvous request-to-send: data still at the sender.
    Rts,
}

/// An arrived-but-unmatched message.
#[derive(Clone, Debug)]
pub struct UnexpectedMsg {
    pub comm_id: u64,
    pub src_rank: usize,
    pub tag: i32,
    pub seq: u64,
    pub sender: SenderInfo,
    pub arrival: Arrival,
}

/// Matching queues for one VCI.
#[derive(Default)]
pub struct MatchingState {
    posted: VecDeque<PostedRecv>,
    unexpected: VecDeque<UnexpectedMsg>,
}

fn envelope_matches(p: &PostedRecv, comm_id: u64, src_rank: usize, tag: i32) -> bool {
    p.comm_id == comm_id
        && match p.src {
            Src::Any => true,
            Src::Rank(r) => r == src_rank,
        }
        && match p.tag {
            Tag::Any => true,
            Tag::Value(t) => t == tag,
        }
}

impl MatchingState {
    pub fn new() -> Self {
        Self::default()
    }

    /// An envelope arrived: match it against the posted queue (in post
    /// order — MPI's matching rule) or append it to the unexpected queue.
    /// On a match, both the posted receive and the message are returned.
    pub fn on_arrival(&mut self, msg: UnexpectedMsg) -> Option<(PostedRecv, UnexpectedMsg)> {
        if let Some(pos) = self
            .posted
            .iter()
            .position(|p| envelope_matches(p, msg.comm_id, msg.src_rank, msg.tag))
        {
            self.posted.remove(pos).map(|p| (p, msg))
        } else {
            self.unexpected.push_back(msg);
            None
        }
    }

    /// A receive is being posted: search the unexpected queue first (in
    /// arrival order), otherwise append to the posted queue.
    pub fn on_post(&mut self, recv: PostedRecv) -> Option<UnexpectedMsg> {
        if let Some(pos) = self
            .unexpected
            .iter()
            .position(|m| envelope_matches(&recv, m.comm_id, m.src_rank, m.tag))
        {
            // Nonovertaking: among queued messages matching this pattern,
            // consume the earliest-arrived (lowest position; FIFO per
            // stream implies lowest seq). `position()` guarantees it; the
            // debug check makes the invariant explicit.
            debug_assert!(!self.unexpected.iter().take(pos).any(|m| envelope_matches(
                &recv,
                m.comm_id,
                m.src_rank,
                m.tag
            )));
            let msg = self.unexpected.remove(pos).unwrap();
            Some(msg)
        } else {
            self.posted.push_back(recv);
            None
        }
    }

    pub fn posted_len(&self) -> usize {
        self.posted.len()
    }

    pub fn unexpected_len(&self) -> usize {
        self.unexpected.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn umsg(comm: u64, src: usize, tag: i32, seq: u64) -> UnexpectedMsg {
        UnexpectedMsg {
            comm_id: comm,
            src_rank: src,
            tag,
            seq,
            sender: SenderInfo { src_proc: src, src_ctx: 0, send_handle: 0 },
            arrival: Arrival::Eager { data: vec![], needs_ack: false },
        }
    }

    fn precv(comm: u64, src: Src, tag: Tag, req: ReqId) -> PostedRecv {
        PostedRecv { comm_id: comm, src, tag, req }
    }

    #[test]
    fn exact_match_on_arrival() {
        let mut m = MatchingState::new();
        assert!(m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).is_none());
        let hit = m.on_arrival(umsg(1, 2, 7, 1));
        assert_eq!(hit.unwrap().0.req, 10);
        assert_eq!(m.posted_len(), 0);
    }

    #[test]
    fn mismatched_envelope_goes_unexpected() {
        let mut m = MatchingState::new();
        assert!(m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).is_none());
        assert!(m.on_arrival(umsg(1, 3, 7, 1)).is_none(), "wrong src");
        assert!(m.on_arrival(umsg(1, 2, 8, 1)).is_none(), "wrong tag");
        assert!(m.on_arrival(umsg(2, 2, 7, 1)).is_none(), "wrong comm");
        assert_eq!(m.unexpected_len(), 3);
        assert_eq!(m.posted_len(), 1);
    }

    #[test]
    fn any_source_any_tag_wildcards() {
        let mut m = MatchingState::new();
        m.on_post(precv(1, Src::Any, Tag::Any, 10));
        let hit = m.on_arrival(umsg(1, 5, 99, 1));
        assert_eq!(hit.unwrap().0.req, 10);
    }

    #[test]
    fn unexpected_consumed_in_arrival_order() {
        let mut m = MatchingState::new();
        assert!(m.on_arrival(umsg(1, 2, 7, 1)).is_none());
        assert!(m.on_arrival(umsg(1, 2, 7, 2)).is_none());
        let first = m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 10)).unwrap();
        assert_eq!(first.seq, 1, "earliest arrival matches first");
        let second = m.on_post(precv(1, Src::Any, Tag::Any, 11)).unwrap();
        assert_eq!(second.seq, 2);
    }

    #[test]
    fn posted_matched_in_post_order() {
        let mut m = MatchingState::new();
        m.on_post(precv(1, Src::Any, Tag::Any, 10));
        m.on_post(precv(1, Src::Rank(2), Tag::Value(7), 11));
        let hit = m.on_arrival(umsg(1, 2, 7, 1));
        assert_eq!(hit.unwrap().0.req, 10, "first posted wins even vs exact match");
    }

    #[test]
    fn different_tags_may_be_consumed_out_of_seq_order() {
        // Legal MPI: recv(tag=20) posted before recv(tag=10) consumes the
        // later-sequenced message first — nonovertaking only constrains
        // messages that match the same pattern.
        let mut m = MatchingState::new();
        assert!(m.on_arrival(umsg_tag(1, 2, 10, 1)).is_none());
        assert!(m.on_arrival(umsg_tag(1, 2, 20, 2)).is_none());
        let got20 = m.on_post(precv(1, Src::Rank(2), Tag::Value(20), 11)).unwrap();
        assert_eq!(got20.seq, 2);
        let got10 = m.on_post(precv(1, Src::Rank(2), Tag::Value(10), 12)).unwrap();
        assert_eq!(got10.seq, 1);
    }

    fn umsg_tag(comm: u64, src: usize, tag: i32, seq: u64) -> UnexpectedMsg {
        umsg(comm, src, tag, seq)
    }
}
