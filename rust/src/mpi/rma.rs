//! One-sided communication: windows, MPI_Put / MPI_Get / MPI_Accumulate /
//! MPI_Fetch_and_op, and passive-target synchronization — both the
//! flush family (MPI_Win_flush / MPI_Win_flush_local) and lock epochs
//! (MPI_Win_lock / MPI_Win_unlock / MPI_Win_lock_all /
//! MPI_Win_unlock_all).
//!
//! Interconnect split (paper §5.2):
//!  * IB personality: contiguous Put/Get execute in hardware — the
//!    initiator moves the bytes and completion is a fixed time stamp; no
//!    target CPU involvement (`RmaCompletion::AtTime`).
//!  * OPA personality: RMA is emulated in software — Put/Get become active
//!    messages the *target* must process by polling the target VCI
//!    (`RmaCompletion::OnAck`), which is the root of the paper's
//!    shared-progress findings (Figs. 13-16, 24-25, 27).
//!  * Accumulates ride the active-message path on both personalities
//!    (datatype reductions are not NIC-offloadable in general).
//!
//! # Per-window policy and striped RMA
//!
//! Every window carries a [`WinPolicy`] resolved at creation
//! ([`MpiProc::win_create_with_info`]) from MPI-style info keys —
//! `accumulate_ordering=none`, `vcmpi_striping=off|rr|hash`,
//! `vcmpi_rx_doorbell`, `mpi_assert_no_locks` — over the process default
//! (the demoted `accumulate_ordering_none` hint on `MpiConfig`), mirroring
//! how communicators resolve a `CommPolicy`. The decision table:
//!
//! | window policy                         | put            | accumulate        | completion                  |
//! |---------------------------------------|----------------|-------------------|-----------------------------|
//! | `striping=off` (ordered, the default) | home VCI       | home VCI¹         | flush handle → `acked` set  |
//! | striped, `accumulate_ordering` kept   | stripe lanes   | home VCI (order!) | counted² / `acked` set      |
//! | striped + `accumulate_ordering=none`  | stripe lanes   | stripe lanes      | per-lane ack counters²      |
//!
//! ¹ `accumulate_ordering=none` without striping keeps the pre-policy
//!   *thread*-spread: each thread picks a VCI by its token (§6.3).
//! ² Ack counting (the striped completion model): the origin bumps a
//!   per-(window, target) **issue counter in the stripe lane's own
//!   `VciState`** while injecting, and records the post-increment value as
//!   the calling thread's watermark. The target applies the op and answers
//!   `RmaAckCount` (echoing the lane), which returns to the issuing lane's
//!   context and bumps that lane's **ack counter**. `win_flush` waits, per
//!   recorded (target, lane), until `acked >= watermark` — correct because
//!   each (origin lane, target) channel is FIFO both ways — so flushing no
//!   longer funnels every completion through one VCI's `acked` set, and an
//!   op never needs an individually tracked flush handle. **Gets stripe
//!   the same way**: a striped window's `MPI_Get` issues on a stripe lane
//!   and its reply (which parks the data under the get handle as always)
//!   additionally bumps the issuing lane's ack counter — one thread's gets
//!   fan out exactly like its puts. Ordered windows (and Fetch_and_op
//!   everywhere — a blocking round-trip striping cannot help) keep the
//!   flush-handle protocol unchanged.
//!
//! Ordered (`striping=off`) windows *pin their home VCI out of the
//! stripe-lane set* like ordered communicators do, so striped bulk —
//! two-sided or RMA — never queues behind their latency-sensitive ops;
//! striped windows' lanes stay in the stripe set and their flush sweeps
//! participate in doorbell-gated striped progress (`vcmpi_rx_doorbell`).
//!
//! # Passive-target lock epochs
//!
//! [`MpiProc::win_lock`] / [`MpiProc::win_unlock`] (and the `_all`
//! variants) add MPI-3.1 §11.5.3 lock epochs on top of the flush
//! machinery. The protocol taken is decided per window by
//! (lock kind × interconnect × `mpi_assert_no_locks`) — passive-target
//! rows extending the decision table above:
//!
//! | lock kind × window policy     | acquisition protocol                  | unlock completion                      |
//! |-------------------------------|---------------------------------------|----------------------------------------|
//! | any kind, `mpi_assert_no_locks` | **elided**: local no-op grant, zero wire traffic | per-target flush waits only (see below) |
//! | shared / exclusive, OPA       | `RmaLockReq` → target FIFO lock table → `RmaLockGrant` | per-target flush waits, then `RmaUnlock` → `RmaAck` |
//! | shared, IB                    | NIC-atomic fast path on the target's [`crate::fabric::WinLockWord`] — typically one round trip, no target CPU | per-target flush waits, then one NIC-atomic release |
//! | exclusive, IB                 | NIC-atomic CAS retry loop (no hardware FIFO; each retry costs an atomic round trip) | per-target flush waits, then one NIC-atomic release |
//!
//! "Per-target flush waits" means an unlock completes the calling
//! thread's outstanding ops *to that target* through exactly the PR 4-5
//! watermark machinery a flush uses — per-(window, target, lane) counted
//! acks for striped ops, flush handles for ordered ones, NIC timestamps
//! on IB — so striped windows compose with epochs for free.
//! [`MpiProc::win_flush_local`] waits local injection only; in this model
//! origin buffers are captured at injection, so it is a (charged)
//! bookkeeping no-op that leaves every record for the next
//! flush/unlock.
//!
//! The target-side OPA state machine (`WinLockTable`, per exposed
//! window):
//!
//! ```text
//!            RmaLockReq(Shared), no writer & empty queue
//!   Idle ───────────────────────────────────────────────▶ Readers(n)
//!     │                                                       │
//!     │ RmaLockReq(Excl), idle & empty queue                  │ any req while queue nonempty,
//!     ▼                                                       ▼ or Excl while held
//!   Writer ◀──────────────────────────────── queue (FIFO) ◀───┘
//!     │   RmaUnlock: release, then grant the FIFO prefix:
//!     └──▶ one Exclusive head, or every consecutive Shared head
//! ```
//!
//! A shared request behind a queued exclusive waiter queues too (FIFO
//! fairness: writers cannot starve), and an unlock batch-grants the
//! longest grantable prefix. Lock/unlock control ops ride the window's
//! *home* VCI (like fetch-and-op: blocking round trips striping cannot
//! help), and grants land in the issuing VCI's `lock_granted` set.
//!
//! With `mpi_assert_no_locks` the whole wire protocol is elided to a
//! local no-op grant (the bench gate `no_locks_over_locked` measures
//! exactly the saved round trips); the unlock's flush-completion
//! semantics are kept, so an elided program still observes MPI's
//! completion rules. The standard's `no_locks` means "lock epochs will
//! not be used"; this model interprets the promise as "epochs need no
//! mutual exclusion" and keeps the calls legal as no-ops, so one program
//! text can run both arms.
//!
//! ## Lock-rank placement (SimSan)
//!
//! Epoch state adds two *leaf* host classes to the hierarchy
//! (`mpi::instrument`): `HostRmaEpochs` (rank 147, `Window::epochs` —
//! the origin's open-epoch map) and `HostWinLocks` (rank 148,
//! `MpiProc::win_locks` — the target's FIFO tables, taken under the
//! polled VCI's sim lock, rank 30, by the protocol handlers). Neither is
//! ever held across a scheduler interaction or together with
//! `HostRmaOutstanding` (145): unlock copies the epoch out, drops the
//! lock, then drains records; handlers compute grants under the table
//! lock and reply after dropping it.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::fabric::{AccOp, Interconnect, LockKind, Payload, WindowMem};
use crate::platform::{padvance, pnow};

use super::instrument::{HostMutex, LockClass};
use super::policy::{Info, WinPolicy};
use super::proc::{thread_token, MpiProc, SpinDeadline};

/// An RMA window.
pub struct Window {
    pub id: u64,
    /// VCI this window funnels through (paper §4.2: VCIs are assigned per
    /// window just as per communicator). Striped ops leave it for the
    /// stripe lanes; ordered ops, gets, and fetch-ops stay on it.
    pub vci: usize,
    pub size: usize,
    mem: Arc<WindowMem>,
    /// Per-thread outstanding-operation records (host table; threads only
    /// ever touch their own entry).
    outstanding: HostMutex<HashMap<u64, Vec<OpRecord>>>,
    /// Get results retrieved at flush time, keyed by the GetHandle.
    get_results: HostMutex<HashMap<u64, Vec<u8>>>,
    /// Origin-side passive-target epochs open on this window, by target
    /// rank. MPI allows at most one lock epoch per (window, target) per
    /// process (a second `win_lock` is erroneous and asserts), so the
    /// map is process-wide, not per-thread. `win_free` asserts it empty
    /// — an open epoch (or a grant still in flight, which also has its
    /// entry here) at free time is the freed-comm-style tripwire.
    epochs: HostMutex<HashMap<usize, LockEpoch>>,
    next_handle: AtomicU64,
    /// Per-window policy resolved from info keys at creation — see the
    /// module doc's decision table.
    pub policy: Arc<WinPolicy>,
}

/// Handle to retrieve MPI_Get data after the next flush. Carries the VCI
/// the get was issued on (replies land there).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GetHandle(pub u64, pub usize);

/// Initiator-side completion record for one outstanding RMA op. Every
/// variant carries its target rank so `win_unlock(target)` can drain
/// exactly the records a per-target flush would (`win_flush` drains all).
#[derive(Clone, Copy, Debug)]
enum OpRecord {
    /// Hardware completion at a fixed virtual time (IB personality).
    AtTime { target: usize, at: u64 },
    /// Ack-based completion (software RMA, ordered windows): the ack
    /// arrives on `vci` and lands in its `acked` set.
    OnAck { target: usize, flush_handle: u64, vci: usize },
    /// Counted completion (striped windows): flush is done with this op
    /// once lane `lane`'s ack counter for (window, `target`) reaches
    /// `watermark` — the lane's issue-counter value right after this op
    /// was injected.
    OnCount { target: usize, lane: usize, watermark: u64 },
}

impl OpRecord {
    fn target(&self) -> usize {
        match *self {
            OpRecord::AtTime { target, .. }
            | OpRecord::OnAck { target, .. }
            | OpRecord::OnCount { target, .. } => target,
        }
    }
}

/// One open origin-side lock epoch (see [`Window::epochs`]).
#[derive(Clone, Copy, Debug)]
struct LockEpoch {
    kind: LockKind,
    /// The window's `mpi_assert_no_locks` policy elided the wire protocol
    /// for this epoch: nothing to release at the target.
    elided: bool,
    /// Opened by `win_lock_all` — must be closed by `win_unlock_all`.
    all: bool,
}

/// Target-side passive-target lock state for one exposed window: the
/// software FIFO lock queue the OPA personality's active-message handlers
/// serve (see the module doc's state machine). Grant decisions happen
/// under `MpiProc::win_locks` (`LockClass::HostWinLocks`, a leaf); the
/// grant *messages* are sent after the lock is dropped.
#[derive(Default)]
pub(super) struct WinLockTable {
    /// Concurrent shared holders.
    readers: usize,
    /// The exclusive holder's origin rank, if any.
    writer: Option<usize>,
    /// Requests not yet grantable, FIFO. A shared request behind a queued
    /// exclusive waiter queues too — writers cannot starve.
    queue: VecDeque<QueuedLock>,
}

/// One queued (or being-granted) lock request: enough to address the
/// grant back to the origin's issuing context.
pub(super) struct QueuedLock {
    pub kind: LockKind,
    pub src_proc: usize,
    pub src_ctx: usize,
    pub handle: u64,
}

impl WinLockTable {
    fn grantable(&self, kind: LockKind) -> bool {
        match kind {
            LockKind::Shared => self.writer.is_none(),
            LockKind::Exclusive => self.writer.is_none() && self.readers == 0,
        }
    }

    fn take(&mut self, kind: LockKind, src_proc: usize) {
        match kind {
            LockKind::Shared => self.readers += 1,
            LockKind::Exclusive => {
                debug_assert!(self.writer.is_none() && self.readers == 0);
                self.writer = Some(src_proc);
            }
        }
    }

    /// Admit a new request: `true` grants it immediately (the caller
    /// sends the grant), `false` queued it FIFO for a later unlock.
    pub(super) fn admit(&mut self, q: QueuedLock) -> bool {
        if self.queue.is_empty() && self.grantable(q.kind) {
            self.take(q.kind, q.src_proc);
            true
        } else {
            self.queue.push_back(q);
            false
        }
    }

    /// Release one held lock and pop the now-grantable FIFO prefix (one
    /// exclusive head, or every consecutive shared head) — the caller
    /// sends each returned entry its grant.
    pub(super) fn release(&mut self, kind: LockKind) -> Vec<QueuedLock> {
        match kind {
            LockKind::Shared => self.readers = self.readers.saturating_sub(1),
            LockKind::Exclusive => self.writer = None,
        }
        let mut grants = Vec::new();
        while let Some(head) = self.queue.front() {
            if !self.grantable(head.kind) {
                break;
            }
            let q = self.queue.pop_front().expect("front checked");
            self.take(q.kind, q.src_proc);
            grants.push(q);
        }
        grants
    }

    /// No holder and no waiter (the win_free tripwire's check).
    pub(super) fn is_idle(&self) -> bool {
        self.readers == 0 && self.writer.is_none() && self.queue.is_empty()
    }
}

/// Apply an accumulate op element-wise under the window-memory lock
/// (guarantees MPI's per-element atomicity for same-location accumulates).
pub fn apply_accumulate(mem: &WindowMem, offset: usize, data: &[u8], op: AccOp) {
    mem.rmw(|buf| match op {
        AccOp::Replace => buf[offset..offset + data.len()].copy_from_slice(data),
        AccOp::SumF64 => {
            assert!(data.len() % 8 == 0, "SumF64 needs 8-byte elements");
            for (i, chunk) in data.chunks_exact(8).enumerate() {
                let o = offset + i * 8;
                let cur = f64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
                let add = f64::from_le_bytes(chunk.try_into().unwrap());
                buf[o..o + 8].copy_from_slice(&(cur + add).to_le_bytes());
            }
        }
        AccOp::SumU64 => {
            assert!(data.len() % 8 == 0, "SumU64 needs 8-byte elements");
            for (i, chunk) in data.chunks_exact(8).enumerate() {
                let o = offset + i * 8;
                let cur = u64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
                let add = u64::from_le_bytes(chunk.try_into().unwrap());
                buf[o..o + 8].copy_from_slice(&cur.wrapping_add(add).to_le_bytes());
            }
        }
    });
}

/// Fetch-and-op: returns the previous bytes at the location.
pub fn apply_fetch_op(mem: &WindowMem, offset: usize, operand: &[u8], op: AccOp) -> Vec<u8> {
    mem.rmw(|buf| {
        let prev = buf[offset..offset + operand.len()].to_vec();
        match op {
            AccOp::Replace => buf[offset..offset + operand.len()].copy_from_slice(operand),
            AccOp::SumU64 => {
                let cur = u64::from_le_bytes(buf[offset..offset + 8].try_into().unwrap());
                let add = u64::from_le_bytes(operand[..8].try_into().unwrap());
                buf[offset..offset + 8].copy_from_slice(&cur.wrapping_add(add).to_le_bytes());
            }
            AccOp::SumF64 => {
                let cur = f64::from_le_bytes(buf[offset..offset + 8].try_into().unwrap());
                let add = f64::from_le_bytes(operand[..8].try_into().unwrap());
                buf[offset..offset + 8].copy_from_slice(&(cur + add).to_le_bytes());
            }
        }
        prev
    })
}

impl Window {
    /// Local direct read (the window owner touching its own memory).
    pub fn read_local(&self, offset: usize, len: usize) -> Vec<u8> {
        self.mem.read(offset, len)
    }

    /// Local direct write.
    pub fn write_local(&self, offset: usize, data: &[u8]) {
        self.mem.write(offset, data);
    }

    fn record(&self, c: OpRecord) {
        let mut t = self.outstanding.lock(LockClass::HostRmaOutstanding);
        t.entry(thread_token()).or_default().push(c);
    }

    fn fresh_handle(&self) -> u64 {
        // Window id in the high bits keeps handles globally unique.
        (self.id << 40) | self.next_handle.fetch_add(1, Ordering::AcqRel)
    }
}

/// Origin-side bounds check for a user-issued RMA op: windows are created
/// collectively with symmetric sizes, so the origin can (and must) reject
/// an erroneous span loudly here. The target-side handlers instead *drop*
/// out-of-bounds requests — but a dropped request never acks, so letting
/// an erroneous program reach the wire would turn into a silent flush
/// hang rather than this immediate failure.
fn check_origin_span(win: &Window, offset: usize, len: usize) {
    let ok = match offset.checked_add(len) {
        Some(end) => end <= win.size,
        None => false,
    };
    assert!(
        ok,
        "RMA op out of window bounds (erroneous program): offset {offset} + len {len} > window size {size}",
        size = win.size
    );
}

impl MpiProc {
    /// MPI_Win_create (collective over `comm`): exposes `size` bytes under
    /// the process-default [`WinPolicy`].
    pub fn win_create(&self, comm: &super::Comm, size: usize) -> Arc<Window> {
        self.win_create_with_info(comm, size, &Info::new())
    }

    /// Compatibility shim for the pre-policy API: the default policy with
    /// `accumulate_ordering=none` forced on/off.
    pub fn win_create_with(
        &self,
        comm: &super::Comm,
        size: usize,
        relaxed_accumulate: bool,
    ) -> Arc<Window> {
        let policy = WinPolicy { relaxed_accumulate, ..(*self.default_win_policy).clone() };
        self.win_create_policy(comm, size, Arc::new(policy))
    }

    /// MPI_Win_create with an info argument: the window's [`WinPolicy`] is
    /// resolved from `info`'s keys over the process default (see
    /// `mpi::policy` for the vocabulary). Collective over `comm`, and —
    /// like a communicator policy — part of the wire contract: every
    /// member must pass identical info keys, since the striped ack format
    /// differs from the ordered flush-handle format.
    pub fn win_create_with_info(
        &self,
        comm: &super::Comm,
        size: usize,
        info: &Info,
    ) -> Arc<Window> {
        let policy = Arc::new(self.default_win_policy.with_info(info));
        self.win_create_policy(comm, size, policy)
    }

    fn win_create_policy(
        &self,
        comm: &super::Comm,
        size: usize,
        policy: Arc<WinPolicy>,
    ) -> Arc<Window> {
        let id = self.next_win_id.fetch_add(1, Ordering::AcqRel);
        padvance(self.backend, self.costs.instructions(300)); // win bookkeeping
        let vci = self.vcis().assign(1 << 32 | id); // distinct id-space from comms
        if !policy.striped() {
            // Ordered windows protect their lane from striped bulk, just
            // like ordered communicators (unpinned again at win_free).
            self.pin_ordered_lane(vci);
        }
        let mem = WindowMem::new(size);
        self.fabric.register_window(id, mem.clone());
        let win = Arc::new(Window {
            id,
            vci,
            size,
            mem,
            outstanding: HostMutex::new(HashMap::new()),
            get_results: HostMutex::new(HashMap::new()),
            epochs: HostMutex::new(HashMap::new()),
            next_handle: AtomicU64::new(1),
            policy,
        });
        self.windows.lock(LockClass::HostWindows).push(win.clone());
        self.barrier(comm); // collective creation
        win
    }

    /// The VCI an RMA op on `win` uses for the calling thread: normally the
    /// window's VCI; accumulates under `accumulate_ordering=none` (or any
    /// op via an endpoint) may use a thread-spread VCI.
    fn rma_vci(&self, win: &Window, spread: bool) -> usize {
        if spread && self.vcis().len() > 1 {
            1 + (thread_token() as usize) % (self.vcis().len() - 1)
        } else {
            win.vci % self.vcis().len()
        }
    }

    /// Inject one striped (ack-counted) RMA active message from stripe
    /// lane `vci_idx`: bumps the lane's issue counter for (window, target)
    /// under its own lock, injects, and records the calling thread's
    /// watermark for `win_flush`.
    fn issue_counted(&self, win: &Window, target: usize, vci_idx: usize, payload: Payload) {
        // Resolve only the LOCAL lane (failover redirect); the wire-visible
        // remote-context derivation and the lane marker in the payload stay
        // on the logical index — the receiver is healthy and its
        // envelope-derived lane must not change.
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        let wm = vci.with_state(self.guard(), |st| {
            let e = st.rma_issued.entry((win.id, target)).or_insert(0);
            *e += 1;
            let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
            self.fabric.inject(vci.ctx_index, target, dst_ctx, payload);
            *e
        });
        win.record(OpRecord::OnCount { target, lane: vci_idx, watermark: wm });
    }

    /// MPI_Put (passive target).
    pub fn put(&self, win: &Window, target: usize, offset: usize, data: &[u8]) {
        self.put_via(win, None, target, offset, data)
    }

    /// Endpoint-aware put: `ep_vci` overrides the VCI (user-visible
    /// endpoints give each thread direct VCI control — paper §5).
    pub fn put_via(
        &self,
        win: &Window,
        ep_vci: Option<usize>,
        target: usize,
        offset: usize,
        data: &[u8],
    ) {
        padvance(self.backend, self.costs.mpi_sw_rma + self.costs.instructions(8));
        check_origin_span(win, offset, data.len());
        let _cs = self.enter_cs();
        let striped = ep_vci.is_none() && win.policy.stripes_puts();
        let h = win.fresh_handle();
        let vci_idx = match ep_vci {
            Some(v) => v,
            None if striped => self.stripe_win_vci(win, target, h),
            None => self.rma_vci(win, false),
        };
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        match self.interconnect() {
            Interconnect::Ib => {
                // Hardware put: initiator-side DMA into the target window.
                // Striping only spreads which context injects; completion
                // stays a fixed NIC timestamp.
                let t = vci.with_state(self.guard(), |_st| {
                    let t = self.fabric.hw_rma_completion_time(target, data.len());
                    let mem = self.fabric.window(target, win.id);
                    mem.write(offset, data);
                    t
                });
                win.record(OpRecord::AtTime { target, at: t });
            }
            Interconnect::Opa if striped => {
                // Striped software put: fan out over the stripe lanes with
                // counted completion (see the module doc).
                self.issue_counted(win, target, vci_idx, Payload::RmaPut {
                    win: win.id,
                    offset,
                    data: data.to_vec(),
                    flush_handle: h,
                    lane: Some(vci_idx as u32),
                });
            }
            Interconnect::Opa => {
                // Ordered software put: active message to the target,
                // flush-handle completion on the window's VCI.
                vci.with_state(self.guard(), |_st| {
                    let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
                    self.fabric.inject(vci.ctx_index, target, dst_ctx, Payload::RmaPut {
                        win: win.id,
                        offset,
                        data: data.to_vec(),
                        flush_handle: h,
                        lane: None,
                    });
                });
                win.record(OpRecord::OnAck { target, flush_handle: h, vci: vci_idx });
            }
        }
    }

    /// MPI_Get (passive target). Data is available via [`MpiProc::get_data`]
    /// after the next `win_flush`.
    pub fn get(&self, win: &Window, target: usize, offset: usize, len: usize) -> GetHandle {
        self.get_via(win, None, target, offset, len)
    }

    pub fn get_via(
        &self,
        win: &Window,
        ep_vci: Option<usize>,
        target: usize,
        offset: usize,
        len: usize,
    ) -> GetHandle {
        padvance(self.backend, self.costs.mpi_sw_rma + self.costs.instructions(8));
        check_origin_span(win, offset, len);
        let _cs = self.enter_cs();
        let h = win.fresh_handle();
        let striped = ep_vci.is_none() && win.policy.stripes_gets();
        let vci_idx = match ep_vci {
            Some(v) => v,
            None if striped => self.stripe_win_vci(win, target, h),
            None => self.rma_vci(win, false),
        };
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        match self.interconnect() {
            Interconnect::Ib => {
                // Hardware get: striping only spreads which context reads;
                // completion stays a fixed NIC timestamp.
                let t = vci.with_state(self.guard(), |_st| {
                    let t = self.fabric.hw_rma_completion_time(target, len);
                    let mem = self.fabric.window(target, win.id);
                    let data = mem.read(offset, len);
                    win.get_results.lock(LockClass::HostRmaResults).insert(h, data);
                    t
                });
                win.record(OpRecord::AtTime { target, at: t });
            }
            Interconnect::Opa if striped => {
                // Striped software get: fan out over the stripe lanes with
                // counted completion, exactly like puts — the reply echoes
                // the issuing lane, bumps that lane's per-(window, target)
                // ack counter, and parks the data under the get handle.
                self.issue_counted(win, target, vci_idx, Payload::RmaGetReq {
                    win: win.id,
                    offset,
                    len,
                    get_handle: h,
                    lane: Some(vci_idx as u32),
                });
            }
            Interconnect::Opa => {
                vci.with_state(self.guard(), |_st| {
                    let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
                    self.fabric.inject(vci.ctx_index, target, dst_ctx, Payload::RmaGetReq {
                        win: win.id,
                        offset,
                        len,
                        get_handle: h,
                        lane: None,
                    });
                });
                win.record(OpRecord::OnAck { target, flush_handle: h, vci: vci_idx });
            }
        }
        GetHandle(h, vci_idx)
    }

    /// MPI_Accumulate. Active-message path on both interconnects. Routing
    /// follows the window's policy (module-doc decision table): ordered
    /// windows funnel through the window's VCI (`accumulate_ordering=none`
    /// without striping thread-spreads, §6.3); striped windows with
    /// relaxed ordering fan a *single* thread's accumulates across the
    /// stripe lanes with counted completion. An endpoint VCI overrides.
    pub fn accumulate(
        &self,
        win: &Window,
        target: usize,
        offset: usize,
        data: &[u8],
        op: AccOp,
    ) {
        self.accumulate_via(win, None, target, offset, data, op)
    }

    pub fn accumulate_via(
        &self,
        win: &Window,
        ep_vci: Option<usize>,
        target: usize,
        offset: usize,
        data: &[u8],
        op: AccOp,
    ) {
        padvance(self.backend, self.costs.mpi_sw_rma + self.costs.instructions(8));
        check_origin_span(win, offset, data.len());
        let _cs = self.enter_cs();
        let striped = ep_vci.is_none() && win.policy.stripes_accumulates();
        let h = win.fresh_handle();
        let vci_idx = match ep_vci {
            Some(v) => v,
            None if striped => self.stripe_win_vci(win, target, h),
            None => self.rma_vci(win, win.policy.relaxed_accumulate),
        };
        if striped {
            self.issue_counted(win, target, vci_idx, Payload::RmaAcc {
                win: win.id,
                offset,
                data: data.to_vec(),
                op,
                flush_handle: h,
                lane: Some(vci_idx as u32),
            });
            return;
        }
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        vci.with_state(self.guard(), |_st| {
            let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
            self.fabric.inject(vci.ctx_index, target, dst_ctx, Payload::RmaAcc {
                win: win.id,
                offset,
                data: data.to_vec(),
                op,
                flush_handle: h,
                lane: None,
            });
        });
        win.record(OpRecord::OnAck { target, flush_handle: h, vci: vci_idx });
    }

    /// MPI_Fetch_and_op on a u64/f64 cell; blocking (fetch + flush fused,
    /// as the BSPMM work-counter idiom uses it).
    pub fn fetch_and_op(
        &self,
        win: &Window,
        target: usize,
        offset: usize,
        operand: &[u8],
        op: AccOp,
    ) -> Vec<u8> {
        padvance(self.backend, self.costs.mpi_sw_rma + self.costs.instructions(8));
        // Sum* fetch-ops read a full 8-byte cell regardless of operand span.
        check_origin_span(win, offset, match op {
            AccOp::Replace => operand.len(),
            _ => operand.len().max(8),
        });
        let vci_idx = self.rma_vci(win, false);
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        let h = win.fresh_handle();
        {
            let _cs = self.enter_cs();
            vci.with_state(self.guard(), |_st| {
                let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
                self.fabric.inject(vci.ctx_index, target, dst_ctx, Payload::RmaFetchOp {
                    win: win.id,
                    offset,
                    operand: operand.to_vec(),
                    op,
                    fetch_handle: h,
                });
            });
        }
        // Wait for the reply on this VCI (re-resolving the lane each
        // iteration: a failover mid-wait migrates `fetch_done` entries to
        // the survivor).
        let deadline = SpinDeadline::new(self.backend);
        loop {
            let got = {
                let _cs = self.enter_cs();
                let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
                vci.with_state(self.guard(), |st| st.fetch_done.remove(&h))
            };
            if let Some(data) = got {
                return data;
            }
            deadline.check(|| {
                format!(
                    "fetch_and_op reply (window {}, target {target}, lane {vci_idx}, \
                     fetch handle {h})",
                    win.id
                )
            });
            self.progress_for_request(vci_idx);
        }
    }

    /// MPI_Win_flush (all targets): wait for completion of all RMA ops the
    /// calling thread issued on `win`.
    pub fn win_flush(&self, win: &Window) {
        padvance(self.backend, self.costs.instructions(20));
        self.flush_records(win, None);
    }

    /// MPI_Win_flush_local: wait only for *local* completion of the
    /// calling thread's outstanding ops — origin buffers reusable, nothing
    /// guaranteed at the target. In this model an op's payload is captured
    /// at injection (and an IB op's source is read before its NIC
    /// timestamp is recorded), so local completion is already true the
    /// moment initiation returns: flush_local charges its bookkeeping cost
    /// and leaves every record in place for the next `win_flush` /
    /// `win_unlock` to complete remotely.
    pub fn win_flush_local(&self, win: &Window) {
        padvance(self.backend, self.costs.instructions(10));
        // Touch the calling thread's record list so an erroneous handle
        // still trips the HostMutex discipline in instrumented builds.
        let _pending = {
            let t = win.outstanding.lock(LockClass::HostRmaOutstanding);
            t.get(&thread_token()).map_or(0, Vec::len)
        };
    }

    /// The flush/unlock wait engine: drain and complete the calling
    /// thread's outstanding records on `win` — all of them (`None`, a
    /// flush) or only those to one target (`Some`, the completion half of
    /// `win_unlock`).
    fn flush_records(&self, win: &Window, only_target: Option<usize>) {
        let mine = {
            let mut t = win.outstanding.lock(LockClass::HostRmaOutstanding);
            match only_target {
                None => t.remove(&thread_token()).unwrap_or_default(),
                Some(tg) => {
                    let recs = t.entry(thread_token()).or_default();
                    let (mine, keep) = recs.drain(..).partition(|c| c.target() == tg);
                    *recs = keep;
                    mine
                }
            }
        };
        // Striped ops coalesce into one watermark per (target, lane): the
        // counters are monotone, so only the highest watermark per lane
        // matters — this is where "issued == acked per lane" replaces
        // per-op flush handles.
        let mut counted: HashMap<(usize, usize), u64> = HashMap::new();
        for c in &mine {
            if let OpRecord::OnCount { target, lane, watermark } = c {
                let e = counted.entry((*target, *lane)).or_insert(0);
                *e = (*e).max(*watermark);
            }
        }
        for c in mine {
            match c {
                OpRecord::OnCount { .. } => {} // waited below, coalesced
                OpRecord::AtTime { at, .. } => {
                    // Hardware completion: just wait out the NIC.
                    while pnow(self.backend) < at {
                        padvance(self.backend, self.costs.poll_empty);
                        self.relax();
                        if self.backend == crate::platform::Backend::Native {
                            break; // wallclock has passed in practice
                        }
                    }
                }
                OpRecord::OnAck { target, flush_handle, vci } => {
                    // Software completion: needs progress (ours and the
                    // target's). This is where OPA's shared-progress pain
                    // lives (Figs. 13-16, 24-25). The lane is re-resolved
                    // each iteration: a failover mid-wait migrates the
                    // `acked`/`get_done` entries to the survivor.
                    let deadline = SpinDeadline::new(self.backend);
                    loop {
                        let acked = {
                            let _cs = self.enter_cs();
                            let v = self.vcis().get(self.vcis().resolve(vci)).clone();
                            v.with_state(self.guard(), |st| {
                                // Puts/accs complete via RmaAck; gets via
                                // their parked RmaGetReply (consumed later
                                // by get_data, so only peek).
                                st.acked.remove(&flush_handle)
                                    || st.get_done.contains_key(&flush_handle)
                            })
                        };
                        if acked {
                            break;
                        }
                        deadline.check(|| {
                            format!(
                                "win_flush ack (window {}, target {target}, lane {vci}, \
                                 flush handle {flush_handle})",
                                win.id
                            )
                        });
                        self.progress_for_request(vci);
                    }
                }
            }
        }
        // Striped completion: wait each recorded lane up to its watermark.
        // The check reads the lane's OWN state (per-lane replicated
        // counters — no single VCI funnels every flush), and progress
        // sweeps the stripe lanes (doorbell-gated per the window policy)
        // since acks for the remaining lanes drain concurrently.
        for ((target, lane), watermark) in counted {
            let deadline = SpinDeadline::new(self.backend);
            loop {
                let acked = {
                    let _cs = self.enter_cs();
                    let v = self.vcis().get(self.vcis().resolve(lane)).clone();
                    v.with_state(self.guard(), |st| {
                        st.rma_acked.get(&(win.id, target)).copied().unwrap_or(0)
                    })
                };
                if acked >= watermark {
                    break;
                }
                deadline.check(|| {
                    format!(
                        "striped flush watermark (window {}, target {target}, lane {lane}, \
                         acked {acked} < watermark {watermark})",
                        win.id
                    )
                });
                self.progress_with(lane, true, win.policy.rx_doorbell);
            }
        }
    }

    /// MPI_Win_lock: open a passive-target epoch of `kind` to `target`.
    /// Blocks until the lock is granted (see the module doc's protocol
    /// table: OPA wire protocol with a target FIFO queue, IB NIC atomics,
    /// or a local no-op grant under `mpi_assert_no_locks`).
    pub fn win_lock(&self, win: &Window, kind: LockKind, target: usize) {
        padvance(self.backend, self.costs.instructions(30));
        assert!(target < self.nprocs(), "win_lock target {target} out of range");
        self.lock_one(win, kind, target, false);
    }

    /// MPI_Win_lock_all: shared epochs to every rank at once. OPA issues
    /// every lock request before waiting any grant, so the acquisition
    /// round trips overlap.
    pub fn win_lock_all(&self, win: &Window) {
        padvance(self.backend, self.costs.instructions(30));
        let n = self.nprocs();
        let elided = win.policy.no_locks;
        {
            let mut e = win.epochs.lock(LockClass::HostRmaEpochs);
            assert!(
                e.is_empty(),
                "erroneous program: win_lock_all on window {} with {} epoch(s) already open",
                win.id,
                e.len()
            );
            for target in 0..n {
                e.insert(target, LockEpoch { kind: LockKind::Shared, elided, all: true });
            }
        }
        if elided {
            self.lock_elisions.fetch_add(n as u64, Ordering::Relaxed);
            return;
        }
        self.lock_wire_reqs.fetch_add(n as u64, Ordering::Relaxed);
        match self.interconnect() {
            Interconnect::Ib => {
                for target in 0..n {
                    self.ib_acquire(win, LockKind::Shared, target);
                }
            }
            Interconnect::Opa => {
                let vci_idx = self.rma_vci(win, false);
                let handles: Vec<u64> = (0..n)
                    .map(|target| self.send_lock_req(win, LockKind::Shared, target, vci_idx))
                    .collect();
                for h in handles {
                    self.wait_grant(win, vci_idx, h);
                }
            }
        }
    }

    /// MPI_Win_unlock: complete the calling thread's outstanding ops to
    /// `target` (the same per-lane watermark / flush-handle / NIC-time
    /// waits a flush performs, filtered to that target), then release the
    /// target-side lock and block until the epoch is closed there.
    pub fn win_unlock(&self, win: &Window, target: usize) {
        padvance(self.backend, self.costs.instructions(30));
        let ep = {
            let e = win.epochs.lock(LockClass::HostRmaEpochs);
            *e.get(&target).unwrap_or_else(|| {
                panic!(
                    "erroneous program: win_unlock on window {} target {target} \
                     without a matching win_lock",
                    win.id
                )
            })
        };
        assert!(
            !ep.all,
            "erroneous program: epoch on window {} target {target} was opened by \
             win_lock_all — close it with win_unlock_all",
            win.id
        );
        self.flush_records(win, Some(target));
        self.release_one(win, target, ep);
        win.epochs.lock(LockClass::HostRmaEpochs).remove(&target);
    }

    /// MPI_Win_unlock_all: complete ALL of the calling thread's
    /// outstanding ops on `win` (a full flush), then release every rank's
    /// lock. OPA sends every unlock before waiting any ack.
    pub fn win_unlock_all(&self, win: &Window) {
        padvance(self.backend, self.costs.instructions(30));
        let eps: Vec<(usize, LockEpoch)> = {
            let e = win.epochs.lock(LockClass::HostRmaEpochs);
            assert!(
                !e.is_empty() && e.values().all(|ep| ep.all),
                "erroneous program: win_unlock_all on window {} without win_lock_all",
                win.id
            );
            e.iter().map(|(t, ep)| (*t, *ep)).collect()
        };
        self.flush_records(win, None);
        if eps.iter().all(|(_, ep)| ep.elided) {
            win.epochs.lock(LockClass::HostRmaEpochs).clear();
            return;
        }
        match self.interconnect() {
            Interconnect::Ib => {
                for (target, ep) in &eps {
                    self.fabric
                        .win_lock_word(*target, win.id)
                        .release(ep.kind == LockKind::Exclusive);
                }
            }
            Interconnect::Opa => {
                let vci_idx = self.rma_vci(win, false);
                let handles: Vec<u64> = eps
                    .iter()
                    .map(|(target, ep)| self.send_unlock(win, ep.kind, *target, vci_idx))
                    .collect();
                for h in handles {
                    self.wait_unlock_ack(vci_idx, h);
                }
            }
        }
        win.epochs.lock(LockClass::HostRmaEpochs).clear();
    }

    /// The single-target acquisition path shared by `win_lock`.
    fn lock_one(&self, win: &Window, kind: LockKind, target: usize, all: bool) {
        let elided = win.policy.no_locks;
        {
            let mut e = win.epochs.lock(LockClass::HostRmaEpochs);
            assert!(
                !e.contains_key(&target),
                "erroneous program: win_lock on window {} target {target} with an \
                 epoch already open (one lock epoch per (window, target) per process)",
                win.id
            );
            e.insert(target, LockEpoch { kind, elided, all });
        }
        if elided {
            // mpi_assert_no_locks: the protocol collapses to a local
            // no-op grant — zero wire traffic, zero NIC atomics. The
            // `no_locks_over_locked` bench gate measures exactly this.
            self.lock_elisions.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.lock_wire_reqs.fetch_add(1, Ordering::Relaxed);
        match self.interconnect() {
            Interconnect::Ib => self.ib_acquire(win, kind, target),
            Interconnect::Opa => {
                let vci_idx = self.rma_vci(win, false);
                let h = self.send_lock_req(win, kind, target, vci_idx);
                self.wait_grant(win, vci_idx, h);
            }
        }
    }

    /// IB acquisition: NIC-atomic attempts on the target's registered
    /// lock word, each costing an atomic round trip. Shared is the fast
    /// path (first attempt succeeds unless an exclusive holder is
    /// present); exclusive retries until the word frees up, progressing
    /// between attempts so this origin's own service work keeps moving.
    fn ib_acquire(&self, win: &Window, kind: LockKind, target: usize) {
        let word = self.fabric.win_lock_word(target, win.id);
        let exclusive = kind == LockKind::Exclusive;
        let deadline = SpinDeadline::new(self.backend);
        loop {
            let t = self.fabric.hw_rma_completion_time(target, 8);
            while pnow(self.backend) < t {
                padvance(self.backend, self.costs.poll_empty);
                self.relax();
                if self.backend == crate::platform::Backend::Native {
                    break;
                }
            }
            if word.try_acquire(exclusive) {
                return;
            }
            deadline.check(|| {
                format!(
                    "IB {} lock acquisition (window {}, target {target})",
                    if exclusive { "exclusive" } else { "shared" },
                    win.id
                )
            });
            self.progress_for_request(self.rma_vci(win, false));
        }
    }

    /// OPA: inject one `RmaLockReq` on the window's home VCI and return
    /// the grant handle to wait on.
    fn send_lock_req(&self, win: &Window, kind: LockKind, target: usize, vci_idx: usize) -> u64 {
        let h = win.fresh_handle();
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        let _cs = self.enter_cs();
        vci.with_state(self.guard(), |_st| {
            let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
            self.fabric.inject(vci.ctx_index, target, dst_ctx, Payload::RmaLockReq {
                win: win.id,
                kind,
                handle: h,
            });
        });
        h
    }

    /// Wait for a lock grant to land in the issuing VCI's `lock_granted`
    /// set (the same blocking-wait shape as `fetch_and_op`).
    fn wait_grant(&self, win: &Window, vci_idx: usize, h: u64) {
        let deadline = SpinDeadline::new(self.backend);
        loop {
            let granted = {
                let _cs = self.enter_cs();
                let v = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
                v.with_state(self.guard(), |st| st.lock_granted.remove(&h))
            };
            if granted {
                return;
            }
            deadline.check(|| {
                format!(
                    "win_lock grant (window {}, lane {vci_idx}, grant handle {h})",
                    win.id
                )
            });
            self.progress_with(vci_idx, win.policy.striped(), win.policy.rx_doorbell);
        }
    }

    /// Release one target's lock per the epoch's protocol (the completion
    /// half — `flush_records` — has already run).
    fn release_one(&self, win: &Window, target: usize, ep: LockEpoch) {
        if ep.elided {
            return;
        }
        match self.interconnect() {
            Interconnect::Ib => {
                // One NIC-atomic release; charge the atomic's round trip.
                let t = self.fabric.hw_rma_completion_time(target, 8);
                self.fabric.win_lock_word(target, win.id).release(ep.kind == LockKind::Exclusive);
                while pnow(self.backend) < t {
                    padvance(self.backend, self.costs.poll_empty);
                    self.relax();
                    if self.backend == crate::platform::Backend::Native {
                        break;
                    }
                }
            }
            Interconnect::Opa => {
                let vci_idx = self.rma_vci(win, false);
                let h = self.send_unlock(win, ep.kind, target, vci_idx);
                self.wait_unlock_ack(vci_idx, h);
            }
        }
    }

    /// OPA: inject one `RmaUnlock` and return the ack handle to wait on.
    fn send_unlock(&self, win: &Window, kind: LockKind, target: usize, vci_idx: usize) -> u64 {
        let h = win.fresh_handle();
        let vci = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
        let _cs = self.enter_cs();
        vci.with_state(self.guard(), |_st| {
            let dst_ctx = self.remote_ctx_for_vci(target, vci_idx);
            self.fabric.inject(vci.ctx_index, target, dst_ctx, Payload::RmaUnlock {
                win: win.id,
                kind,
                handle: h,
            });
        });
        h
    }

    /// Wait the target's `RmaAck` for an unlock (it lands in the issuing
    /// VCI's `acked` set, like an ordered flush handle).
    fn wait_unlock_ack(&self, vci_idx: usize, h: u64) {
        let deadline = SpinDeadline::new(self.backend);
        loop {
            let acked = {
                let _cs = self.enter_cs();
                let v = self.vcis().get(self.vcis().resolve(vci_idx)).clone();
                v.with_state(self.guard(), |st| st.acked.remove(&h))
            };
            if acked {
                return;
            }
            deadline.check(|| format!("win_unlock ack (lane {vci_idx}, ack handle {h})"));
            self.progress_for_request(vci_idx);
        }
    }

    /// Retrieve MPI_Get data after a flush.
    pub fn get_data(&self, win: &Window, h: GetHandle) -> Vec<u8> {
        if let Some(d) = win.get_results.lock(LockClass::HostRmaResults).remove(&h.0) {
            return d;
        }
        // OPA path: the reply was parked in the issuing VCI's state (or
        // migrated to the survivor if the issuing lane failed over).
        let vci = self.vcis().get(self.vcis().resolve(h.1)).clone();
        let _cs = self.enter_cs();
        vci.with_state(self.guard(), |st| {
            st.get_done.remove(&h.0).expect("get_data before flush completed")
        })
    }

    /// MPI_Win_free (collective): flush, then a barrier during which the
    /// caller keeps progressing the window's VCI — the behavior behind the
    /// paper's Fig. 15 ("parallel Win_free restores progress"). Tears the
    /// per-window policy state down: the ordered-lane pin and every VCI's
    /// striped-completion counters for this window.
    /// Freeing a window with a passive-target epoch still open — or a
    /// lock grant still in flight, which also holds its `epochs` entry —
    /// is erroneous and fails loudly here (the freed-communicator-style
    /// tripwire), as does freeing while this rank's *exposed* side still
    /// has holders or queued waiters.
    pub fn win_free(&self, comm: &super::Comm, win: Arc<Window>) {
        {
            let e = win.epochs.lock(LockClass::HostRmaEpochs);
            assert!(
                e.is_empty(),
                "erroneous program: win_free on window {} with {} open passive-target epoch(s) \
                 (win_unlock / win_unlock_all them first)",
                win.id,
                e.len()
            );
        }
        self.win_flush(&win);
        self.barrier_progressing(comm, Some(win.vci % self.vcis().len()));
        // After the collective point every rank has passed its origin-side
        // epoch assert, so a non-idle target-side table means a rogue
        // origin raced the free — fail loudly rather than deregister under
        // a holder.
        {
            let mut t = self.win_locks.lock(LockClass::HostWinLocks);
            if let Some(table) = t.remove(&win.id) {
                assert!(
                    table.is_idle(),
                    "erroneous program: win_free on window {} while its exposed side still has \
                     passive-target lock holders or queued waiters",
                    win.id
                );
            }
        }
        if let Some(word) = self.fabric.find_win_lock(self.rank(), win.id) {
            assert!(
                word.is_idle(),
                "erroneous program: win_free on window {} while its hardware lock word is held",
                win.id
            );
        }
        self.fabric.deregister_window(win.id);
        if !win.policy.striped() {
            self.unpin_ordered_lane(win.vci);
        }
        self.purge_rma_counters(win.id);
        self.vcis().release(win.vci);
        let mut t = self.windows.lock(LockClass::HostWindows);
        t.retain(|w| w.id != win.id);
    }
}

impl MpiProc {
    /// Remote context index corresponding to local VCI `vci_idx` (symmetric
    /// pools; reduced modulo the remote pool size).
    pub(super) fn remote_ctx_for_vci(&self, target: usize, vci_idx: usize) -> usize {
        let remote = self.fabric.open_count(target).max(1);
        vci_idx % remote
    }
}
