//! Per-communicator sharded matching for striped traffic, with the
//! wildcard-**epoch** protocol for `MPI_ANY_SOURCE`.
//!
//! PR 1's striping spread *injection* across the VCI pool but re-routed
//! every striped arrival back to the communicator's home VCI, whose single
//! matching engine re-serialized the receive side (exactly the hidden
//! serialization the "Lessons Learned on MPI+Threads Communication" paper
//! blames for residual slowdowns). This module shards the matching engine
//! itself: each communicator owns a small power-of-two array of
//! [`MatchingState`] shards, and each `(comm, source rank)` stream is
//! owned by exactly one shard — `shard(hash(comm, src))`. A striped
//! envelope is matched *on the VCI that polled it* by taking only the
//! owning shard's lock; posted receives with a concrete source go to the
//! same shard. Per-stream nonovertaking holds because a stream never
//! spans shards; cross-stream order is not MPI-visible.
//!
//! # Wildcard epochs
//!
//! `MPI_ANY_SOURCE` must consider every source, so it cannot live in one
//! shard. Posting a wildcard receive flips the communicator into a
//! **serialized epoch**:
//!
//!  1. the poster takes every shard lock (in index order), sets the
//!     `serialized` flag, and drains shards 1..n into shard 0 (the *home
//!     shard*) — per-stream queue order and reorder-stage continuity are
//!     preserved because each stream lives wholly in one shard;
//!  2. while serialized, every arrival and every post routes to the home
//!     shard (lock-free flag read, double-checked under the shard lock),
//!     so wildcard matching sees one engine, like a single VCI would;
//!  3. when the last pending wildcard completes (plus an optional
//!     [`MpiConfig::wildcard_epoch_linger`] hysteresis of further
//!     operations — arrivals or concrete posts), the state is split back
//!     out by source and the flag clears.
//!
//! The hysteresis is operation-counted, so a communicator that goes
//! *idle* right after its last wildcard stays (harmlessly) serialized
//! until `linger` further operations arrive: an idle epoch costs nothing,
//! and traffic that resumes pays at most `linger` serialized operations
//! before sharding resumes. Benchmarks asserting full epoch resolution at
//! quiescence should use `linger = 0`.
//!
//! When no wildcard is pending the only cost over plain sharding is one
//! atomic flag load per operation. A communicator configured with a
//! single shard (`match_shards = 1`) degenerates to PR 1's one-engine
//! behavior and never needs epochs: the home shard *is* the only shard.
//!
//! Lock order: a VCI lock may be held when taking a shard lock (the
//! progress path polls under the VCI lock), shard locks are taken in
//! index order during transitions, and the epoch control lock is taken
//! only while no shard lock is held. No path takes a VCI lock while
//! holding a shard lock, so the discipline is acyclic.
//!
//! # Engine retirement (policy adoption)
//!
//! When a communicator's registration replaces a lazily built engine
//! (the striped arrival raced the creating call), the old engine is
//! **retired** under all of its shard locks — the same stop-the-world
//! pattern as an epoch flip — after the table entry has been swapped to
//! the successor. An operation still holding the old handle observes the
//! `retired` flag under its shard lock, gets its operand handed back
//! (`Err` from [`CommMatch::striped_arrival`] / [`CommMatch::post`]),
//! and retries via the engine table. See [`CommMatch::retire_into`].
//!
//! Robustness note: a striped envelope with an unknown `comm_id` cannot
//! be told apart from one whose communicator the receiver is about to
//! create (comm creation is symmetric but unsynchronized), so it lazily
//! allocates an engine and queues as unexpected rather than being
//! dropped — the same bounded-by-the-sender growth the per-VCI
//! unexpected queues always had for forged envelopes. Control-message
//! forgeries (stale CTS/DATA/acks, bad RMA handles) are still dropped
//! and counted by the progress engine.
//!
//! [`MpiConfig::wildcard_epoch_linger`]: super::config::MpiConfig::wildcard_epoch_linger

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::platform::{Backend, PMutex, PMutexGuard};

use super::instrument::{self, LockClass};
use super::matching::{MatchingState, PostedRecv, Src, UnexpectedMsg};

/// Index of the home shard (wildcard-epoch serialization target).
const HOME_SHARD: usize = 0;

/// Which shard (of `mask + 1`, a power of two) owns the `(comm, src)`
/// stream outside a wildcard epoch. A free function so the shard-anchored
/// request-allocation path (`mpi::p2p`) can compute the owning shard from
/// a communicator's policy alone, without resolving the engine first.
pub(crate) fn shard_index(comm_id: u64, src_rank: usize, mask: usize) -> usize {
    let z = (src_rank as u64).wrapping_add(comm_id.wrapping_mul(0x9E3779B97F4A7C15));
    (crate::util::mix64(z) as usize) & mask
}

/// Wildcard-epoch bookkeeping (taken only with no shard lock held).
struct EpochCtl {
    /// Posted-but-unmatched `MPI_ANY_SOURCE` receives.
    pending_wildcards: u64,
    /// Arrivals left to absorb before flipping back (hysteresis).
    linger_left: u32,
}

/// Counters a sharded communicator accumulates (see
/// [`CommMatch::epoch_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EpochStats {
    /// Flips into the serialized wildcard epoch.
    pub flips: u64,
    /// Flips back to sharded matching.
    pub unflips: u64,
    /// Wildcard receives posted.
    pub wildcard_posts: u64,
}

/// The sharded matching engine of one communicator.
pub struct CommMatch {
    comm_id: u64,
    shards: Vec<PMutex<MatchingState>>,
    /// `shards.len() - 1`; shard count is a power of two.
    mask: usize,
    /// Are we inside a serialized wildcard epoch? Read lock-free on every
    /// routing decision; written only with all shard locks held.
    serialized: AtomicBool,
    /// Has a policy adoption retired this engine? Written only with all
    /// shard locks held (like `serialized`), so a single shard lock is
    /// enough to observe it; a retired engine's queues were drained into
    /// its successor and every operation on it must retry via the engine
    /// table. See [`CommMatch::retire_into`].
    retired: AtomicBool,
    /// Epoch bookkeeping. A `PMutex`, NOT a host mutex: it is held across
    /// shard-lock acquisition during transitions, and in the DES parking
    /// on a virtual-time lock while holding a host mutex would deadlock
    /// the scheduler at the host level.
    ctl: PMutex<EpochCtl>,
    linger: u32,
    flips: AtomicU64,
    unflips: AtomicU64,
    wildcard_posts: AtomicU64,
}

impl CommMatch {
    /// Build the engine with `shards` shards (rounded up to a power of
    /// two, min 1).
    pub fn new(backend: Backend, comm_id: u64, shards: usize, linger: u32) -> Arc<Self> {
        let n = shards.max(1).next_power_of_two();
        Arc::new(CommMatch {
            comm_id,
            shards: (0..n).map(|_| PMutex::new(backend, MatchingState::new())).collect(),
            mask: n - 1,
            serialized: AtomicBool::new(false),
            retired: AtomicBool::new(false),
            ctl: PMutex::new(backend, EpochCtl { pending_wildcards: 0, linger_left: 0 }),
            linger,
            flips: AtomicU64::new(0),
            unflips: AtomicU64::new(0),
            wildcard_posts: AtomicU64::new(0),
        })
    }

    pub fn comm_id(&self) -> u64 {
        self.comm_id
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The wildcard-epoch linger this engine was built with (per-comm
    /// policy adoption compares it against the registered policy).
    pub(crate) fn linger(&self) -> u32 {
        self.linger
    }

    /// Which shard owns the `(comm, src)` stream outside an epoch.
    fn shard_of(&self, src_rank: usize) -> usize {
        shard_index(self.comm_id, src_rank, self.mask)
    }

    /// Stop-the-world retirement (policy adoption): with EVERY shard lock
    /// held in index order — the wildcard-epoch pattern — mark this engine
    /// retired and drain its queues, then re-bucket them into `fresh` by
    /// the successor's shard map. Streams move whole, so per-stream queue
    /// order and reorder-stage seq continuity are preserved.
    ///
    /// Setting the flag under all shard locks makes a single-shard-lock
    /// double-check authoritative: an in-flight operation that raced the
    /// engine-table swap either finished depositing before the drain (its
    /// state migrates with everything else) or observes `retired` under
    /// its shard lock, gets its operand handed back, and retries via the
    /// table — which has resolved the successor since before the drain
    /// began. Two live engines can therefore never hold parts of the same
    /// stream, which is what the old remove/rebuild/reinsert adoption
    /// could not guarantee.
    ///
    /// Adoption runs during communicator registration, before the
    /// creating call returns the `Comm` handle, so no receive — in
    /// particular no wildcard — has been posted yet: the engine cannot be
    /// inside a serialized epoch.
    pub(crate) fn retire_into(&self, fresh: &CommMatch) {
        debug_assert_eq!(self.comm_id, fresh.comm_id, "engine migration across comms");
        debug_assert!(!self.is_serialized(), "retiring an engine mid wildcard epoch");
        let parts: Vec<_> = {
            let mut guards: Vec<PMutexGuard<'_, MatchingState>> =
                (0..self.shards.len()).map(|i| self.lock_shard(i)).collect();
            self.retired.store(true, Ordering::Release);
            guards.iter_mut().map(|g| g.take_parts()).collect()
        };
        for p in parts {
            let buckets = p.split_by_source(fresh.shards.len(), |src| fresh.shard_of(src));
            for (idx, bucket) in buckets.into_iter().enumerate() {
                let mut guard = fresh.lock_shard(idx);
                guard.absorb_parts(bucket);
            }
        }
    }

    /// Has a policy adoption retired this engine? Test aid — the hot
    /// paths read the flag under their shard lock, not here.
    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    fn lock_shard(&self, idx: usize) -> PMutexGuard<'_, MatchingState> {
        self.shards[idx].lock_ordinal(LockClass::Shard, idx as u32)
    }

    /// Lock the shard that owns operations for `src_rank` *right now*,
    /// honoring the epoch: the mode flag is read lock-free, the shard
    /// locked, and the flag re-checked — a transition that raced us holds
    /// (or waits for) every shard lock, so a stale pick is always
    /// detected and retried. `None` means the engine was retired by a
    /// policy adoption (flag set under every shard lock, so this shard's
    /// lock suffices to observe it): the caller must re-resolve the
    /// engine from the table and retry there.
    fn route_lock(&self, src_rank: usize) -> Option<PMutexGuard<'_, MatchingState>> {
        loop {
            let serialized = self.serialized.load(Ordering::Acquire);
            let idx = if serialized { HOME_SHARD } else { self.shard_of(src_rank) };
            let guard = self.lock_shard(idx);
            if self.retired.load(Ordering::Acquire) {
                return None;
            }
            if self.serialized.load(Ordering::Acquire) == serialized {
                return Some(guard);
            }
            drop(guard);
        }
    }

    /// A striped envelope arrived (on whatever VCI polled it): run the
    /// owning shard's reorder stage + matching. The returned pairs are
    /// consumed by the caller *after* this returns (no shard lock held);
    /// the caller must then report them via [`CommMatch::note_arrival`].
    /// `Err` hands the message back: the engine was retired by a policy
    /// adoption and the caller must retry via the engine table.
    pub fn striped_arrival(
        &self,
        msg: UnexpectedMsg,
    ) -> Result<Vec<(PostedRecv, UnexpectedMsg)>, UnexpectedMsg> {
        debug_assert_eq!(msg.comm_id, self.comm_id);
        match self.route_lock(msg.src_rank) {
            Some(mut guard) => Ok(guard.on_striped_arrival(msg)),
            None => Err(msg),
        }
    }

    /// Post a receive. Concrete sources go to their owning shard;
    /// `MPI_ANY_SOURCE` enters (or extends) the serialized wildcard epoch
    /// before posting to the home shard. An immediately matched wildcard
    /// is accounted here; a match returned for a *wildcard* receive from a
    /// later arrival must be reported via [`CommMatch::note_arrival`].
    /// `Err` hands the receive back: the engine was retired by a policy
    /// adoption and the caller must retry via the engine table.
    pub fn post(&self, recv: PostedRecv) -> Result<Option<UnexpectedMsg>, PostedRecv> {
        debug_assert_eq!(recv.comm_id, self.comm_id);
        match recv.src {
            Src::Rank(src) => {
                let matched = match self.route_lock(src) {
                    Some(mut guard) => guard.on_post(recv),
                    None => return Err(recv),
                };
                // Concrete posts also tick the linger hysteresis (cheap
                // flag load outside an epoch; see `linger_tick`).
                if self.shards.len() > 1 && self.serialized.load(Ordering::Acquire) {
                    self.linger_tick();
                }
                Ok(matched)
            }
            Src::Any => {
                if self.retired.load(Ordering::Acquire) {
                    return Err(recv);
                }
                self.wildcard_posts.fetch_add(1, Ordering::Relaxed);
                instrument::record_wildcard_post();
                if self.shards.len() > 1 {
                    let mut ctl = self.ctl.lock_class(LockClass::EpochCtl);
                    ctl.pending_wildcards += 1;
                    if !self.serialized.load(Ordering::Acquire) {
                        self.flip_to_serialized();
                    }
                    // From here until this wildcard matches, pending >= 1,
                    // so no flip-back can race the post below.
                }
                let matched = {
                    let mut guard = self.lock_shard(HOME_SHARD);
                    if self.retired.load(Ordering::Acquire) {
                        // Raced the retirement (cannot happen through the
                        // MPI surface — adoption precedes the first post —
                        // but the protocol stays safe anyway): undo the
                        // epoch accounting on the abandoned engine and
                        // hand the receive back for a retry.
                        drop(guard);
                        if self.shards.len() > 1 {
                            let mut ctl = self.ctl.lock_class(LockClass::EpochCtl);
                            ctl.pending_wildcards -= 1;
                        }
                        return Err(recv);
                    }
                    guard.on_post(recv)
                };
                if matched.is_some() {
                    // Matched straight out of the unexpected queue: the
                    // wildcard is already complete.
                    self.wildcard_done(1);
                }
                Ok(matched)
            }
        }
    }

    /// Report the outcome of consuming one striped arrival:
    /// `matched_wildcards` of the returned pairs bound to `MPI_ANY_SOURCE`
    /// receives. Ticks the epoch state machine (pending count, linger,
    /// flip-back). Must be called with no shard lock held.
    pub fn note_arrival(&self, matched_wildcards: u64) {
        if self.shards.len() == 1 {
            return; // single-shard engines never enter an epoch
        }
        if !self.serialized.load(Ordering::Acquire) {
            debug_assert_eq!(
                matched_wildcards, 0,
                "wildcard matched outside a serialized epoch"
            );
            return;
        }
        if matched_wildcards > 0 {
            self.wildcard_done(matched_wildcards);
        } else {
            self.linger_tick();
        }
    }

    fn wildcard_done(&self, n: u64) {
        if self.shards.len() == 1 {
            return; // single-shard engines never entered an epoch
        }
        let mut ctl = self.ctl.lock_class(LockClass::EpochCtl);
        debug_assert!(ctl.pending_wildcards >= n, "wildcard accounting underflow");
        ctl.pending_wildcards = ctl.pending_wildcards.saturating_sub(n);
        if ctl.pending_wildcards == 0 {
            ctl.linger_left = self.linger;
            if ctl.linger_left == 0 {
                self.flip_back();
            }
        }
    }

    fn linger_tick(&self) {
        if self.shards.len() == 1 {
            return;
        }
        let mut ctl = self.ctl.lock_class(LockClass::EpochCtl);
        if ctl.pending_wildcards > 0 || !self.serialized.load(Ordering::Acquire) {
            return;
        }
        ctl.linger_left = ctl.linger_left.saturating_sub(1);
        if ctl.linger_left == 0 {
            self.flip_back();
        }
    }

    /// Enter the serialized epoch: with every shard lock held (index
    /// order), set the flag and drain shards 1..n into the home shard.
    /// Caller holds the epoch control lock.
    fn flip_to_serialized(&self) {
        self.flips.fetch_add(1, Ordering::Relaxed);
        instrument::record_epoch_flip();
        let mut guards: Vec<PMutexGuard<'_, MatchingState>> =
            (0..self.shards.len()).map(|i| self.lock_shard(i)).collect();
        self.serialized.store(true, Ordering::Release);
        let (home, rest) = guards.split_at_mut(1);
        for shard in rest.iter_mut() {
            let parts = shard.take_parts();
            home[0].absorb_parts(parts);
        }
    }

    /// Leave the serialized epoch: with every shard lock held, split the
    /// home shard's state back out by source and clear the flag. Caller
    /// holds the epoch control lock and has observed `pending == 0` (so
    /// no wildcard receive is still posted).
    fn flip_back(&self) {
        self.unflips.fetch_add(1, Ordering::Relaxed);
        instrument::record_epoch_unflip();
        let mut guards: Vec<PMutexGuard<'_, MatchingState>> =
            (0..self.shards.len()).map(|i| self.lock_shard(i)).collect();
        debug_assert!(
            guards[1..].iter().all(|g| g.is_idle()),
            "non-home shards accumulated state during a serialized epoch"
        );
        let parts = guards[HOME_SHARD].take_parts();
        let buckets = parts.split_by_source(self.shards.len(), |src| self.shard_of(src));
        for (idx, bucket) in buckets.into_iter().enumerate() {
            guards[idx].absorb_parts(bucket);
        }
        self.serialized.store(false, Ordering::Release);
    }

    /// Currently inside a serialized wildcard epoch? (Test/debug aid.)
    pub fn is_serialized(&self) -> bool {
        self.serialized.load(Ordering::Acquire)
    }

    pub fn epoch_stats(&self) -> EpochStats {
        EpochStats {
            flips: self.flips.load(Ordering::Relaxed),
            unflips: self.unflips.load(Ordering::Relaxed),
            wildcard_posts: self.wildcard_posts.load(Ordering::Relaxed),
        }
    }

    /// (duplicate-seq drops, parked striped arrivals) summed over shards.
    pub fn reorder_stats(&self) -> (u64, usize) {
        let mut dups = 0;
        let mut parked = 0;
        for i in 0..self.shards.len() {
            let guard = self.lock_shard(i);
            dups += guard.dup_seq_drops();
            parked += guard.reorder_parked();
        }
        (dups, parked)
    }

    /// Posted + unexpected totals over all shards (test/debug aid).
    pub fn queue_lens(&self) -> (usize, usize) {
        let mut posted = 0;
        let mut unexpected = 0;
        for i in 0..self.shards.len() {
            let guard = self.lock_shard(i);
            posted += guard.posted_len();
            unexpected += guard.unexpected_len();
        }
        (posted, unexpected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::matching::{Arrival, SenderInfo, Tag};

    fn umsg(comm: u64, src: usize, tag: i32, seq: u64) -> UnexpectedMsg {
        UnexpectedMsg {
            comm_id: comm,
            src_rank: src,
            tag,
            seq,
            sender: SenderInfo { src_proc: src, src_ctx: 0, send_handle: 0 },
            arrival: Arrival::Eager { data: vec![], needs_ack: false },
        }
    }

    fn precv(comm: u64, src: Src, tag: Tag, req: crate::mpi::request::ReqId) -> PostedRecv {
        PostedRecv { comm_id: comm, src, tag, req }
    }

    fn engine(shards: usize, linger: u32) -> Arc<CommMatch> {
        CommMatch::new(Backend::Native, 7, shards, linger)
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(engine(1, 0).shard_count(), 1);
        assert_eq!(engine(3, 0).shard_count(), 4);
        assert_eq!(engine(8, 0).shard_count(), 8);
        assert_eq!(engine(0, 0).shard_count(), 1);
    }

    #[test]
    fn concrete_traffic_matches_without_epochs() {
        let m = engine(8, 0);
        assert!(m.post(precv(7, Src::Rank(2), Tag::Value(5), 10)).unwrap().is_none());
        let hits = m.striped_arrival(umsg(7, 2, 5, 1)).unwrap();
        m.note_arrival(0);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.req, 10);
        assert!(!m.is_serialized());
        assert_eq!(m.epoch_stats(), EpochStats::default());
    }

    #[test]
    fn streams_shard_independently() {
        let m = engine(8, 0);
        // Gap one source's stream; other sources keep flowing.
        assert!(m.striped_arrival(umsg(7, 0, 5, 2)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(m.striped_arrival(umsg(7, 1, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        let (_, unexpected) = m.queue_lens();
        assert_eq!(unexpected, 1, "src 1 admitted; src 0 parked on its gap");
        let (dups, parked) = m.reorder_stats();
        assert_eq!((dups, parked), (0, 1));
        // Fill the gap: both of src 0's messages admit in order.
        assert!(m.striped_arrival(umsg(7, 0, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        assert_eq!(m.queue_lens().1, 3);
        assert_eq!(m.reorder_stats(), (0, 0));
    }

    #[test]
    fn wildcard_flips_epoch_and_matches_across_shards() {
        let m = engine(8, 0);
        // Unexpected messages from two sources land in two shards.
        assert!(m.striped_arrival(umsg(7, 0, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(m.striped_arrival(umsg(7, 3, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        // A wildcard post serializes and must see BOTH queued messages.
        let first = m.post(precv(7, Src::Any, Tag::Value(5), 20)).unwrap();
        assert!(first.is_some(), "wildcard must match a queued message");
        let second = m.post(precv(7, Src::Any, Tag::Value(5), 21)).unwrap();
        assert!(second.is_some());
        let srcs = [first.unwrap().src_rank, second.unwrap().src_rank];
        assert!(srcs.contains(&0) && srcs.contains(&3));
        let stats = m.epoch_stats();
        assert!(stats.flips >= 1);
        assert_eq!(stats.wildcard_posts, 2);
        // Both wildcards completed at post time: sharded mode restored.
        assert!(!m.is_serialized());
        assert_eq!(m.epoch_stats().unflips, m.epoch_stats().flips);
    }

    #[test]
    fn pending_wildcard_holds_epoch_until_arrival_matches() {
        let m = engine(4, 0);
        assert!(m.post(precv(7, Src::Any, Tag::Any, 20)).unwrap().is_none());
        assert!(m.is_serialized(), "unmatched wildcard keeps the epoch open");
        // Concrete posts during the epoch go to the home shard, behind
        // the wildcard in post order.
        assert!(m.post(precv(7, Src::Rank(1), Tag::Any, 21)).unwrap().is_none());
        let hits = m.striped_arrival(umsg(7, 1, 9, 1)).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.req, 20, "earlier-posted wildcard matches first");
        let wilds = hits.iter().filter(|(p, _)| p.src == Src::Any).count() as u64;
        m.note_arrival(wilds);
        assert!(!m.is_serialized(), "last wildcard completion flips back");
        // The concrete recv survived the flip-back and still matches.
        let hits = m.striped_arrival(umsg(7, 1, 9, 2)).unwrap();
        m.note_arrival(0);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.req, 21);
    }

    #[test]
    fn reorder_state_survives_epoch_round_trip() {
        let m = engine(8, 0);
        // Seq 2 parks (gap); then an epoch flips state into home and back.
        assert!(m.striped_arrival(umsg(7, 4, 5, 2)).unwrap().is_empty());
        m.note_arrival(0);
        let got = m.post(precv(7, Src::Any, Tag::Value(5), 20)).unwrap();
        assert!(got.is_none(), "parked arrival is not matchable");
        assert!(m.is_serialized());
        // Seq 1 arrives during the epoch: admits both, wildcard gets seq 1.
        let hits = m.striped_arrival(umsg(7, 4, 5, 1)).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].1.seq, 1);
        let wilds = hits.iter().filter(|(p, _)| p.src == Src::Any).count() as u64;
        assert_eq!(wilds, 1);
        m.note_arrival(wilds);
        assert!(!m.is_serialized());
        // Seq 2 sits in the unexpected queue of src 4's shard again.
        let got = m.post(precv(7, Src::Rank(4), Tag::Value(5), 21)).unwrap().unwrap();
        assert_eq!(got.seq, 2);
        // Stream continuity: next expected seq is 3, not reset.
        assert!(m.striped_arrival(umsg(7, 4, 5, 3)).unwrap().is_empty());
        m.note_arrival(0);
        assert_eq!(m.queue_lens().1, 1);
        assert_eq!(m.reorder_stats(), (0, 0));
    }

    #[test]
    fn linger_keeps_epoch_open_for_n_arrivals() {
        let m = engine(4, 2);
        assert!(m.striped_arrival(umsg(7, 2, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(m.post(precv(7, Src::Any, Tag::Value(5), 20)).unwrap().is_some());
        assert!(m.is_serialized(), "linger holds the epoch after completion");
        assert!(m.striped_arrival(umsg(7, 2, 5, 2)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(m.is_serialized(), "one linger tick left");
        assert!(m.striped_arrival(umsg(7, 2, 5, 3)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(!m.is_serialized(), "linger exhausted: flipped back");
        assert_eq!(m.queue_lens().1, 2);
        assert_eq!(m.reorder_stats(), (0, 0));
    }

    #[test]
    fn linger_ticks_on_concrete_posts_too() {
        let m = engine(4, 2);
        assert!(m.striped_arrival(umsg(7, 2, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(m.post(precv(7, Src::Any, Tag::Value(5), 20)).unwrap().is_some());
        assert!(m.is_serialized(), "linger holds after the wildcard completes");
        assert!(m.post(precv(7, Src::Rank(2), Tag::Value(5), 21)).unwrap().is_none());
        assert!(m.is_serialized(), "one linger tick left");
        assert!(m.post(precv(7, Src::Rank(2), Tag::Value(5), 22)).unwrap().is_none());
        assert!(!m.is_serialized(), "concrete posts exhaust the linger");
        // The concrete recvs migrated back to their shard in post order.
        let hits = m.striped_arrival(umsg(7, 2, 5, 2)).unwrap();
        m.note_arrival(0);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.req, 21);
    }

    #[test]
    fn single_shard_engine_never_epochs() {
        let m = engine(1, 0);
        assert!(m.post(precv(7, Src::Any, Tag::Any, 20)).unwrap().is_none());
        assert!(!m.is_serialized(), "one shard needs no serialization");
        assert_eq!(m.epoch_stats().flips, 0);
        let hits = m.striped_arrival(umsg(7, 5, 1, 1)).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.req, 20);
        let wilds = hits.iter().filter(|(p, _)| p.src == Src::Any).count() as u64;
        m.note_arrival(wilds);
        assert_eq!(m.epoch_stats().unflips, 0);
    }

    #[test]
    fn retire_into_migrates_queues_and_stream_continuity() {
        // A lazily created 1-shard engine accumulates unexpected arrivals
        // (including a parked gap); policy adoption retires it into a
        // 4-shard successor and must preserve per-stream order and
        // next_seq.
        let old = engine(1, 0);
        assert!(old.striped_arrival(umsg(7, 2, 5, 1)).unwrap().is_empty());
        old.note_arrival(0);
        assert!(old.striped_arrival(umsg(7, 3, 5, 1)).unwrap().is_empty());
        old.note_arrival(0);
        assert!(
            old.striped_arrival(umsg(7, 2, 5, 3)).unwrap().is_empty(),
            "seq 3 parks on its gap"
        );
        old.note_arrival(0);
        let fresh = engine(4, 0);
        old.retire_into(&fresh);
        assert!(old.is_retired());
        assert_eq!(old.queue_lens(), (0, 0), "old engine drained");
        assert_eq!(fresh.queue_lens().1, 2, "both admitted arrivals migrated");
        // Stream continuity: seq 2 fills the gap and drains parked seq 3.
        assert!(fresh.striped_arrival(umsg(7, 2, 5, 2)).unwrap().is_empty());
        fresh.note_arrival(0);
        assert_eq!(fresh.queue_lens().1, 4);
        assert_eq!(fresh.reorder_stats(), (0, 0));
        for want in 1..=3u64 {
            let got = fresh.post(precv(7, Src::Rank(2), Tag::Value(5), 10)).unwrap().unwrap();
            assert_eq!(got.seq, want, "migrated stream must stay in seq order");
        }
        let got = fresh.post(precv(7, Src::Rank(3), Tag::Value(5), 11)).unwrap().unwrap();
        assert_eq!(got.seq, 1);
    }

    #[test]
    fn retired_engine_bounces_stragglers_to_the_successor() {
        // The engine-adoption double race: a handler still holding the old
        // engine's handle deposits AFTER the drain. With the retire
        // protocol the straggler gets its operand handed back and retries
        // on the successor — the stream never straddles two live engines,
        // so continuity survives with no duplicate drops.
        let old = engine(1, 0);
        assert!(old.striped_arrival(umsg(7, 2, 5, 1)).unwrap().is_empty());
        old.note_arrival(0);
        let fresh = engine(4, 0);
        old.retire_into(&fresh);
        // Straggler arrival bounces off the retired engine...
        let back = old.striped_arrival(umsg(7, 2, 5, 2)).expect_err("retired engine must bounce");
        assert_eq!(back.seq, 2);
        // ...and lands on the successor with seq continuity intact.
        assert!(fresh.striped_arrival(back).unwrap().is_empty());
        fresh.note_arrival(0);
        assert_eq!(fresh.queue_lens().1, 2);
        assert_eq!(fresh.reorder_stats(), (0, 0), "no duplicate drops, nothing parked");
        // Straggler posts bounce the same way (concrete and wildcard).
        let recv = old
            .post(precv(7, Src::Rank(2), Tag::Value(5), 30))
            .expect_err("retired engine must bounce posts");
        assert_eq!(fresh.post(recv).unwrap().unwrap().seq, 1, "post retries on the successor");
        let wild = old
            .post(precv(7, Src::Any, Tag::Any, 31))
            .expect_err("retired engine must bounce wildcard posts");
        assert_eq!(wild.req, 31);
        assert!(!old.is_serialized(), "bounced wildcard leaves no epoch behind");
    }

    #[test]
    fn duplicate_drops_counted_across_shards() {
        let m = engine(8, 0);
        assert!(m.striped_arrival(umsg(7, 1, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        assert!(m.striped_arrival(umsg(7, 1, 5, 1)).unwrap().is_empty());
        m.note_arrival(0);
        assert_eq!(m.reorder_stats().0, 1);
    }
}
