//! Two-sided point-to-point: MPI_Isend / MPI_Issend / MPI_Irecv /
//! MPI_Wait / MPI_Test and their blocking forms.
//!
//! Protocols (paper §4.1 and the CH4 design it builds on):
//!  * immediate: small sends complete at injection; no request object is
//!    allocated — a lightweight pre-completed request is referenced.
//!  * eager: payload travels with the envelope; TX completes when the DMA
//!    drains (tracked with `Completion::AtTime`).
//!  * rendezvous: RTS/CTS/DATA exchange for large payloads.
//!  * synchronous (Ssend): completes on the receiver's match ack.

use crate::fabric::{P2pProtocol, Payload};
use crate::platform::{padvance, pnow};

use super::config::CsMode;
use super::instrument::LockClass;
use super::matching::{Arrival, PostedRecv, SenderInfo, Src, Tag, UnexpectedMsg};
use super::proc::{thread_token, MpiProc};
use super::request::{ReqId, Request, REQ_FLAG_DOORBELL, REQ_FLAG_STREAM, REQ_FLAG_STRIPED};
use super::vci::{Guard, VciState};
use super::Comm;

/// Request-slot routing flags for an operation on `comm` (striped comms'
/// waiters sweep the stripe lanes; doorbell participation per policy).
fn req_flags(comm: &Comm, striped: bool) -> u8 {
    if !striped {
        return 0;
    }
    REQ_FLAG_STRIPED | if comm.policy.rx_doorbell { REQ_FLAG_DOORBELL } else { 0 }
}

/// How many request ids a stream refill pulls from the shared slab in one
/// (amortized) lock acquisition. Also the `stream_bind` pre-charge, so
/// the first window of ops on a fresh stream is already lock-free.
const STREAM_FREELIST_PREFILL: usize = 64;

impl MpiProc {
    /// True when completion counters must be updated atomically (FG mode
    /// with thread safety enabled).
    pub(super) fn charged_atomics(&self) -> bool {
        self.cfg.cs_mode == CsMode::Fg && self.guard() != Guard::None
    }

    pub(super) fn take_pool_lock(&self) -> bool {
        self.cfg.cs_mode == CsMode::Fg && self.guard() != Guard::None
    }

    /// Allocate a request with the VCI state already held (per-VCI cache
    /// fast path — paper §4.3 "per-VCI request management"). Cache misses
    /// refill a chunk from the global pool under one lock acquisition.
    pub(super) fn alloc_request(&self, st: &mut VciState) -> ReqId {
        if self.cfg.per_vci_req_cache {
            if let Some(id) = st.req_cache.pop() {
                padvance(self.backend, self.costs.request_cache_op);
                self.slab.reset_slot(id);
                return id;
            }
            let mut chunk = self.slab.alloc_chunk(&self.costs, self.take_pool_lock(), 32);
            let id = chunk.pop().expect("chunk non-empty");
            st.req_cache.extend(chunk);
            self.slab.reset_slot(id);
            return id;
        }
        self.slab.alloc_global(&self.costs, self.take_pool_lock())
    }

    /// Pre-charge `lane`'s stream freelist so a fresh stream's first
    /// window of ops never touches the shared slab lock (called by
    /// `stream_bind`, after the lane entered single-writer mode).
    pub(super) fn stream_prefill(&self, lane: usize) {
        let chunk =
            self.slab.alloc_chunk(&self.costs, self.take_pool_lock(), STREAM_FREELIST_PREFILL);
        self.stream_freelist_outstanding
            .fetch_add(chunk.len(), std::sync::atomic::Ordering::Relaxed);
        let vci = self.vcis().get(lane).clone();
        vci.with_state_stream(|st| st.stream_freelist.extend(chunk));
    }

    /// Drain `lane`'s stream freelist back to the shared slab (the unbind
    /// path — must run while the caller still owns the stream).
    pub(super) fn stream_drain_freelist(&self, lane: usize) {
        let vci = self.vcis().get(lane).clone();
        let drained = vci.with_state_stream(|st| std::mem::take(&mut st.stream_freelist));
        if drained.is_empty() {
            return;
        }
        self.stream_freelist_outstanding
            .fetch_sub(drained.len(), std::sync::atomic::Ordering::Relaxed);
        let take_lock = self.take_pool_lock();
        for id in drained {
            self.slab.free_global(id, &self.costs, take_lock);
        }
    }

    /// Stream-path request allocation: pop the lane-local freelist (zero
    /// locks, zero shared-cache touches) or refill a chunk from the
    /// shared slab — one amortized lock acquisition, the same honesty as
    /// the per-VCI cache refill in [`MpiProc::alloc_request`].
    fn alloc_request_stream(&self, st: &mut VciState) -> ReqId {
        if let Some(id) = st.stream_freelist.pop() {
            super::instrument::count_stream_freelist_hit();
            padvance(self.backend, self.costs.request_cache_op);
            self.slab.reset_slot(id);
            return id;
        }
        let mut chunk =
            self.slab.alloc_chunk(&self.costs, self.take_pool_lock(), STREAM_FREELIST_PREFILL);
        self.stream_freelist_outstanding
            .fetch_add(chunk.len(), std::sync::atomic::Ordering::Relaxed);
        let id = chunk.pop().expect("chunk non-empty");
        st.stream_freelist.extend(chunk);
        self.slab.reset_slot(id);
        id
    }

    /// Free a request after wait/test observes completion. Runs *outside*
    /// the VCI critical section that observed completion (paper §4.3: the
    /// VCI lock is taken a second time for the free).
    pub(super) fn release_request(&self, id: ReqId, vci_idx: usize) {
        let guard = self.guard();
        let flags = self.slab.slot(id).flags.load(std::sync::atomic::Ordering::Relaxed);
        if flags & REQ_FLAG_STREAM != 0 {
            let vci = self.vcis().get(vci_idx).clone();
            if vci.stream_owned_by(thread_token()) {
                // Owner free: back onto the lane-local freelist, lock-free.
                vci.with_state_stream(|st| {
                    padvance(self.backend, self.costs.request_cache_op);
                    st.stream_freelist.push(id);
                });
            } else {
                // The lane was unbound between initiation and this free:
                // return the id straight to the shared slab so nothing
                // leaks (finalize asserts the checkout count balanced).
                self.stream_freelist_outstanding
                    .fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                self.slab.free_global(id, &self.costs, guard == Guard::VciLock);
            }
            return;
        }
        if self.cfg.per_vci_req_cache {
            if flags & REQ_FLAG_STRIPED != 0 {
                // Striping (per the owning comm's policy): the allocating
                // VCI's lock is a hot resource, so don't pay a dedicated
                // acquisition for the free — park it on the owner (one
                // shared-list push, modeled as an atomic) and let the next
                // locked entry absorb it, like the deferred lightweight
                // release.
                padvance(self.backend, self.costs.atomic_rmw + self.costs.request_cache_op);
                self.vcis().get(vci_idx).defer_request_free(id);
                return;
            }
            // Return to the owning VCI's cache under the mode's guard
            // discipline (VCI lock in FG; the big lock / nothing in
            // Global / no-thread-safety modes).
            let vci = self.vcis().get(vci_idx).clone();
            vci.with_state(guard, |st| {
                padvance(self.backend, self.costs.request_cache_op);
                st.req_cache.push(id);
            });
        } else {
            let take_lock = guard == Guard::VciLock;
            self.slab.free_global(id, &self.costs, take_lock);
        }
    }

    pub(super) fn lightweight_acquire(&self, st: &mut VciState) {
        if self.cfg.per_vci_lightweight {
            // Plain (uncharged) bump: protected by the VCI lock.
            st.lw_refs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        } else {
            // One global lightweight request: contended atomic in FG mode.
            self.slab.global_lightweight_refs.fetch_add(1, self.charged_atomics());
        }
    }

    fn lightweight_release(&self, vci_idx: usize) {
        if self.cfg.per_vci_lightweight {
            let vci = self.vcis().get(vci_idx);
            if vci.stream_owned_by(thread_token()) {
                // Single-writer lane: decrement in place — the lock-free
                // twin of the deferred release below (nothing to defer to:
                // no other thread ever enters this lane's state, and the
                // stream's own ops never drain the deferral list).
                vci.clone().with_state_stream(|st| {
                    st.lw_refs.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                });
                return;
            }
            // Deferred decrement: MPI_Wait on a lightweight request takes
            // zero locks (paper Table 1). The release parks on the owning
            // VCI and is reconciled by its next locked operation; balance
            // is asserted at finalize.
            self.vcis().get(vci_idx).defer_lightweight_release();
        } else {
            self.slab.global_lightweight_refs.fetch_sub(1, self.charged_atomics());
        }
    }

    /// Resolve the serial-execution-stream fast path for an op on `comm`:
    /// `Some(lane)` when the calling thread owns the comm's lane as a
    /// stream — binding implicitly on the first touch of a
    /// `vcmpi_stream=local` communicator (the info-key flavor of
    /// [`MpiProc::stream_bind`]). Streams never combine with striping or
    /// the §7 envelope-spread hints (the traffic must funnel through the
    /// one bound lane), and a stream comm driven from a second thread is
    /// erroneous — caught here, deterministically.
    fn stream_lane(&self, comm: &Comm) -> Option<usize> {
        if comm.is_endpoints()
            || self.striping_active(comm)
            || (comm.policy.no_any_source && comm.policy.no_any_tag && self.vcis().len() > 1)
        {
            return None;
        }
        let lane = self.comm_vci(comm, None);
        if lane == super::vci::FALLBACK_VCI {
            return None; // the shared world lane never streams
        }
        let vci = self.vcis().get(lane);
        let me = thread_token();
        if vci.stream_owned_by(me) {
            return Some(lane);
        }
        if !comm.policy.stream {
            return None;
        }
        if !vci.is_stream_owned() {
            if self.guard() != Guard::VciLock {
                return None; // coarse CS modes have no per-VCI lock to elide
            }
            self.stream_bind(comm);
            return Some(lane);
        }
        panic!(
            "stream comm {} driven from thread token {me}, but its lane {lane} is \
             stream-owned by token {}; a serial execution stream has exactly one driving \
             thread (erroneous program)",
            comm.id,
            vci.stream_owner()
        );
    }

    /// MPI_Isend (standard mode).
    pub fn isend(&self, comm: &Comm, dst: usize, tag: i32, data: &[u8]) -> Request {
        self.isend_ep(comm, None, dst, tag, data, false)
    }

    /// MPI_Issend (synchronous mode: completes only once matched).
    pub fn issend(&self, comm: &Comm, dst: usize, tag: i32, data: &[u8]) -> Request {
        self.isend_ep(comm, None, dst, tag, data, true)
    }

    /// Endpoint-aware isend: `my_ep` selects the sending endpoint for
    /// endpoints communicators (None for process communicators).
    pub fn isend_ep(
        &self,
        comm: &Comm,
        my_ep: Option<usize>,
        dst: usize,
        tag: i32,
        data: &[u8],
        sync: bool,
    ) -> Request {
        self.isend_inner(comm, my_ep, dst, tag, data, sync, None)
    }

    /// Collective-internal isend: `coll_vci` forces the message onto an
    /// explicit lane (dedicated / envelope-spread collectives — see
    /// `mpi::collectives`), bypassing per-message striping so both sides
    /// agree on the path from the envelope alone.
    pub(super) fn isend_coll(
        &self,
        comm: &Comm,
        dst: usize,
        tag: i32,
        data: &[u8],
        coll_vci: Option<usize>,
    ) -> Request {
        self.isend_inner(comm, None, dst, tag, data, false, coll_vci)
    }

    #[allow(clippy::too_many_arguments)]
    fn isend_inner(
        &self,
        comm: &Comm,
        my_ep: Option<usize>,
        dst: usize,
        tag: i32,
        data: &[u8],
        sync: bool,
        coll_vci: Option<usize>,
    ) -> Request {
        padvance(self.backend, self.costs.mpi_sw_send + self.costs.instructions(8));
        // Serial-execution-stream fast path: when the calling thread owns
        // this comm's lane, the whole send runs single-writer — no CS, no
        // VCI lock, lane-local request allocation. Wire format is
        // identical to the ordered locked path below.
        if coll_vci.is_none() && my_ep.is_none() {
            if let Some(lane) = self.stream_lane(comm) {
                return self.isend_stream(comm, lane, dst, tag, data, sync);
            }
        }
        let _cs = self.enter_cs();
        let guard = self.guard();
        // VCI selection, in precedence order:
        //  0. A collective-segment lane override (dedicated-lane or
        //     envelope-spread collectives): explicit, never striped.
        //  1. Per-message striping: any pool VCI, chosen per message; the
        //     receiver's reorder stage restores nonovertaking order from
        //     the shared (comm, dst) stream sequence.
        //  2. MPI-4.0 hint spreading (paper §7): the stream is keyed by
        //     the SENDER's rank + tag so the receiver can derive the same
        //     one (wildcards are asserted away).
        //  3. The communicator's / endpoint's assigned VCI.
        let striped = coll_vci.is_none() && my_ep.is_none() && self.striping_active(comm);
        let (wire_idx, stripe_seq) = if let Some(v) = coll_vci {
            (v, None)
        } else if striped {
            let seq = self.next_stripe_seq(comm.id, dst);
            (self.stripe_vci(comm, dst, seq), Some(seq))
        } else if my_ep.is_none() {
            (self.vci_for_envelope(comm, comm.rank, tag), None)
        } else {
            (self.comm_vci(comm, my_ep), None)
        };
        // Lane failover: issue from the survivor when the derived lane's
        // context hard-failed. Only the LOCAL lane resolves — the
        // wire-visible derivation below stays in the unresolved lane
        // space, because the receiver (healthy) posts and polls on the
        // lane both sides derive from the envelope; frames aimed at a
        // context that later dies are re-homed by the fabric's own
        // redirect at delivery. Identity (one plain load) without a
        // fault plan.
        let vci_idx = self.vcis().resolve(wire_idx);
        let vci = self.vcis().get(vci_idx).clone();
        let (dst_proc, base_dst_ctx) = self.route(comm, dst);
        let dst_ctx = if striped
            || coll_vci.is_some()
            || (my_ep.is_none() && wire_idx != self.comm_vci(comm, None))
        {
            // Striped / hinted / collective-lane spread: target the mirror
            // context on the receiver.
            self.remote_ctx_for_vci(dst_proc, wire_idx)
        } else {
            base_dst_ctx
        };
        // Striped envelopes carry the comm's home VCI so the receiver
        // knows which matching engine owns the stream (reduced modulo its
        // pool size there).
        let stripe_home = if striped { Some(comm.vci) } else { None };
        let my_rank = match &comm.kind {
            super::comm::CommKind::Procs | super::comm::CommKind::Group { .. } => comm.rank,
            super::comm::CommKind::Endpoints { per_proc, .. } => {
                comm.rank * per_proc + my_ep.expect("endpoint identity required")
            }
        };
        let eager = data.len() <= self.costs.rendezvous_threshold;
        let immediate = eager && !sync && data.len() <= self.costs.immediate_completion_max;
        vci.with_state(guard, |st| {
            let seq = match stripe_seq {
                // Striped: the shared per-(comm, dst) stream counter was
                // drawn before VCI selection (hashed striping needs it).
                Some(s) => s,
                None => {
                    let e = st.send_seq.entry((comm.id, dst)).or_insert(0);
                    *e += 1;
                    *e
                }
            };
            if immediate {
                self.lightweight_acquire(st);
                self.fabric.inject(vci.ctx_index, dst_proc, dst_ctx, Payload::TwoSided {
                    comm_id: comm.id,
                    src_rank: my_rank,
                    dst_rank: dst,
                    tag,
                    seq,
                    stripe_home,
                    protocol: P2pProtocol::Eager { send_handle: 0 },
                    needs_ack: false,
                    data: data.to_vec(),
                });
                return Request::Lightweight { vci: vci_idx };
            }
            let id = self.alloc_request(st);
            let rf = req_flags(comm, striped);
            self.slab.slot(id).vci.store(vci_idx, std::sync::atomic::Ordering::Relaxed);
            self.slab.slot(id).flags.store(rf, std::sync::atomic::Ordering::Relaxed);
            padvance(self.backend, self.costs.instructions(3)); // record VCI in request
            if eager {
                self.fabric.inject(vci.ctx_index, dst_proc, dst_ctx, Payload::TwoSided {
                    comm_id: comm.id,
                    src_rank: my_rank,
                    dst_rank: dst,
                    tag,
                    seq,
                    stripe_home,
                    protocol: P2pProtocol::Eager { send_handle: id as u64 },
                    needs_ack: sync,
                    data: data.to_vec(),
                });
                if sync {
                    // Completes on the receiver's SendAck.
                } else {
                    // TX completion when the DMA drains.
                    let done = pnow(self.backend) + self.costs.dma_cost(data.len());
                    self.slab
                        .slot(id)
                        .complete_at
                        .store(done, std::sync::atomic::Ordering::Release);
                }
            } else {
                // Rendezvous: park the payload, send RTS.
                st.pending_sends.insert(
                    id as u64,
                    super::vci::PendingSend {
                        data: data.to_vec(),
                        comm_id: comm.id,
                        dst_rank: dst,
                        tag,
                        req: id,
                    },
                );
                self.fabric.inject(vci.ctx_index, dst_proc, dst_ctx, Payload::TwoSided {
                    comm_id: comm.id,
                    src_rank: my_rank,
                    dst_rank: dst,
                    tag,
                    seq,
                    stripe_home,
                    protocol: P2pProtocol::Rts { send_handle: id as u64 },
                    needs_ack: false,
                    data: Vec::new(),
                });
            }
            Request::Real { id, vci: vci_idx }
        })
    }

    /// Single-writer isend on a stream-owned lane: the same protocols,
    /// wire format, and modeled instruction costs as the ordered locked
    /// path in [`MpiProc::isend_inner`], minus the VCI lock and the
    /// shared request cache — the Table-1 "endpoints without endpoints"
    /// arm. Only ever entered by the lane's owning thread.
    fn isend_stream(
        &self,
        comm: &Comm,
        lane: usize,
        dst: usize,
        tag: i32,
        data: &[u8],
        sync: bool,
    ) -> Request {
        let vci = self.vcis().get(lane).clone();
        let (dst_proc, dst_ctx) = self.route(comm, dst);
        let eager = data.len() <= self.costs.rendezvous_threshold;
        let immediate = eager && !sync && data.len() <= self.costs.immediate_completion_max;
        vci.with_state_stream(|st| {
            let seq = {
                let e = st.send_seq.entry((comm.id, dst)).or_insert(0);
                *e += 1;
                *e
            };
            if immediate {
                self.lightweight_acquire(st);
                self.fabric.inject(vci.ctx_index, dst_proc, dst_ctx, Payload::TwoSided {
                    comm_id: comm.id,
                    src_rank: comm.rank,
                    dst_rank: dst,
                    tag,
                    seq,
                    stripe_home: None,
                    protocol: P2pProtocol::Eager { send_handle: 0 },
                    needs_ack: false,
                    data: data.to_vec(),
                });
                return Request::Lightweight { vci: lane };
            }
            let id = self.alloc_request_stream(st);
            self.slab.slot(id).vci.store(lane, std::sync::atomic::Ordering::Relaxed);
            self.slab
                .slot(id)
                .flags
                .store(REQ_FLAG_STREAM, std::sync::atomic::Ordering::Relaxed);
            padvance(self.backend, self.costs.instructions(3)); // record VCI in request
            if eager {
                self.fabric.inject(vci.ctx_index, dst_proc, dst_ctx, Payload::TwoSided {
                    comm_id: comm.id,
                    src_rank: comm.rank,
                    dst_rank: dst,
                    tag,
                    seq,
                    stripe_home: None,
                    protocol: P2pProtocol::Eager { send_handle: id as u64 },
                    needs_ack: sync,
                    data: data.to_vec(),
                });
                if !sync {
                    let done = pnow(self.backend) + self.costs.dma_cost(data.len());
                    self.slab
                        .slot(id)
                        .complete_at
                        .store(done, std::sync::atomic::Ordering::Release);
                }
            } else {
                st.pending_sends.insert(
                    id as u64,
                    super::vci::PendingSend {
                        data: data.to_vec(),
                        comm_id: comm.id,
                        dst_rank: dst,
                        tag,
                        req: id,
                    },
                );
                self.fabric.inject(vci.ctx_index, dst_proc, dst_ctx, Payload::TwoSided {
                    comm_id: comm.id,
                    src_rank: comm.rank,
                    dst_rank: dst,
                    tag,
                    seq,
                    stripe_home: None,
                    protocol: P2pProtocol::Rts { send_handle: id as u64 },
                    needs_ack: false,
                    data: Vec::new(),
                });
            }
            Request::Real { id, vci: lane }
        })
    }

    /// MPI_Irecv. Returns a request whose `wait` yields the payload.
    pub fn irecv(&self, comm: &Comm, src: Src, tag: Tag) -> Request {
        self.irecv_ep(comm, None, src, tag)
    }

    pub fn irecv_ep(&self, comm: &Comm, my_ep: Option<usize>, src: Src, tag: Tag) -> Request {
        self.irecv_inner(comm, my_ep, src, tag, None)
    }

    /// Collective-internal irecv: `coll_vci` posts the receive into an
    /// explicit lane's matching engine (the collective tag space never
    /// uses wildcards, so the fully specified envelope selects the same
    /// lane on both sides — see `MpiProc::coll_segment_vci`).
    pub(super) fn irecv_coll(
        &self,
        comm: &Comm,
        src: Src,
        tag: Tag,
        coll_vci: Option<usize>,
    ) -> Request {
        self.irecv_inner(comm, None, src, tag, coll_vci)
    }

    fn irecv_inner(
        &self,
        comm: &Comm,
        my_ep: Option<usize>,
        src: Src,
        tag: Tag,
        coll_vci: Option<usize>,
    ) -> Request {
        padvance(self.backend, self.costs.mpi_sw_recv + self.costs.instructions(8));
        // Serial-execution-stream fast path (see `isend_inner`): posts
        // into the bound lane's own matching engine, single-writer.
        if coll_vci.is_none() && my_ep.is_none() {
            if let Some(lane) = self.stream_lane(comm) {
                return self.irecv_stream(comm, lane, src, tag);
            }
        }
        let _cs = self.enter_cs();
        let guard = self.guard();
        if let Some(v) = coll_vci {
            // Collective segment on an explicit lane: post into that VCI's
            // own matching engine (never the sharded striped path — the
            // matching sender marked no stripe_home, so its arrival is
            // handled by this engine too). A failed lane resolves to its
            // survivor — the matching sender's frame is re-homed to the
            // same survivor context by the fabric redirect.
            let v = self.vcis().resolve(v);
            let vci = self.vcis().get(v).clone();
            return vci.with_state(guard, |st| {
                let id = self.alloc_request(st);
                self.slab.slot(id).vci.store(v, std::sync::atomic::Ordering::Relaxed);
                padvance(self.backend, self.costs.instructions(3) + self.costs.match_cost);
                let posted = PostedRecv { comm_id: comm.id, src, tag, req: id };
                if let Some(m) = st.matching.on_post(posted) {
                    self.consume_matched(vci.ctx_index, id, m);
                }
                Request::Real { id, vci: v }
            });
        }
        // Under striping (per this communicator's policy), receives post
        // into the communicator's sharded matching engine: a concrete
        // source goes to the shard that owns its stream (matched by
        // whichever VCI polls the arrival), and MPI_ANY_SOURCE enters the
        // serialized wildcard epoch — wildcards stay fully legal, unlike
        // the §7 envelope hints (unless this comm's policy asserts them
        // away). The request allocates from the **shard-anchored** VCI's
        // cache — the VCI derived from the stream's shard — so concurrent
        // posts for different sources spread their allocation locks over
        // the pool instead of all funneling through the home VCI: the last
        // shared lock on the striped receive-post path (counted in the
        // Table-1 `anchored_allocs` column).
        if my_ep.is_none() && self.striping_active(comm) {
            if comm.policy.no_any_source && src == Src::Any {
                panic!(
                    "mpi_assert_no_any_source asserted on this communicator, but a wildcard receive was posted (erroneous program)"
                );
            }
            if comm.policy.no_any_tag && matches!(tag, Tag::Any) {
                panic!(
                    "mpi_assert_no_any_tag asserted on this communicator, but a wildcard receive was posted (erroneous program)"
                );
            }
            let home = self.comm_vci(comm, None);
            let vci_idx = match src {
                Src::Rank(s) => self.shard_anchor_vci(comm, s),
                // Wildcards serialize through the home shard; anchor home.
                Src::Any => home,
            };
            if vci_idx != home {
                super::instrument::count_anchored_alloc();
            }
            let vci_idx = self.vcis().resolve(vci_idx);
            let vci = self.vcis().get(vci_idx).clone();
            let rf = req_flags(comm, true);
            let (id, cm) = vci.with_state(guard, |st| {
                let id = self.alloc_request(st);
                self.slab.slot(id).vci.store(vci_idx, std::sync::atomic::Ordering::Relaxed);
                self.slab.slot(id).flags.store(rf, std::sync::atomic::Ordering::Relaxed);
                (id, self.cached_comm_match(st, comm.id))
            });
            padvance(self.backend, self.costs.instructions(3) + self.costs.match_cost);
            let mut cm = cm;
            let mut posted = PostedRecv { comm_id: comm.id, src, tag, req: id };
            let matched = loop {
                match cm.post(posted) {
                    Ok(m) => break m,
                    Err(back) => {
                        // The engine was retired by a policy adoption
                        // between resolution and post: the table already
                        // holds the successor — retry there.
                        posted = back;
                        cm = self.comm_match(comm.id);
                    }
                }
            };
            if let Some(m) = matched {
                // Matched straight off the unexpected queue (wildcard
                // epoch accounting, if any, happened inside `post`).
                self.consume_matched(vci.ctx_index, id, m);
            }
            return Request::Real { id, vci: vci_idx };
        }
        let hinted =
            comm.policy.no_any_source && comm.policy.no_any_tag && !comm.is_endpoints();
        let vci_idx = if hinted && my_ep.is_none() {
            // The asserted hints forbid wildcards: the envelope is fully
            // specified and selects the stream.
            let (s, t) = match (src, tag) {
                (Src::Rank(s), Tag::Value(t)) => (s, t),
                _ => panic!(
                    "mpi_assert_no_any_source/no_any_tag asserted, but a wildcard receive was posted (erroneous program)"
                ),
            };
            self.vci_for_envelope(comm, s, t)
        } else {
            self.comm_vci(comm, my_ep)
        };
        let vci_idx = self.vcis().resolve(vci_idx);
        let vci = self.vcis().get(vci_idx).clone();
        vci.with_state(guard, |st| {
            let id = self.alloc_request(st);
            self.slab.slot(id).vci.store(vci_idx, std::sync::atomic::Ordering::Relaxed);
            padvance(self.backend, self.costs.instructions(3) + self.costs.match_cost);
            let posted = PostedRecv { comm_id: comm.id, src, tag, req: id };
            if let Some(m) = st.matching.on_post(posted) {
                self.consume_matched(vci.ctx_index, id, m);
            }
            Request::Real { id, vci: vci_idx }
        })
    }

    /// Single-writer irecv on a stream-owned lane — the lock-free twin of
    /// the ordered post at the tail of [`MpiProc::irecv_inner`].
    /// Wildcards stay fully legal: the lane's matching engine is the same
    /// one the locked path uses, just entered without the lock.
    fn irecv_stream(&self, comm: &Comm, lane: usize, src: Src, tag: Tag) -> Request {
        let vci = self.vcis().get(lane).clone();
        vci.with_state_stream(|st| {
            let id = self.alloc_request_stream(st);
            self.slab.slot(id).vci.store(lane, std::sync::atomic::Ordering::Relaxed);
            self.slab
                .slot(id)
                .flags
                .store(REQ_FLAG_STREAM, std::sync::atomic::Ordering::Relaxed);
            padvance(self.backend, self.costs.instructions(3) + self.costs.match_cost);
            let posted = PostedRecv { comm_id: comm.id, src, tag, req: id };
            if let Some(m) = st.matching.on_post(posted) {
                self.consume_matched(vci.ctx_index, id, m);
            }
            Request::Real { id, vci: lane }
        })
    }

    /// Deliver a matched unexpected message into recv request `id`
    /// (either eagerly, or by answering an RTS with a CTS).
    pub(super) fn consume_matched(&self, my_ctx_index: usize, id: ReqId, m: UnexpectedMsg) {
        match m.arrival {
            Arrival::Eager { data, needs_ack } => {
                padvance(
                    self.backend,
                    self.costs.memcpy_cost(data.len()) + self.costs.completion_process,
                );
                *self.slab.slot(id).data.lock(LockClass::HostSlotData) = Some(data);
                self.slab.slot(id).completed.store(1, self.charged_atomics());
                if needs_ack {
                    self.reply(my_ctx_index, &m.sender, Payload::SendAck {
                        send_handle: m.sender.send_handle,
                    });
                }
            }
            Arrival::Rts => {
                // Control step: bypasses the striped reorder stage
                // (stripe_home None) and is handled by whichever VCI owns
                // the context it lands on.
                self.reply(my_ctx_index, &m.sender, Payload::TwoSided {
                    comm_id: m.comm_id,
                    src_rank: 0,
                    dst_rank: 0,
                    tag: 0,
                    seq: 0,
                    stripe_home: None,
                    protocol: P2pProtocol::Cts {
                        send_handle: m.sender.send_handle,
                        recv_handle: id as u64,
                    },
                    needs_ack: false,
                    data: Vec::new(),
                });
            }
        }
    }

    /// Inject a control reply toward the context a message came from.
    /// A malformed origin (unknown process or never-opened context) is
    /// dropped with a counted diagnostic instead of panicking in the
    /// fabric lookup — wire-message handling must never abort the process.
    pub(super) fn reply(&self, my_ctx_index: usize, sender: &SenderInfo, payload: Payload) {
        if sender.src_proc >= self.nprocs()
            || sender.src_ctx >= self.fabric.open_count(sender.src_proc)
        {
            self.stale_ctrl_drops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            super::instrument::record_stale_ctrl_drop();
            return;
        }
        self.fabric.inject(my_ctx_index, sender.src_proc, sender.src_ctx, payload);
    }

    /// Has this request completed? (Non-consuming check.)
    pub(super) fn is_complete(&self, id: ReqId) -> bool {
        let slot = self.slab.slot(id);
        if slot.completed.load() == 1 {
            return true;
        }
        let t = slot.complete_at.load(std::sync::atomic::Ordering::Acquire);
        t > 0 && pnow(self.backend) >= t
    }

    /// MPI_Wait: progress until complete; returns received payload if any.
    pub fn wait(&self, req: Request) -> Option<Vec<u8>> {
        match req {
            Request::Lightweight { vci } => {
                if self.cfg.cs_mode == CsMode::Global && self.guard() != Guard::None {
                    let _g = self.global_cs.lock_class(LockClass::Global);
                    self.lightweight_release(vci);
                } else {
                    self.lightweight_release(vci);
                }
                None
            }
            Request::Real { id, vci } => {
                // Progress routing per the owning communicator's policy,
                // recorded in the slot at initiation: striped comms sweep
                // the stripe lanes (optionally doorbell-gated), ordered
                // comms poll their own VCI, and stream requests waited by
                // their owning thread spin on the lock-free single-writer
                // poll (hook checks included for collective liveness —
                // the hook lock is only taken when a schedule is active).
                let flags = self.slab.slot(id).flags.load(std::sync::atomic::Ordering::Relaxed);
                let striped = flags & REQ_FLAG_STRIPED != 0;
                let doorbell = flags & REQ_FLAG_DOORBELL != 0;
                let stream = flags & REQ_FLAG_STREAM != 0
                    && self.vcis().get(vci).stream_owned_by(thread_token());
                loop {
                    if self.is_complete(id) {
                        break;
                    }
                    if stream {
                        self.progress_stream(vci);
                        self.check_hooks();
                        self.relax();
                    } else {
                        self.progress_with(vci, striped, doorbell);
                    }
                }
                let data = self.slab.slot(id).data.lock(LockClass::HostSlotData).take();
                if self.guard() == Guard::GlobalHeld {
                    let _cs = self.enter_cs();
                    self.release_request(id, vci);
                } else {
                    self.release_request(id, vci);
                }
                data
            }
        }
    }

    /// MPI_Test: one progress pass, then a completion check.
    pub fn test(&self, req: &Request) -> bool {
        match req {
            Request::Lightweight { .. } => true,
            Request::Real { id, vci } => {
                if self.is_complete(*id) {
                    return true;
                }
                let flags = self.slab.slot(*id).flags.load(std::sync::atomic::Ordering::Relaxed);
                if flags & REQ_FLAG_STREAM != 0
                    && self.vcis().get(*vci).stream_owned_by(thread_token())
                {
                    self.progress_stream(*vci);
                    self.check_hooks();
                } else {
                    let striped = flags & REQ_FLAG_STRIPED != 0;
                    self.progress_with(*vci, striped, flags & REQ_FLAG_DOORBELL != 0);
                }
                self.is_complete(*id)
            }
        }
    }

    /// MPI_Waitall.
    pub fn waitall(&self, reqs: impl IntoIterator<Item = Request>) -> Vec<Option<Vec<u8>>> {
        reqs.into_iter().map(|r| self.wait(r)).collect()
    }

    /// Blocking standard send.
    pub fn send(&self, comm: &Comm, dst: usize, tag: i32, data: &[u8]) {
        let r = self.isend(comm, dst, tag, data);
        self.wait(r);
    }

    /// Blocking synchronous send.
    pub fn ssend(&self, comm: &Comm, dst: usize, tag: i32, data: &[u8]) {
        let r = self.issend(comm, dst, tag, data);
        self.wait(r);
    }

    /// Blocking receive; returns the payload.
    pub fn recv(&self, comm: &Comm, src: Src, tag: Tag) -> Vec<u8> {
        let r = self.irecv(comm, src, tag);
        self.wait(r).expect("recv request must carry data")
    }
}
