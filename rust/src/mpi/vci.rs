//! Virtual communication interfaces: the paper's central abstraction.
//!
//! A VCI is an abstract communication stream bound 1:1 to a NIC hardware
//! context, holding its own matching engine, rendezvous state, request
//! cache, lightweight request, and RMA completion records — all protected
//! by the VCI's own lock (paper §4.2). The pool hands VCIs to communicators
//! and windows as they are created.
//!
//! # Per-message VCI striping (a per-communicator policy)
//!
//! With striping enabled **on a communicator's policy** (info keys at
//! creation — see `mpi::policy`; [`crate::mpi::VciStriping`] on the
//! process config is only the default), that communicator is no longer
//! pinned to its one assigned VCI for two-sided traffic: every `isend`
//! picks a stripe VCI (round-robin or hashed per message) from the pool's
//! stripe lanes and targets the mirror context on the receiver, so a
//! single hot communicator can use all hardware contexts. Lanes assigned
//! to `striping=off` (ordered) or endpoints communicators are *pinned out
//! of the stripe-lane set*, so hot and latency-ordered communicators
//! coexist in one process without the striped bulk queuing on the ordered
//! lanes. On the receive side a striped envelope is matched by whichever
//! VCI polled it, through the communicator's per-source **matching
//! shards** (`mpi::shard`, shaped by the comm's policy) rather than this
//! VCI's own [`MatchingState`] — stripe VCIs contribute injection,
//! polling, *and* matching parallelism; striped receive posts allocate
//! their request from the stream's shard-anchored VCI cache, not the home
//! VCI. The pool also carries an rx [`RxDoorbell`]: delivery rings the
//! polled VCI's bit, and the doorbell-gated striped sweep (for comms
//! whose policy opts in) skips VCIs (or the whole sweep) with nothing
//! queued. See `mpi::matching` for the ordering story.
//!
//! RMA windows stripe the same way under a per-window policy
//! (`mpi::policy::WinPolicy`, resolved at `win_create_with_info`): a
//! striped window's puts/accumulates — and gets — fan out over the
//! stripe lanes and complete via per-lane issue/ack counters held in
//! each lane's [`VciState`] (`rma_issued`/`rma_acked`) instead of the
//! per-VCI `acked` set — see `mpi::rma` for the completion model and
//! decision table.
//!
//! Collectives add a third lane-mapping layer (`vcmpi_collectives` on
//! the comm policy — see `mpi::collectives`): a `dedicated` comm
//! reserves one lane for collective traffic through the same pin
//! machinery ordered comms use (so striped bulk never queues ahead of an
//! allreduce step), while a `striped` collectives policy spreads each
//! collective's per-segment tags over the pool by the pure envelope hash
//! — matched per VCI, no reorder stage, because the internal collective
//! tag space never posts wildcards.
//!
//! # Serial execution streams (single-writer VCIs)
//!
//! The fourth mode is the MPIX-Stream endgame (`vcmpi_stream=local` /
//! `MpiProc::stream_bind`): one thread declares itself the *sole* driver
//! of a communicator, binds itself to the comm's VCI, and the lane flips
//! into **single-writer** mode — [`Vci::with_state_stream`] hands out the
//! state with *no lock at all* (a plain cell access), `MPI_Wait` polls
//! only the owned lane, and requests recycle through a thread-local
//! freelist instead of the shared per-VCI cache. The lane is pinned out
//! of the stripe set by the same refcounts ordered comms use, and no
//! progress thread may sweep it (`stripe_poll_target` and the global
//! round both skip stream-owned lanes) — the owner is the only thread
//! that ever touches the state, which is what makes the lock elision
//! sound. A SimSan-integrated tripwire panics deterministically on any
//! cross-thread state entry, and (under the `simsan` feature) every
//! stream op touches a *tracked* witness cell so the vector-clock race
//! checker independently verifies that ownership handoffs (bind/unbind)
//! carry real happens-before edges.
//!
//! Decision table — when to use which lane mapping:
//!
//! | traffic shape | policy |
//! |---------------|--------|
//! | many threads, one hot comm, bulk | striping (`rr`/`hash`) + shards + doorbell |
//! | one thread, one comm, rate/latency-critical | `vcmpi_stream=local` — zero locks per op |
//! | one thread per comm, several comms | ordered comms (pinned lanes) or a stream each |
//! | collectives head-of-line sensitive | `vcmpi_collectives=dedicated` |

use std::cell::UnsafeCell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::fabric::RxDoorbell;
use crate::platform::{Backend, PMutex, PMutexGuard};
use crate::sim::CacheLine;

use super::config::{CsMode, MpiConfig, VciPolicy};
use super::instrument::{HostMutex, LockClass};
use super::matching::MatchingState;
use super::request::ReqId;
use super::shard::CommMatch;

/// Sender-side record of a rendezvous in flight.
#[derive(Clone, Debug)]
pub struct PendingSend {
    pub data: Vec<u8>,
    pub comm_id: u64,
    pub dst_rank: usize,
    pub tag: i32,
    pub req: ReqId,
}

/// Mutable state owned by one VCI (guarded by the VCI lock).
#[derive(Default)]
pub struct VciState {
    pub matching: MatchingState,
    /// Rendezvous payloads waiting for CTS, by send handle (= request id).
    pub pending_sends: HashMap<u64, PendingSend>,
    /// Per-VCI request cache (paper §4.3).
    pub req_cache: Vec<ReqId>,
    /// Serial-execution-stream request freelist — the lock-free twin of
    /// `req_cache`, touched only through [`Vci::with_state_stream`] while
    /// the lane is stream-owned (thread-local by the single-writer
    /// contract, not by storage). Drained back to the shared slab at
    /// `stream_unbind`; `MpiProc::stream_freelist_outstanding` accounts
    /// every id checked out into it.
    pub stream_freelist: Vec<ReqId>,
    /// Per-VCI lightweight request refcount. Host atomic for correctness
    /// on the native backend, but *modeled* as a plain counter protected by
    /// the VCI lock — no atomic/cacheline cost is charged (the point of the
    /// per-VCI replication, paper §4.3).
    pub lw_refs: std::sync::atomic::AtomicU64,
    /// RMA: flush handles acked by targets (software-RMA completion,
    /// ordered windows).
    pub acked: HashSet<u64>,
    /// RMA striped-completion issue counters: cumulative striped
    /// puts/accumulates injected *from this VCI (= stripe lane)* per
    /// (window id, target process). Bumped under this VCI's lock at
    /// injection; `win_flush` records the post-increment value as its
    /// per-thread watermark. Purged when the window is freed.
    pub rma_issued: HashMap<(u64, usize), u64>,
    /// RMA striped-completion ack counters: cumulative
    /// [`crate::fabric::Payload::RmaAckCount`] acks *received on this VCI*
    /// per (window id, target process). Acks return to the issuing lane's
    /// context, so issued/acked for one (window, target, lane) live in the
    /// same [`VciState`] — per-lane replicated state, no shared cache
    /// line, and flush no longer funnels through one VCI's `acked` set.
    pub rma_acked: HashMap<(u64, usize), u64>,
    /// RMA: get replies that have arrived, by get handle.
    pub get_done: HashMap<u64, Vec<u8>>,
    /// RMA: fetch-and-op replies.
    pub fetch_done: HashMap<u64, Vec<u8>>,
    /// RMA passive target: lock grants that have arrived
    /// ([`crate::fabric::Payload::RmaLockGrant`]), by lock handle —
    /// `win_lock` waits here on the window's home VCI, exactly like
    /// `fetch_and_op` waits `fetch_done`. Purged with the window's
    /// counters at `win_free` (handles embed the window id).
    pub lock_granted: HashSet<u64>,
    /// Send-side FIFO sequence per (comm, dst_rank).
    pub send_seq: HashMap<(u64, usize), u64>,
    /// Cached handles to per-communicator sharded matching engines, so
    /// the striped arrival path resolves its engine under this VCI's lock
    /// instead of the process-wide table mutex on every message (the
    /// table is consulted once per (VCI, comm)). Entries are populated
    /// from the policy table and invalidated by `MpiProc` when a
    /// communicator is freed or its registered policy replaces a lazily
    /// created engine; finalize asserts no freed comm id remains here.
    pub match_cache: HashMap<u64, Arc<CommMatch>>,
}

/// How VCI state access is guarded for this call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Guard {
    /// Take this VCI's lock (FG mode).
    VciLock,
    /// A coarser lock (the Global CS) is already held — access directly.
    GlobalHeld,
    /// No thread safety at all (Fig. 12 mode / single-threaded processes).
    None,
}

struct StateCell(UnsafeCell<VciState>);
// SAFETY: access is serialized either by the VCI lock, the Global CS,
// (Guard::None) by the caller's guarantee of single-threaded / DES-serial
// execution, or — for stream-owned VCIs — by the single-writer ownership
// contract (`Vci::with_state_stream`: only the bound thread ever enters,
// enforced by the SimSan tripwire and the progress-sweep skips).
unsafe impl Sync for StateCell {}

/// `Vci::stream_owner` value meaning "not stream-owned". Thread tokens
/// (`proc::thread_token`) are small sim tids or `1<<32`-based native ids,
/// so `u64::MAX` can never collide with a real owner.
pub const STREAM_UNOWNED: u64 = u64::MAX;

/// One virtual communication interface.
pub struct Vci {
    pub idx: usize,
    /// Fabric hardware context this VCI is bound to.
    pub ctx_index: usize,
    /// THE VCI lock. May share a modeled cache line with neighbors when the
    /// pool is built without cache alignment (Fig. 8).
    lock: PMutex<()>,
    state: StateCell,
    /// Assigned to at least one live communicator/window?
    active: AtomicBool,
    /// Hard-failed (fault-plan context kill): the lane is quarantined,
    /// its state migrated to a survivor, and the pool redirect maps it
    /// away. Set once by `MpiProc::failover_vci`.
    failed: AtomicBool,
    /// Per-VCI progress bookkeeping: consecutive unsuccessful polls (drives
    /// the hybrid global-progress fallback).
    pub progress_failures: AtomicUsize,
    /// Lightweight-request releases parked by lock-free `MPI_Wait`s
    /// (paper Table 1: waiting on a lightweight request takes zero locks).
    /// Reconciled into `VciState::lw_refs` by the next VCI-locked
    /// operation; balance is asserted at finalize. Host atomic: the
    /// deferred-release trick is exactly what makes this access free on
    /// the modeled critical path.
    lw_deferred: std::sync::atomic::AtomicU64,
    /// Request frees parked without the VCI lock (striping only: the home
    /// VCI's lock is the hot resource, so completed requests are pushed
    /// here and absorbed into `VciState::req_cache` by the next locked
    /// entry instead of paying a dedicated lock acquisition each).
    deferred_frees: HostMutex<Vec<ReqId>>,
    /// Serial-stream single-writer owner: [`STREAM_UNOWNED`], or the
    /// owning thread's token (`proc::thread_token`). Host atomic — the
    /// modeled fast path never pays for it (ownership is checked with a
    /// relaxed load, and on the owner's path the check is a same-thread
    /// compare). Set/cleared by `MpiProc::stream_bind`/`stream_unbind`.
    stream_owner: std::sync::atomic::AtomicU64,
    /// SimSan happens-before witness for the single-writer fast path: a
    /// *tracked* plain cell bumped by every stream op and by every
    /// ownership transition (the transition touch happens under the VCI
    /// lock, whose release/acquire edges order successive owners). If a
    /// stream op ever runs without a real happens-before edge from the
    /// previous owner's accesses, the vector-clock checker reports a data
    /// race on this cell — independent of the owner-token tripwire.
    #[cfg(feature = "simsan")]
    stream_cell: crate::sim::SimCell<u64>,
}

impl Vci {
    fn new(idx: usize, ctx_index: usize, backend: Backend, line: Option<Arc<CacheLine>>) -> Self {
        let mut lock = PMutex::new(backend, ());
        if let Some(line) = line {
            lock = lock.on_line(line);
        }
        Vci {
            idx,
            ctx_index,
            lock,
            state: StateCell(UnsafeCell::new(VciState::default())),
            active: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            progress_failures: AtomicUsize::new(0),
            lw_deferred: std::sync::atomic::AtomicU64::new(0),
            deferred_frees: HostMutex::new(Vec::new()),
            stream_owner: std::sync::atomic::AtomicU64::new(STREAM_UNOWNED),
            #[cfg(feature = "simsan")]
            stream_cell: crate::sim::SimCell::new(0),
        }
    }

    /// The stream owner's thread token, or [`STREAM_UNOWNED`].
    pub fn stream_owner(&self) -> u64 {
        self.stream_owner.load(Ordering::Acquire)
    }

    /// Is this VCI in single-writer (stream) mode?
    pub fn is_stream_owned(&self) -> bool {
        self.stream_owner() != STREAM_UNOWNED
    }

    /// Is this VCI stream-owned by the thread with `token`?
    pub fn stream_owned_by(&self, token: u64) -> bool {
        self.stream_owner() == token
    }

    /// Flip this VCI into single-writer mode, owned by `token`. Double
    /// binding (by anyone, including the owner) is erroneous — a stream
    /// binding is exclusive until `stream_clear_owner`.
    pub fn stream_set_owner(&self, token: u64) {
        let prev = self.stream_owner.swap(token, Ordering::AcqRel);
        assert_eq!(
            prev, STREAM_UNOWNED,
            "VCI {} is already stream-owned by thread token {prev}; a lane carries at most one \
             serial execution stream (erroneous program)",
            self.idx
        );
    }

    /// Return this VCI to normal (locked) multi-writer mode.
    pub fn stream_clear_owner(&self) {
        self.stream_owner.store(STREAM_UNOWNED, Ordering::Release);
    }

    /// SimSan-integrated stream tripwire: any state entry on a
    /// stream-owned VCI from a thread other than the owner is a
    /// single-writer discipline violation and panics deterministically.
    /// Compiled out of `--no-default-features` bench builds.
    #[inline]
    fn stream_tripwire(&self) {
        #[cfg(feature = "simsan")]
        {
            let owner = self.stream_owner.load(Ordering::Relaxed);
            if owner != STREAM_UNOWNED {
                let me = super::proc::thread_token();
                assert!(
                    me == owner,
                    "SimSan: stream-owned VCI {} touched by thread token {me} (single-writer \
                     owner is token {owner}); cross-thread use of a serial execution stream is \
                     erroneous",
                    self.idx
                );
            }
        }
    }

    /// Bump the stream happens-before witness cell (tracked access: the
    /// SimSan race checker sees it). No-op without the `simsan` feature.
    #[cfg(feature = "simsan")]
    fn stream_hb_touch(&self) {
        *self.stream_cell.get() += 1;
    }

    /// Publish a stream-ownership transition: one locked state entry that
    /// touches the happens-before witness under the VCI lock, so SimSan
    /// sees bind/unbind as real release/acquire points between successive
    /// owners. Called by `stream_bind`/`stream_unbind` while the caller
    /// still holds (or is) the owner.
    pub fn stream_transition(&self, guard: Guard) {
        self.with_state(guard, |_st| {
            #[cfg(feature = "simsan")]
            self.stream_hb_touch();
        });
    }

    /// Park one lightweight-request release without entering the VCI
    /// critical section (`MPI_Wait` on a lightweight request takes no
    /// locks — paper Table 1). The next [`Vci::with_state`] drains it.
    pub fn defer_lightweight_release(&self) {
        self.lw_deferred.fetch_add(1, Ordering::Relaxed);
    }

    /// Park a completed request's free without entering the VCI critical
    /// section (striping's hot-home-lock relief; the cost of the shared
    /// push is charged by the caller). Absorbed by the next
    /// [`Vci::with_state`].
    pub fn defer_request_free(&self, id: ReqId) {
        self.deferred_frees.lock(LockClass::HostDeferredFrees).push(id);
    }

    /// Reconcile parked lightweight releases and request frees into the
    /// locked state. Runs at every state entry; free in modeled time
    /// (plain counter/list work under a lock that is already held).
    fn drain_deferred_lightweight(&self, st: &mut VciState) {
        let d = self.lw_deferred.swap(0, Ordering::Relaxed);
        if d != 0 {
            st.lw_refs.fetch_sub(d, std::sync::atomic::Ordering::Relaxed);
        }
        let mut f = self.deferred_frees.lock(LockClass::HostDeferredFrees);
        if !f.is_empty() {
            st.req_cache.append(&mut f);
        }
    }

    /// Run `f` with exclusive access to the VCI state, honoring the guard
    /// discipline of the configured critical-section mode.
    pub fn with_state<R>(&self, guard: Guard, f: impl FnOnce(&mut VciState) -> R) -> R {
        self.stream_tripwire();
        let _held: Option<PMutexGuard<'_, ()>> = match guard {
            Guard::VciLock => Some(self.lock.lock_class(LockClass::Vci)),
            Guard::GlobalHeld | Guard::None => None,
        };
        // SAFETY: serialized per the `Guard` contract (see StateCell).
        let st = unsafe { &mut *self.state.0.get() };
        self.drain_deferred_lightweight(st);
        f(st)
    }

    /// Attempt the same under `try_lock`; `None` if the VCI is busy.
    pub fn try_with_state<R>(&self, guard: Guard, f: impl FnOnce(&mut VciState) -> R) -> Option<R> {
        self.stream_tripwire();
        match guard {
            Guard::VciLock => {
                let g = self.lock.try_lock_class(LockClass::Vci)?;
                let st = unsafe { &mut *self.state.0.get() };
                self.drain_deferred_lightweight(st);
                let r = f(st);
                drop(g);
                Some(r)
            }
            Guard::GlobalHeld | Guard::None => {
                let st = unsafe { &mut *self.state.0.get() };
                self.drain_deferred_lightweight(st);
                Some(f(st))
            }
        }
    }

    /// The single-writer fast path: run `f` with the VCI state and **no
    /// lock at all** — a plain cell access in the modeled machine (zero
    /// lock acquisitions, zero atomics; the whole point of the stream
    /// mode, Table 1's streamed column). Sound only on the stream-owning
    /// thread: the lane is out of the stripe set, every progress sweep
    /// skips it, and the tripwire panics on any other thread entering
    /// through the locked paths. The owner releases directly, so there is
    /// no deferred state to drain here — `stream_bind`'s locked
    /// transition drained pre-bind leftovers, and anything a foreign
    /// thread parks mid-stream (a deferred lightweight release for a
    /// pre-bind request — the side-lists are host atomics, not state
    /// entries) is absorbed by `stream_unbind`'s transition.
    // lint:allow-stream-cell (audited single-writer access; see module doc)
    pub fn with_state_stream<R>(&self, f: impl FnOnce(&mut VciState) -> R) -> R {
        self.stream_tripwire();
        #[cfg(feature = "simsan")]
        self.stream_hb_touch();
        super::instrument::count_stream_op();
        // SAFETY: single-writer ownership (see StateCell and above).
        let st = unsafe { &mut *self.state.0.get() };
        f(st)
    }

    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Acquire)
    }

    /// Mark this lane hard-failed (its hardware context died).
    pub fn set_failed(&self) {
        self.failed.store(true, Ordering::Release);
    }

    /// Has this lane been failed over away from?
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }
}

/// The per-process VCI pool (paper §4.2's "VCI pool design").
pub struct VciPool {
    vcis: Vec<Arc<Vci>>,
    /// Free-list for the FirstComePool policy. Host mutex: pool maintenance
    /// happens at communicator/window creation, off the critical path; its
    /// modeled cost is charged explicitly by the callers.
    free: HostMutex<Vec<usize>>,
    rr_next: AtomicUsize,
    policy: VciPolicy,
    /// Pool-wide rx doorbell: bit `i` is rung while VCI `i`'s hardware
    /// context has messages queued. Installed onto the contexts by
    /// `MpiProc::init`; consulted by the doorbell-gated striped sweep.
    doorbell: Arc<RxDoorbell>,
    /// Lane-failover redirect: `redirect[i]` is the lane that now
    /// serves traffic logically addressed to lane `i` (identity until a
    /// failover). Checked via [`VciPool::resolve`] by every lane
    /// resolution; the fast path is one relaxed bool load.
    redirect: Vec<AtomicUsize>,
    /// True once any redirect is installed.
    any_redirect: AtomicBool,
}

/// Index of the fallback VCI (assigned to MPI_COMM_WORLD).
pub const FALLBACK_VCI: usize = 0;

impl VciPool {
    /// Build `n` VCIs bound to fabric contexts `ctx_indices[i]`.
    /// `cache_aligned=false` packs lock words two-per-modeled-line.
    pub fn new(
        backend: Backend,
        ctx_indices: &[usize],
        cache_aligned: bool,
        policy: VciPolicy,
    ) -> Self {
        let n = ctx_indices.len();
        assert!(n >= 1, "need at least the fallback VCI");
        let mut vcis = Vec::with_capacity(n);
        let mut shared_line: Option<Arc<CacheLine>> = None;
        for (i, &ctx) in ctx_indices.iter().enumerate() {
            let line = if backend == Backend::Sim {
                if cache_aligned {
                    Some(CacheLine::new())
                } else {
                    // Two adjacent VCI lock words per 64B line.
                    if i % 2 == 0 {
                        shared_line = Some(CacheLine::new());
                    }
                    shared_line.clone()
                }
            } else {
                None
            };
            vcis.push(Arc::new(Vci::new(i, ctx, backend, line)));
        }
        // VCI 0 is the fallback: never in the free pool, always active.
        vcis[FALLBACK_VCI].active.store(true, Ordering::Release);
        let free = (1..n).rev().collect();
        VciPool {
            vcis,
            free: HostMutex::new(free),
            rr_next: AtomicUsize::new(1),
            policy,
            doorbell: RxDoorbell::new(n),
            redirect: (0..n).map(AtomicUsize::new).collect(),
            any_redirect: AtomicBool::new(false),
        }
    }

    /// Resolve a lane index through the failover redirect table. The
    /// common (no failover ever happened) path is one relaxed load.
    #[inline]
    pub fn resolve(&self, idx: usize) -> usize {
        if !self.any_redirect.load(Ordering::Relaxed) {
            return idx;
        }
        self.redirect[idx].load(Ordering::Acquire)
    }

    /// Install a failover redirect `from → to`. Chains collapse so a
    /// double failover never leaves a lane pointing at a dead lane.
    pub fn set_redirect(&self, from: usize, to: usize) {
        assert_ne!(from, to, "lane cannot fail over to itself");
        for r in &self.redirect {
            if r.load(Ordering::Acquire) == from {
                r.store(to, Ordering::Release);
            }
        }
        self.redirect[from].store(to, Ordering::Release);
        self.any_redirect.store(true, Ordering::Release);
    }

    /// The pool-wide rx-nonempty doorbell (one bit per VCI).
    pub fn doorbell(&self) -> &Arc<RxDoorbell> {
        &self.doorbell
    }

    pub fn len(&self) -> usize {
        self.vcis.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vcis.is_empty()
    }

    pub fn get(&self, idx: usize) -> &Arc<Vci> {
        &self.vcis[idx]
    }

    pub fn all(&self) -> &[Arc<Vci>] {
        &self.vcis
    }

    /// Assign a VCI for a newly created communicator/window with id `id`.
    /// Falls back to [`FALLBACK_VCI`] when the pool is exhausted (paper
    /// §4.2) — the source of the Fig. 17 mapping-mismatch effect.
    pub fn assign(&self, id: u64) -> usize {
        let idx = match self.policy {
            VciPolicy::FirstComePool => {
                self.free.lock(LockClass::HostPoolFree).pop().unwrap_or(FALLBACK_VCI)
            }
            VciPolicy::RoundRobin => {
                if self.vcis.len() == 1 {
                    FALLBACK_VCI
                } else {
                    let k = self.rr_next.fetch_add(1, Ordering::AcqRel);
                    1 + (k - 1) % (self.vcis.len() - 1)
                }
            }
            VciPolicy::Hashed => {
                if self.vcis.len() == 1 {
                    FALLBACK_VCI
                } else {
                    // SplitMix-style scramble of the id.
                    let mut z = id.wrapping_add(0x9E3779B97F4A7C15);
                    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                    1 + (z % (self.vcis.len() as u64 - 1)) as usize
                }
            }
        };
        self.vcis[idx].active.store(true, Ordering::Release);
        idx
    }

    /// Return a VCI on communicator/window free. Only FirstComePool
    /// recycles; the fallback VCI is never recycled.
    pub fn release(&self, idx: usize) {
        if idx == FALLBACK_VCI {
            return;
        }
        if self.policy == VciPolicy::FirstComePool {
            self.vcis[idx].active.store(false, Ordering::Release);
            self.free.lock(LockClass::HostPoolFree).push(idx);
        }
    }
}

/// Resolve the guard discipline for a VCI access given the configuration.
pub fn guard_for(cfg: &MpiConfig, backend: Backend) -> Guard {
    if cfg.unsafe_no_thread_safety && backend == Backend::Sim {
        Guard::None
    } else {
        match cfg.cs_mode {
            CsMode::Global => Guard::GlobalHeld,
            CsMode::Fg => Guard::VciLock,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize, policy: VciPolicy) -> VciPool {
        let ctxs: Vec<usize> = (0..n).collect();
        VciPool::new(Backend::Native, &ctxs, true, policy)
    }

    #[test]
    fn first_come_assigns_then_falls_back() {
        let p = pool(3, VciPolicy::FirstComePool);
        let a = p.assign(100);
        let b = p.assign(101);
        assert_ne!(a, FALLBACK_VCI);
        assert_ne!(b, FALLBACK_VCI);
        assert_ne!(a, b);
        // Pool (vcis 1,2) exhausted -> fallback.
        assert_eq!(p.assign(102), FALLBACK_VCI);
        p.release(a);
        assert_eq!(p.assign(103), a);
    }

    #[test]
    fn round_robin_cycles() {
        let p = pool(3, VciPolicy::RoundRobin);
        let seq: Vec<usize> = (0..4).map(|i| p.assign(i)).collect();
        assert_eq!(seq, vec![1, 2, 1, 2]);
    }

    #[test]
    fn hashed_is_deterministic() {
        let p = pool(4, VciPolicy::Hashed);
        assert_eq!(p.assign(42), p.assign(42));
    }

    #[test]
    fn single_vci_pool_always_fallback() {
        let p = pool(1, VciPolicy::FirstComePool);
        assert_eq!(p.assign(1), FALLBACK_VCI);
        let p = pool(1, VciPolicy::RoundRobin);
        assert_eq!(p.assign(1), FALLBACK_VCI);
    }

    #[test]
    fn with_state_grants_exclusive_access() {
        let p = pool(2, VciPolicy::FirstComePool);
        let v = p.get(1);
        v.with_state(Guard::VciLock, |st| {
            st.lw_refs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        let refs =
            v.with_state(Guard::None, |st| st.lw_refs.load(std::sync::atomic::Ordering::Relaxed));
        assert_eq!(refs, 1);
    }

    #[test]
    fn deferred_lightweight_release_drains_on_next_state_entry() {
        let p = pool(2, VciPolicy::FirstComePool);
        let v = p.get(1);
        v.with_state(Guard::None, |st| {
            st.lw_refs.fetch_add(3, std::sync::atomic::Ordering::Relaxed);
        });
        // Two lock-free waits park their releases...
        v.defer_lightweight_release();
        v.defer_lightweight_release();
        // ...and the next locked operation reconciles them.
        let refs = v.with_state(Guard::VciLock, |st| {
            st.lw_refs.load(std::sync::atomic::Ordering::Relaxed)
        });
        assert_eq!(refs, 1);
        v.defer_lightweight_release();
        let refs =
            v.with_state(Guard::None, |st| st.lw_refs.load(std::sync::atomic::Ordering::Relaxed));
        assert_eq!(refs, 0);
    }

    #[test]
    fn fallback_never_recycled() {
        let p = pool(2, VciPolicy::FirstComePool);
        p.release(FALLBACK_VCI);
        assert!(p.get(FALLBACK_VCI).is_active());
    }

    #[test]
    fn stream_owner_lifecycle() {
        let p = pool(2, VciPolicy::FirstComePool);
        let v = p.get(1);
        assert!(!v.is_stream_owned());
        v.stream_set_owner(7);
        assert!(v.is_stream_owned());
        assert!(v.stream_owned_by(7) && !v.stream_owned_by(8));
        assert_eq!(v.stream_owner(), 7);
        v.stream_clear_owner();
        assert!(!v.is_stream_owned());
        assert_eq!(v.stream_owner(), STREAM_UNOWNED);
    }

    #[test]
    #[should_panic(expected = "already stream-owned")]
    fn double_stream_bind_is_erroneous() {
        let p = pool(2, VciPolicy::FirstComePool);
        let v = p.get(1);
        v.stream_set_owner(7);
        v.stream_set_owner(8);
    }

    #[test]
    fn redirect_resolves_and_collapses_chains() {
        let p = pool(4, VciPolicy::FirstComePool);
        assert_eq!(p.resolve(2), 2, "identity before any failover");
        p.set_redirect(2, 3);
        assert_eq!(p.resolve(2), 3);
        assert_eq!(p.resolve(3), 3);
        // Second failover: 3 dies too; 2's redirect must follow.
        p.set_redirect(3, 1);
        assert_eq!(p.resolve(2), 1);
        assert_eq!(p.resolve(3), 1);
        assert!(!p.get(2).is_failed(), "failed flag is set by the proc, not the pool");
    }

    #[test]
    fn stream_fast_path_reaches_state_without_lock() {
        let p = pool(2, VciPolicy::FirstComePool);
        let v = p.get(1);
        // Native backend, current thread as owner: the fast path must see
        // the same state the locked path wrote.
        v.stream_set_owner(crate::mpi::proc::thread_token());
        v.with_state_stream(|st| st.req_cache.push(42));
        let got = v.with_state_stream(|st| st.req_cache.pop());
        assert_eq!(got, Some(42));
        v.stream_clear_owner();
    }
}
