//! `MpiProc` — one MPI process: VCI pool, request slab, communicator and
//! window tables, the Global critical section, progress hooks, and the
//! connection-establishment logic of MPI_Init/Finalize (paper §4.2).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::fabric::{Interconnect, ProcFabric};
use crate::platform::{padvance, pyield, Backend, PMutex};
use crate::sim::CostModel;

use super::comm::{Comm, CommKind};
use super::config::{CsMode, MpiConfig, VciStriping};
use super::instrument::{count_lock, LockClass};
use super::request::{RequestSlab, DEFAULT_SLAB_CAPACITY};
use super::rma::Window;
use super::shard::{CommMatch, EpochStats};
use super::vci::{guard_for, Guard, VciPool, VciState, FALLBACK_VCI};

thread_local! {
    static ACTIVE_COSTS: RefCell<Option<Arc<CostModel>>> = const { RefCell::new(None) };
    static THREAD_TOKEN: RefCell<Option<u64>> = const { RefCell::new(None) };
}

/// Install the cost model for the calling thread (done by the world runner
/// and test harnesses before any MPI call).
pub fn set_active_costs(c: Arc<CostModel>) {
    ACTIVE_COSTS.with(|a| *a.borrow_mut() = Some(c));
}

pub fn active_costs() -> Arc<CostModel> {
    ACTIVE_COSTS
        .with(|a| a.borrow().clone())
        .unwrap_or_else(|| Arc::new(CostModel::default()))
}

/// A stable per-thread token for per-thread RMA completion tracking.
pub fn thread_token() -> u64 {
    if crate::sim::in_sim() {
        return crate::sim::current_tid() as u64;
    }
    THREAD_TOKEN.with(|t| {
        let mut t = t.borrow_mut();
        if t.is_none() {
            static NEXT: AtomicU64 = AtomicU64::new(1 << 32);
            *t = Some(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        t.unwrap()
    })
}

/// MPI progress hooks (MPICH/CH4 maintains two — paper §4.1). Each has its
/// own lock, acquired per progress-engine iteration in FG mode.
pub struct ProgressHook {
    pub lock: PMutex<()>,
    pub active: AtomicBool,
}

/// One MPI process.
pub struct MpiProc {
    pub cfg: MpiConfig,
    pub fabric: ProcFabric,
    pub backend: Backend,
    pub costs: Arc<CostModel>,
    /// Set by `init()`.
    vcis: OnceLock<VciPool>,
    pub slab: RequestSlab,
    /// The Global critical section (CsMode::Global).
    pub global_cs: PMutex<()>,
    pub hooks: [ProgressHook; 2],
    /// Live communicators (host table; creation is off the critical path).
    comms: Mutex<Vec<Comm>>,
    pub(super) windows: Mutex<Vec<Arc<Window>>>,
    next_comm_id: AtomicU64,
    pub(super) next_win_id: AtomicU64,
    /// Signals service threads (PSM2-style progress) to stop.
    pub finalized: AtomicBool,
    pub initialized: AtomicBool,
    /// Striping: shared per-(comm, dst) send-stream sequence counters.
    /// One logical FIFO stream per destination even though messages fan
    /// out across VCIs — the receiver's reorder stage keys off it. Host
    /// mutex; the modeled cost of the shared fetch-add is charged at the
    /// call site ([`MpiProc::next_stripe_seq`]).
    stripe_seq: Mutex<HashMap<(u64, usize), u64>>,
    /// Striping: round-robin cursor for per-message send VCI selection.
    stripe_rr: AtomicUsize,
    /// Striping: rotation cursor for progress polling (a striped comm's
    /// traffic lands on every VCI, so waiters sweep the whole pool).
    stripe_poll_rr: AtomicUsize,
    /// Sharded matching engines, one per communicator seen carrying
    /// striped traffic (created lazily; see `mpi::shard`). Host mutex: the
    /// lookup models a comm-id indexed table walk, free in virtual time.
    match_engines: Mutex<HashMap<u64, Arc<CommMatch>>>,
    /// Doorbell-gated sweeps skipped outright (no rx bit rung).
    pub(super) doorbell_skips: AtomicU64,
    /// Context polls that found nothing ready.
    pub(super) empty_polls: AtomicU64,
    /// Consecutive doorbell skips (drives the paranoid global-round
    /// fallback, mirroring the per-VCI hybrid progress counter).
    pub(super) skip_streak: AtomicUsize,
    /// Counted diagnostic: stale, duplicate, or malformed wire control
    /// messages dropped by the progress engine instead of panicking
    /// (e.g. a CTS for an unknown rendezvous send).
    pub(super) stale_ctrl_drops: AtomicU64,
}

impl MpiProc {
    /// Construct the (uninitialized) process. Call [`MpiProc::init`] from
    /// exactly one of its threads before communicating.
    pub fn new(fabric: ProcFabric, cfg: MpiConfig) -> Arc<MpiProc> {
        let backend = fabric.backend();
        let costs = fabric.costs().clone();
        Arc::new(MpiProc {
            cfg,
            backend,
            costs,
            vcis: OnceLock::new(),
            slab: RequestSlab::new(backend, DEFAULT_SLAB_CAPACITY),
            global_cs: PMutex::new(backend, ()),
            hooks: [
                ProgressHook { lock: PMutex::new(backend, ()), active: AtomicBool::new(false) },
                ProgressHook { lock: PMutex::new(backend, ()), active: AtomicBool::new(false) },
            ],
            comms: Mutex::new(Vec::new()),
            windows: Mutex::new(Vec::new()),
            next_comm_id: AtomicU64::new(1),
            next_win_id: AtomicU64::new(1),
            finalized: AtomicBool::new(false),
            initialized: AtomicBool::new(false),
            stripe_seq: Mutex::new(HashMap::new()),
            stripe_rr: AtomicUsize::new(0),
            stripe_poll_rr: AtomicUsize::new(0),
            match_engines: Mutex::new(HashMap::new()),
            doorbell_skips: AtomicU64::new(0),
            empty_polls: AtomicU64::new(0),
            skip_streak: AtomicUsize::new(0),
            stale_ctrl_drops: AtomicU64::new(0),
            fabric,
        })
    }

    pub fn rank(&self) -> usize {
        self.fabric.proc
    }

    pub fn nprocs(&self) -> usize {
        self.fabric.nprocs()
    }

    pub fn interconnect(&self) -> Interconnect {
        self.fabric.interconnect()
    }

    pub fn vcis(&self) -> &VciPool {
        self.vcis.get().expect("MpiProc::init not called")
    }

    pub fn guard(&self) -> Guard {
        guard_for(&self.cfg, self.backend)
    }

    /// Enter the Global critical section if configured (no-op in FG mode).
    /// Returns a guard to hold for the duration of the MPI call.
    pub fn enter_cs(&self) -> Option<crate::platform::PMutexGuard<'_, ()>> {
        if self.cfg.unsafe_no_thread_safety && self.backend == Backend::Sim {
            return None;
        }
        match self.cfg.cs_mode {
            CsMode::Global => {
                count_lock(LockClass::Global);
                Some(self.global_cs.lock())
            }
            CsMode::Fg => None,
        }
    }

    /// MPI_Init: open hardware contexts (one per requested VCI, bounded by
    /// the node's budget), build the VCI pool, and establish connections:
    /// PMI-style out-of-band exchange for the fallback VCI, then an
    /// allgather of the remaining VCI addresses *over* the fallback VCI
    /// (paper §4.2 "Connection establishment" — the Fig. 4 overhead).
    pub fn init(self: &Arc<Self>) {
        assert!(!self.initialized.load(Ordering::Acquire), "double init");
        let mut ctx_indices = Vec::new();
        for _ in 0..self.cfg.num_vcis.max(1) {
            match self.fabric.open_context() {
                Some((idx, _ctx)) => ctx_indices.push(idx),
                None => break, // hardware exhausted: smaller pool
            }
        }
        assert!(
            !ctx_indices.is_empty(),
            "node out of hardware contexts for even the fallback VCI"
        );
        let pool = VciPool::new(
            self.backend,
            &ctx_indices,
            self.cfg.cache_aligned_vcis,
            self.cfg.vci_policy,
        );
        // Wire the pool's rx doorbell into each VCI's hardware context so
        // delivery rings bit `i` and the striped sweep can skip idle VCIs.
        for (i, &ctx_idx) in ctx_indices.iter().enumerate() {
            self.fabric.context(self.rank(), ctx_idx).install_doorbell(pool.doorbell().clone(), i);
        }
        self.vcis.set(pool).ok().expect("init raced");

        // PMI exchange of fallback addresses: every rank inserts every other
        // rank's fallback address into its address vector. PMI is an
        // out-of-band rendezvous — it cannot complete until every process
        // has opened (and published) its fallback context, so wait for
        // that before the in-band allgather below.
        for p in 0..self.nprocs() {
            if p != self.rank() {
                while self.fabric.open_count(p) == 0 {
                    padvance(self.backend, 200); // PMI poll interval
                    pyield(self.backend);
                }
                self.fabric.insert_address();
            }
        }
        self.initialized.store(true, Ordering::Release);
        // Address allgather for the remaining VCIs rides over the fallback
        // VCI (world communicator), exactly as the paper does it.
        let world = self.comm_world();
        let my_nvcis = self.vcis().len() as u64;
        let counts = self.allgather_u64(&world, my_nvcis);
        for (p, &n) in counts.iter().enumerate() {
            if p != self.rank() {
                for _ in 0..n.saturating_sub(1) {
                    self.fabric.insert_address();
                }
            }
        }
        self.barrier(&world);
    }

    /// MPI_Finalize: drain, tear down contexts (cost grows with the number
    /// of VCIs — Fig. 4's finalize series), release service threads.
    pub fn finalize(self: &Arc<Self>) {
        let world = self.comm_world();
        self.barrier(&world);
        // Lightweight-request refcounts must balance once every thread has
        // quiesced: each immediate `isend` acquired one reference and each
        // `wait` released one (for per-VCI replication the release was
        // deferred; entering the state below drains it first). An
        // imbalance here means a leaked reference — exactly the bug the
        // deferred-drain path used to have.
        {
            let _cs = self.enter_cs();
            if self.cfg.per_vci_lightweight {
                let guard = self.guard();
                for i in 0..self.vcis().len() {
                    let v = self.vcis().get(i).clone();
                    let refs = v.with_state(guard, |st| {
                        st.lw_refs.load(std::sync::atomic::Ordering::Relaxed)
                    });
                    assert_eq!(
                        refs, 0,
                        "VCI {i}: {refs} lightweight request refs leaked at finalize"
                    );
                }
            } else {
                let refs = self.slab.global_lightweight_refs.load();
                assert_eq!(refs, 0, "{refs} global lightweight request refs leaked at finalize");
            }
        }
        let n = self.vcis().len();
        for i in 0..n {
            self.fabric.close_context(self.vcis().get(i).ctx_index);
        }
        self.finalized.store(true, Ordering::Release);
    }

    /// MPI_COMM_WORLD: rank = process id, mapped to the fallback VCI.
    pub fn comm_world(&self) -> Comm {
        Comm {
            id: 0,
            vci: FALLBACK_VCI,
            size: self.nprocs(),
            rank: self.rank(),
            kind: CommKind::Procs,
        }
    }

    /// Allocate the next communicator id (shared by dup and endpoint
    /// creation so that symmetric collective creation orders yield
    /// identical ids on every process).
    pub(super) fn alloc_comm_id(&self) -> u64 {
        self.next_comm_id.fetch_add(1, Ordering::AcqRel)
    }

    /// MPI_Comm_dup: a new communicator with its own VCI from the pool
    /// (or the fallback when the pool is empty). Collective: call on every
    /// process in creation order; assignment is symmetric because pools
    /// start identical and assignment order matches.
    pub fn comm_dup(&self, parent: &Comm) -> Comm {
        let id = self.alloc_comm_id();
        padvance(self.backend, self.costs.instructions(200)); // comm bookkeeping
        let vci = self.vcis().assign(id);
        let c = Comm { id, vci, size: parent.size, rank: parent.rank, kind: parent.kind.clone() };
        self.comms.lock().unwrap_or_else(|e| e.into_inner()).push(c.clone());
        c
    }

    /// MPI_Comm_free: return the VCI to the pool.
    pub fn comm_free(&self, comm: Comm) {
        self.vcis().release(comm.vci);
        let mut t = self.comms.lock().unwrap_or_else(|e| e.into_inner());
        t.retain(|c| c.id != comm.id);
    }

    /// Resolve a communicator rank to (target process, target ctx index).
    pub fn route(&self, comm: &Comm, rank: usize) -> (usize, usize) {
        match &comm.kind {
            CommKind::Procs => {
                let proc = rank;
                let remote_ctxs = self.fabric.open_count(proc).max(1);
                (proc, comm.vci % remote_ctxs)
            }
            CommKind::Endpoints { per_proc, vcis } => {
                let proc = rank / per_proc;
                let ep = rank % per_proc;
                let remote_ctxs = self.fabric.open_count(proc).max(1);
                (proc, vcis[ep] % remote_ctxs)
            }
        }
    }

    /// The local VCI index an operation on `comm` (issued by the calling
    /// thread, in the given role) maps to.
    pub fn comm_vci(&self, comm: &Comm, my_endpoint: Option<usize>) -> usize {
        match &comm.kind {
            CommKind::Procs => comm.vci % self.vcis().len(),
            CommKind::Endpoints { vcis, .. } => {
                let ep = my_endpoint.expect("endpoint comms require an endpoint identity");
                vcis[ep] % self.vcis().len()
            }
        }
    }

    /// MPI-4.0 hint path (paper §7): with `mpi_assert_no_any_source` +
    /// `mpi_assert_no_any_tag` asserted, traffic within ONE communicator
    /// may spread over VCIs by its fully-specified envelope — matching
    /// stays correct because both sides can compute the same stream from
    /// (comm, source rank, tag). Falls back to the communicator's VCI when
    /// the hints are not asserted (or with a single-VCI pool).
    pub fn vci_for_envelope(&self, comm: &Comm, src_rank: usize, tag: i32) -> usize {
        if comm.is_endpoints()
            || !(self.cfg.hints.no_any_source && self.cfg.hints.no_any_tag)
            || self.vcis().len() <= 1
        {
            return self.comm_vci(comm, None);
        }
        // SplitMix-style scramble of the full envelope.
        let z = crate::util::mix64(
            comm.id
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add((src_rank as u64) << 32)
                .wrapping_add(tag as u32 as u64),
        );
        1 + (z % (self.vcis().len() as u64 - 1)) as usize
    }

    /// Does per-message VCI striping apply to two-sided traffic on `comm`?
    /// Endpoints communicators are excluded (each endpoint IS a dedicated
    /// VCI — striping would defeat their contract). Deliberately NOT a
    /// function of the local pool size: the predicate decides whether
    /// receives post into the sharded engine, and it must match the
    /// sender's decision to mark envelopes striped even when one side's
    /// hardware granted fewer contexts (a single-VCI pool then stripes
    /// degenerately onto its one lane).
    pub fn striping_active(&self, comm: &Comm) -> bool {
        self.cfg.vci_striping != VciStriping::Off && !comm.is_endpoints()
    }

    /// The sharded matching engine for a striped communicator (created on
    /// first use; all two-sided traffic of a striped comm funnels here
    /// instead of the per-VCI engines).
    pub fn comm_match(&self, comm_id: u64) -> Arc<CommMatch> {
        let mut table = self.match_engines.lock().unwrap_or_else(|e| e.into_inner());
        table
            .entry(comm_id)
            .or_insert_with(|| {
                CommMatch::new(
                    self.backend,
                    comm_id,
                    self.cfg.match_shards,
                    self.cfg.wildcard_epoch_linger,
                )
            })
            .clone()
    }

    /// [`MpiProc::comm_match`] through the calling VCI's cache: the hot
    /// striped paths run with a VCI's state held anyway, so the engine
    /// handle is resolved there and the process-wide table is touched
    /// only on the first message a VCI sees for a communicator.
    pub(super) fn cached_comm_match(&self, st: &mut VciState, comm_id: u64) -> Arc<CommMatch> {
        st.match_cache.entry(comm_id).or_insert_with(|| self.comm_match(comm_id)).clone()
    }

    /// Next sequence number of the (comm, dst) striped send stream. The
    /// counter is shared by every thread and VCI of this process — that is
    /// what makes the stream a single FIFO the receiver can restore.
    /// Modeled as a shared atomic fetch-add: one RMW plus a cache-line
    /// transfer (the line ping-pongs between sender threads).
    pub(super) fn next_stripe_seq(&self, comm_id: u64, dst: usize) -> u64 {
        padvance(self.backend, self.costs.atomic_rmw + self.costs.cacheline_transfer);
        let mut t = self.stripe_seq.lock().unwrap_or_else(|e| e.into_inner());
        let e = t.entry((comm_id, dst)).or_insert(0);
        *e += 1;
        *e
    }

    /// Stripe VCI for one message. Round-robin walks the pool with a
    /// process-wide cursor; hashed scrambles (comm, dst, seq) so a message
    /// keeps its VCI deterministically without shared state. Both exclude
    /// the fallback VCI 0 (like the hinted envelope spread): it is the
    /// shared lane every pool-exhausted communicator funnels through, so
    /// striping onto it would contend with funneled traffic.
    pub(super) fn stripe_vci(&self, comm: &Comm, dst: usize, seq: u64) -> usize {
        let n = self.vcis().len();
        if n <= 1 {
            // Degenerate pool (hardware granted one context): stripe onto
            // the only lane. The envelope is still marked striped so both
            // sides agree on the matching path.
            return FALLBACK_VCI;
        }
        match self.cfg.vci_striping {
            VciStriping::RoundRobin => {
                1 + self.stripe_rr.fetch_add(1, Ordering::Relaxed) % (n - 1)
            }
            VciStriping::HashedByRequest => {
                let z = crate::util::mix64(
                    comm.id
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add((dst as u64) << 32)
                        .wrapping_add(seq),
                );
                1 + (z % (n as u64 - 1)) as usize
            }
            VciStriping::Off => self.comm_vci(comm, None),
        }
    }

    /// Which VCI a progress call on behalf of a request mapped to
    /// `req_vci` should poll. With striping on, a striped communicator's
    /// traffic lands on every VCI, so waiters sweep the pool round-robin
    /// (pinning to the request's VCI could starve a stream whose
    /// gap-filling message sits on another context); otherwise the
    /// request's own VCI, per the configured progress model.
    ///
    /// With `rx_doorbell` the sweep consults the pool's rx-nonempty
    /// bitmask: the rotation lands on the next VCI whose doorbell is rung,
    /// and `None` means *no* VCI has anything queued — the caller skips
    /// the poll entirely instead of paying an empty CQ read per VCI.
    pub(super) fn stripe_poll_target(&self, req_vci: usize) -> Option<usize> {
        let n = self.vcis().len();
        if self.cfg.vci_striping == VciStriping::Off || n <= 1 {
            return Some(req_vci);
        }
        let cursor = self.stripe_poll_rr.fetch_add(1, Ordering::Relaxed) % n;
        if !self.cfg.rx_doorbell {
            return Some(cursor);
        }
        self.vcis().doorbell().next_set(cursor, n)
    }

    /// Stale/duplicate/malformed wire control messages dropped so far
    /// (instead of panicking). Diagnostic counter.
    pub fn stale_ctrl_drop_count(&self) -> u64 {
        self.stale_ctrl_drops.load(Ordering::Relaxed)
    }

    /// Reorder-stage diagnostics summed over all VCIs *and* all sharded
    /// communicator engines: (duplicate-seq drops, striped arrivals
    /// currently parked).
    pub fn reorder_stats(&self) -> (u64, usize) {
        let _cs = self.enter_cs();
        let guard = self.guard();
        let mut dups = 0u64;
        let mut parked = 0usize;
        for i in 0..self.vcis().len() {
            let v = self.vcis().get(i).clone();
            let (d, p) = v.with_state(guard, |st| {
                (st.matching.dup_seq_drops(), st.matching.reorder_parked())
            });
            dups += d;
            parked += p;
        }
        let engines: Vec<Arc<CommMatch>> = {
            let table = self.match_engines.lock().unwrap_or_else(|e| e.into_inner());
            table.values().cloned().collect()
        };
        for cm in engines {
            let (d, p) = cm.reorder_stats();
            dups += d;
            parked += p;
        }
        (dups, parked)
    }

    /// Wildcard-epoch statistics summed over this process's sharded
    /// communicator engines.
    pub fn epoch_stats(&self) -> EpochStats {
        let table = self.match_engines.lock().unwrap_or_else(|e| e.into_inner());
        let mut total = EpochStats::default();
        for cm in table.values() {
            let s = cm.epoch_stats();
            total.flips += s.flips;
            total.unflips += s.unflips;
            total.wildcard_posts += s.wildcard_posts;
        }
        total
    }

    /// Striped sweeps skipped because no rx doorbell was rung.
    pub fn doorbell_skip_count(&self) -> u64 {
        self.doorbell_skips.load(Ordering::Relaxed)
    }

    /// Context polls that found nothing ready.
    pub fn empty_poll_count(&self) -> u64 {
        self.empty_polls.load(Ordering::Relaxed)
    }

    /// Cooperative yield used inside progress/wait loops.
    pub fn relax(&self) {
        pyield(self.backend);
    }
}
