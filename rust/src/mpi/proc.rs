//! `MpiProc` — one MPI process: VCI pool, request slab, communicator and
//! window tables, the Global critical section, progress hooks, and the
//! connection-establishment logic of MPI_Init/Finalize (paper §4.2).

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::fabric::{Interconnect, ProcFabric};
use crate::platform::{padvance, pnow, pyield, Backend, PMutex};
use crate::sim::CostModel;

use super::comm::{Comm, CommKind};
use super::config::{CsMode, MpiConfig, VciStriping};
use super::instrument::{HostMutex, LockClass};
use super::policy::{CollectivesMode, CommPolicy, Info, WinPolicy, MAX_COLL_SEGMENTS};
use super::request::{RequestSlab, DEFAULT_SLAB_CAPACITY};
use super::rma::Window;
use super::shard::{CommMatch, EpochStats};
use super::vci::{guard_for, Guard, Vci, VciPool, VciState, FALLBACK_VCI};

/// Lock-free stripe-lane pin mask: one bit per pool lane, in as many
/// words as the configured pool needs (the old single-`u64` mask silently
/// capped pinning at 64 lanes — with striped windows pinning lanes on top
/// of ordered/endpoints communicators, that cap is reachable). Writers
/// (pin/unpin) are serialized by `MpiProc::ordered_pins`; readers on the
/// per-message stripe paths pay one relaxed-class atomic load per probe,
/// exactly like the single-word mask did.
pub(super) struct PinMask {
    words: Vec<AtomicU64>,
    /// Count of currently pinned lanes (fast "anything pinned?" check so
    /// the common no-pins case stays a single load).
    pinned: AtomicUsize,
}

impl PinMask {
    pub(super) fn new(lanes: usize) -> Self {
        PinMask {
            words: (0..lanes.max(1).div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
            pinned: AtomicUsize::new(0),
        }
    }

    /// Mark lane `idx` pinned. Caller holds the pin-table mutex (the
    /// refcounting layer), so set/count cannot race another writer.
    fn pin(&self, idx: usize) {
        debug_assert!(idx / 64 < self.words.len(), "lane {idx} beyond pin-mask capacity");
        let bit = 1u64 << (idx % 64);
        if self.words[idx / 64].fetch_or(bit, Ordering::Release) & bit == 0 {
            self.pinned.fetch_add(1, Ordering::Release);
        }
    }

    fn unpin(&self, idx: usize) {
        let bit = 1u64 << (idx % 64);
        if self.words[idx / 64].fetch_and(!bit, Ordering::Release) & bit != 0 {
            self.pinned.fetch_sub(1, Ordering::Release);
        }
    }

    /// Is any lane pinned at all?
    pub(super) fn any(&self) -> bool {
        self.pinned.load(Ordering::Acquire) != 0
    }

    /// Is pool lane `idx` pinned out of the stripe-lane set? Lanes beyond
    /// the mask's capacity are never pinned (defensive: the mask is sized
    /// from the configured pool).
    pub(super) fn excluded(&self, idx: usize) -> bool {
        match self.words.get(idx / 64) {
            Some(w) => w.load(Ordering::Acquire) & (1u64 << (idx % 64)) != 0,
            None => false,
        }
    }
}

/// Cap on the freed-comm finalize tripwire (`MpiProc::freed_comms`):
/// teardown correctness is enforced at free time (engine removed, caches
/// purged); the finalize assertion only guards against later
/// resurrection, so tracking the first ids is enough of a canary.
const FREED_TRACK_CAP: usize = 1024;

/// `1 + mix64(z) % (lanes - 1)`: the shared non-fallback lane scramble
/// behind every deterministic lane derivation whose two wire ends must
/// agree — the §7 envelope spread, striped-collectives segment lanes,
/// and dedicated collective lanes. One formula so the wire contract
/// cannot drift between them. Caller guarantees `lanes > 1`.
fn scrambled_lane(z: u64, lanes: usize) -> usize {
    1 + (crate::util::mix64(z) % (lanes as u64 - 1)) as usize
}

/// Deterministic probe for the first un-pinned stripe lane starting from
/// scramble `z` (lanes `1..n`; the fallback lane 0 is never a stripe
/// lane). `None` when every stripe lane is pinned. Shared by hashed
/// stripe selection (two-sided and RMA) and shard-anchored request
/// allocation so the three cannot diverge.
fn probe_stripe_lane(z: u64, n: usize, mask: &PinMask) -> Option<usize> {
    for k in 0..n as u64 - 1 {
        let lane = 1 + ((z.wrapping_add(k)) % (n as u64 - 1)) as usize;
        if !mask.excluded(lane) {
            return Some(lane);
        }
    }
    None
}

thread_local! {
    static ACTIVE_COSTS: RefCell<Option<Arc<CostModel>>> = const { RefCell::new(None) };
    static THREAD_TOKEN: RefCell<Option<u64>> = const { RefCell::new(None) };
}

/// Install the cost model for the calling thread (done by the world runner
/// and test harnesses before any MPI call).
pub fn set_active_costs(c: Arc<CostModel>) {
    ACTIVE_COSTS.with(|a| *a.borrow_mut() = Some(c));
}

pub fn active_costs() -> Arc<CostModel> {
    ACTIVE_COSTS
        .with(|a| a.borrow().clone())
        .unwrap_or_else(|| Arc::new(CostModel::default()))
}

/// A stable per-thread token for per-thread RMA completion tracking.
pub fn thread_token() -> u64 {
    if crate::sim::in_sim() {
        return crate::sim::current_tid() as u64;
    }
    THREAD_TOKEN.with(|t| {
        let mut t = t.borrow_mut();
        if t.is_none() {
            static NEXT: AtomicU64 = AtomicU64::new(1 << 32);
            *t = Some(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        t.unwrap()
    })
}

/// MPI progress hooks (MPICH/CH4 maintains two — paper §4.1). Each has its
/// own lock, acquired per progress-engine iteration in FG mode.
pub struct ProgressHook {
    pub lock: PMutex<()>,
    pub active: AtomicBool,
}

/// One MPI process.
pub struct MpiProc {
    pub cfg: MpiConfig,
    pub fabric: ProcFabric,
    pub backend: Backend,
    pub costs: Arc<CostModel>,
    /// Set by `init()`.
    vcis: OnceLock<VciPool>,
    pub slab: RequestSlab,
    /// The Global critical section (CsMode::Global).
    pub global_cs: PMutex<()>,
    pub hooks: [ProgressHook; 2],
    /// Live communicators (host table; creation is off the critical path).
    comms: HostMutex<Vec<Comm>>,
    pub(super) windows: HostMutex<Vec<Arc<Window>>>,
    next_comm_id: AtomicU64,
    pub(super) next_win_id: AtomicU64,
    /// Signals service threads (PSM2-style progress) to stop.
    pub finalized: AtomicBool,
    pub initialized: AtomicBool,
    /// Striping: shared per-(comm, dst) send-stream sequence counters.
    /// One logical FIFO stream per destination even though messages fan
    /// out across VCIs — the receiver's reorder stage keys off it. Host
    /// mutex; the modeled cost of the shared fetch-add is charged at the
    /// call site ([`MpiProc::next_stripe_seq`]).
    stripe_seq: HostMutex<HashMap<(u64, usize), u64>>,
    /// Striping: round-robin cursor for per-message send VCI selection.
    stripe_rr: AtomicUsize,
    /// Striping: rotation cursor for progress polling (a striped comm's
    /// traffic lands on every VCI, so waiters sweep the whole pool).
    stripe_poll_rr: AtomicUsize,
    /// Sharded matching engines, one per communicator seen carrying
    /// striped traffic (created lazily; see `mpi::shard`). Host mutex: the
    /// lookup models a comm-id indexed table walk, free in virtual time.
    match_engines: HostMutex<HashMap<u64, Arc<CommMatch>>>,
    /// The process-default [`CommPolicy`] — the demoted `MpiConfig` knobs.
    /// Every communicator (including MPI_COMM_WORLD) starts from it; info
    /// keys at creation override per communicator.
    pub(super) default_policy: Arc<CommPolicy>,
    /// Per-communicator policy table, keyed by comm id: the receive side
    /// only sees comm ids on the wire, so engine creation resolves the
    /// registered policy here. Host mutex (creation path + first-message
    /// engine builds only).
    policies: HostMutex<HashMap<u64, Arc<CommPolicy>>>,
    /// Comm ids freed by `comm_free`/`free_endpoints` — finalize asserts
    /// none of them remains cached in any VCI's `match_cache` or in the
    /// engine table (a freed comm must not pin shard engines forever).
    /// Diagnostic tripwire, bounded at [`FREED_TRACK_CAP`] ids so a
    /// per-iteration create/free loop cannot grow it without bound.
    freed_comms: HostMutex<HashSet<u64>>,
    /// Stripe-lane pins: per-VCI count of live ordered (`striping=off`)
    /// and endpoints communicators — and ordered RMA windows — funneling
    /// through it. A pinned lane is excluded from stripe-VCI selection and
    /// the striped progress sweep, so a latency-ordered communicator's (or
    /// ordered window's) VCI never queues striped bulk.
    ordered_pins: HostMutex<HashMap<usize, u32>>,
    /// Bitmask mirror of `ordered_pins` (a word array covering the whole
    /// configured pool), read lock-free on the per-message stripe paths.
    stripe_excluded: PinMask,
    /// Dedicated collective lanes, keyed by comm id: a communicator whose
    /// policy says `vcmpi_collectives=dedicated` reserves one lane for its
    /// collective traffic at registration (pinned out of the stripe set
    /// via `ordered_pins`, so striped p2p bulk never queues ahead of an
    /// allreduce step) and releases it at `comm_free`. Host mutex:
    /// consulted once per collective segment, off the wire path.
    coll_lanes: HostMutex<HashMap<u64, usize>>,
    /// Outstanding nonblocking-collective schedules (`mpi::coll_nb`),
    /// the workload behind progress hook 0: every progress iteration's
    /// `check_hooks` snapshots this registry and advances each schedule.
    /// Non-empty iff `hooks[0].active` (armed at initiation, disarmed
    /// when the last `coll_wait` retires its schedule).
    pub(super) coll_scheds: HostMutex<Vec<Arc<super::coll_nb::CollSched>>>,
    /// The process-default [`WinPolicy`] — the demoted
    /// `accumulate_ordering_none` hint. Every window starts from it; info
    /// keys at `win_create_with_info` override per window.
    pub(super) default_win_policy: Arc<WinPolicy>,
    /// Collective-order counters for `comm_split_with_info` id
    /// derivation, keyed by PARENT comm id: a split is collective over
    /// the parent's members only, so a per-parent counter stays symmetric
    /// even when subgroups split independently (a process-wide counter
    /// would diverge between members with different split histories).
    split_seqs: HostMutex<HashMap<u64, u64>>,
    /// Striped envelopes that forced an engine for a communicator whose
    /// registered policy says `striping=off` — a wire-contract violation
    /// (members passed different info keys). Counted, never fatal.
    policy_mismatches: AtomicU64,
    /// Doorbell-gated sweeps skipped outright (no rx bit rung).
    pub(super) doorbell_skips: AtomicU64,
    /// Context polls that found nothing ready.
    pub(super) empty_polls: AtomicU64,
    /// Consecutive doorbell skips (drives the paranoid global-round
    /// fallback, mirroring the per-VCI hybrid progress counter).
    pub(super) skip_streak: AtomicUsize,
    /// Counted diagnostic: stale, duplicate, or malformed wire control
    /// messages dropped by the progress engine instead of panicking
    /// (e.g. a CTS for an unknown rendezvous send).
    pub(super) stale_ctrl_drops: AtomicU64,
    /// Serial execution streams: lane index → owning thread token, one
    /// entry per live `stream_bind`. The authoritative ownership bit lives
    /// on the [`Vci`] itself (`stream_owner`, read lock-free on every
    /// fast-path op); this table exists for teardown bookkeeping —
    /// `comm_free` auto-unbind and the finalize leak tripwire. Host mutex:
    /// bind/unbind only, never on the per-op path.
    streams: HostMutex<HashMap<usize, u64>>,
    /// Request ids currently parked in per-thread stream freelists
    /// (allocated out of the shared slab in chunks by the stream fast
    /// path). `stream_unbind` drains the caller's freelist back and
    /// finalize asserts this count returned to zero — the freelist twin of
    /// the lightweight-refs leak tripwire.
    pub(super) stream_freelist_outstanding: AtomicUsize,
    /// Target-side passive-target lock tables (OPA software protocol),
    /// keyed by window id — this process as the *exposed* side. Served by
    /// the `RmaLockReq`/`RmaUnlock` wire handlers; `win_free` removes the
    /// entry and asserts it idle. `LockClass::HostWinLocks`, a leaf class
    /// never held across a scheduler interaction.
    pub(super) win_locks: HostMutex<HashMap<u64, super::rma::WinLockTable>>,
    /// Lock epochs opened without wire traffic because the window promised
    /// `mpi_assert_no_locks` (the load-bearing elision the
    /// `no_locks_over_locked` bench gate measures).
    pub(super) lock_elisions: AtomicU64,
    /// Lock acquisitions that did pay the wire protocol (OPA request/grant
    /// round trip) or NIC atomics (IB).
    pub(super) lock_wire_reqs: AtomicU64,
    /// Cached `fabric.has_fault_plan()` — true iff a deterministic fault
    /// plan is installed on the network. Gates every chaos-only branch
    /// (kill detection, retransmit driving) behind one plain bool load so
    /// the fault-free path pays nothing.
    pub(super) chaos: bool,
    /// Transparent lane failover enabled (`MpiConfig::lane_failover`).
    pub(super) lane_failover: bool,
    /// Lane-failover table: dead lane -> survivor lane, one entry per
    /// completed [`MpiProc::failover_vci`]. The idempotence gate — held
    /// only for the check/insert, never across VCI state migration.
    failed_lanes: HostMutex<HashMap<usize, usize>>,
}

impl MpiProc {
    /// Construct the (uninitialized) process. Call [`MpiProc::init`] from
    /// exactly one of its threads before communicating.
    pub fn new(fabric: ProcFabric, cfg: MpiConfig) -> Arc<MpiProc> {
        let backend = fabric.backend();
        let costs = fabric.costs().clone();
        let default_policy = Arc::new(CommPolicy::from_config(&cfg));
        let default_win_policy = Arc::new(WinPolicy::from_config(&cfg));
        let pin_lanes = cfg.num_vcis.max(1);
        let lane_failover_cfg = cfg.lane_failover;
        // MPI_COMM_WORLD (id 0) carries the default policy from birth.
        let mut policies = HashMap::new();
        policies.insert(0u64, default_policy.clone());
        Arc::new(MpiProc {
            cfg,
            backend,
            costs,
            vcis: OnceLock::new(),
            slab: RequestSlab::new(backend, DEFAULT_SLAB_CAPACITY),
            global_cs: PMutex::new(backend, ()),
            hooks: [
                ProgressHook { lock: PMutex::new(backend, ()), active: AtomicBool::new(false) },
                ProgressHook { lock: PMutex::new(backend, ()), active: AtomicBool::new(false) },
            ],
            comms: HostMutex::new(Vec::new()),
            windows: HostMutex::new(Vec::new()),
            next_comm_id: AtomicU64::new(1),
            next_win_id: AtomicU64::new(1),
            finalized: AtomicBool::new(false),
            initialized: AtomicBool::new(false),
            stripe_seq: HostMutex::new(HashMap::new()),
            stripe_rr: AtomicUsize::new(0),
            stripe_poll_rr: AtomicUsize::new(0),
            match_engines: HostMutex::new(HashMap::new()),
            default_policy,
            policies: HostMutex::new(policies),
            freed_comms: HostMutex::new(HashSet::new()),
            ordered_pins: HostMutex::new(HashMap::new()),
            stripe_excluded: PinMask::new(pin_lanes),
            coll_lanes: HostMutex::new(HashMap::new()),
            coll_scheds: HostMutex::new(Vec::new()),
            default_win_policy,
            split_seqs: HostMutex::new(HashMap::new()),
            policy_mismatches: AtomicU64::new(0),
            doorbell_skips: AtomicU64::new(0),
            empty_polls: AtomicU64::new(0),
            skip_streak: AtomicUsize::new(0),
            stale_ctrl_drops: AtomicU64::new(0),
            streams: HostMutex::new(HashMap::new()),
            stream_freelist_outstanding: AtomicUsize::new(0),
            win_locks: HostMutex::new(HashMap::new()),
            lock_elisions: AtomicU64::new(0),
            lock_wire_reqs: AtomicU64::new(0),
            chaos: fabric.has_fault_plan(),
            lane_failover: lane_failover_cfg,
            failed_lanes: HostMutex::new(HashMap::new()),
            fabric,
        })
    }

    pub fn rank(&self) -> usize {
        self.fabric.proc
    }

    pub fn nprocs(&self) -> usize {
        self.fabric.nprocs()
    }

    pub fn interconnect(&self) -> Interconnect {
        self.fabric.interconnect()
    }

    pub fn vcis(&self) -> &VciPool {
        self.vcis.get().expect("MpiProc::init not called")
    }

    pub fn guard(&self) -> Guard {
        guard_for(&self.cfg, self.backend)
    }

    /// Enter the Global critical section if configured (no-op in FG mode).
    /// Returns a guard to hold for the duration of the MPI call.
    pub fn enter_cs(&self) -> Option<crate::platform::PMutexGuard<'_, ()>> {
        if self.cfg.unsafe_no_thread_safety && self.backend == Backend::Sim {
            return None;
        }
        match self.cfg.cs_mode {
            CsMode::Global => Some(self.global_cs.lock_class(LockClass::Global)),
            CsMode::Fg => None,
        }
    }

    /// MPI_Init: open hardware contexts (one per requested VCI, bounded by
    /// the node's budget), build the VCI pool, and establish connections:
    /// PMI-style out-of-band exchange for the fallback VCI, then an
    /// allgather of the remaining VCI addresses *over* the fallback VCI
    /// (paper §4.2 "Connection establishment" — the Fig. 4 overhead).
    pub fn init(self: &Arc<Self>) {
        assert!(!self.initialized.load(Ordering::Acquire), "double init");
        let mut ctx_indices = Vec::new();
        for _ in 0..self.cfg.num_vcis.max(1) {
            match self.fabric.open_context() {
                Some((idx, _ctx)) => ctx_indices.push(idx),
                None => break, // hardware exhausted: smaller pool
            }
        }
        assert!(
            !ctx_indices.is_empty(),
            "node out of hardware contexts for even the fallback VCI"
        );
        let pool = VciPool::new(
            self.backend,
            &ctx_indices,
            self.cfg.cache_aligned_vcis,
            self.cfg.vci_policy,
        );
        // Wire the pool's rx doorbell into each VCI's hardware context so
        // delivery rings bit `i` and the striped sweep can skip idle VCIs.
        for (i, &ctx_idx) in ctx_indices.iter().enumerate() {
            self.fabric.context(self.rank(), ctx_idx).install_doorbell(pool.doorbell().clone(), i);
        }
        self.vcis.set(pool).ok().expect("init raced");

        // PMI exchange of fallback addresses: every rank inserts every other
        // rank's fallback address into its address vector. PMI is an
        // out-of-band rendezvous — it cannot complete until every process
        // has opened (and published) its fallback context, so wait for
        // that before the in-band allgather below.
        for p in 0..self.nprocs() {
            if p != self.rank() {
                while self.fabric.open_count(p) == 0 {
                    padvance(self.backend, 200); // PMI poll interval
                    pyield(self.backend);
                }
                self.fabric.insert_address();
            }
        }
        self.initialized.store(true, Ordering::Release);
        // Address allgather for the remaining VCIs rides over the fallback
        // VCI (world communicator), exactly as the paper does it.
        let world = self.comm_world();
        let my_nvcis = self.vcis().len() as u64;
        let counts = self.allgather_u64(&world, my_nvcis);
        for (p, &n) in counts.iter().enumerate() {
            if p != self.rank() {
                for _ in 0..n.saturating_sub(1) {
                    self.fabric.insert_address();
                }
            }
        }
        self.barrier(&world);
    }

    /// MPI_Finalize: drain, tear down contexts (cost grows with the number
    /// of VCIs — Fig. 4's finalize series), release service threads.
    pub fn finalize(self: &Arc<Self>) {
        let world = self.comm_world();
        self.barrier(&world);
        // Reliability linger (chaos runs only): the finalize barrier's own
        // last frames can be fault-dropped, and a peer that exits before
        // its retransmit timer fires would strand the blocked rank
        // forever. Each rank therefore keeps polling + retransmitting for
        // a bounded virtual-time window after its barrier completes —
        // long enough for many backoff doublings, so a straggler's
        // recovery cycle (retransmit → dup-ack → prune) converges while
        // its peers are still responsive. Zero cost without a fault plan.
        if self.chaos {
            if let Some(plan) = self.fabric.fault_plan() {
                let linger = (plan.retransmit_timeout_ns * 64).max(5_000_000);
                let until = pnow(self.backend).saturating_add(linger);
                while pnow(self.backend) < until {
                    padvance(self.backend, self.costs.psm2_progress_interval.max(1));
                    self.service_progress_round();
                    if self.backend == Backend::Native {
                        break; // wallclock backends have no virtual clock to wait out
                    }
                }
            }
        }
        // Lightweight-request refcounts must balance once every thread has
        // quiesced: each immediate `isend` acquired one reference and each
        // `wait` released one (for per-VCI replication the release was
        // deferred; entering the state below drains it first). An
        // imbalance here means a leaked reference — exactly the bug the
        // deferred-drain path used to have.
        {
            let _cs = self.enter_cs();
            // Stream hygiene (mirror of the freed-comm tripwire below): a
            // lane still in single-writer mode here would be swept by the
            // context teardown from the wrong thread, and request ids still
            // parked in a thread-local freelist are slab leaks.
            {
                let streams = self.streams.lock(LockClass::HostStreams);
                assert!(
                    streams.is_empty(),
                    "stream-owned VCIs leaked at finalize: {:?} (stream_unbind or comm_free \
                     every streamed communicator before finalize)",
                    {
                        let mut lanes: Vec<usize> = streams.keys().copied().collect();
                        lanes.sort_unstable();
                        lanes
                    }
                );
            }
            let parked = self.stream_freelist_outstanding.load(Ordering::Relaxed);
            assert_eq!(
                parked, 0,
                "{parked} request ids still parked in stream freelists at finalize"
            );
            if self.cfg.per_vci_lightweight {
                let guard = self.guard();
                for i in 0..self.vcis().len() {
                    let v = self.vcis().get(i).clone();
                    let refs = v.with_state(guard, |st| {
                        st.lw_refs.load(std::sync::atomic::Ordering::Relaxed)
                    });
                    assert_eq!(
                        refs, 0,
                        "VCI {i}: {refs} lightweight request refs leaked at finalize"
                    );
                }
            } else {
                let refs = self.slab.global_lightweight_refs.load();
                assert_eq!(refs, 0, "{refs} global lightweight request refs leaked at finalize");
            }
            // Per-comm policy teardown: a freed communicator must leave no
            // sharded-engine state behind — not in the process-wide table
            // and not as a cached handle in any VCI (either would pin the
            // freed comm's shard engines for the life of the process).
            let freed: Vec<u64> = {
                let f = self.freed_comms.lock(LockClass::HostFreedComms);
                f.iter().copied().collect()
            };
            if !freed.is_empty() {
                {
                    let engines = self.match_engines.lock(LockClass::HostMatchEngines);
                    for id in &freed {
                        assert!(
                            !engines.contains_key(id),
                            "freed comm {id} still owns a matching engine at finalize"
                        );
                    }
                }
                let guard = self.guard();
                for i in 0..self.vcis().len() {
                    let v = self.vcis().get(i).clone();
                    v.with_state(guard, |st| {
                        for id in &freed {
                            assert!(
                                !st.match_cache.contains_key(id),
                                "VCI {i}: freed comm {id} still cached in match_cache at finalize"
                            );
                        }
                    });
                }
            }
        }
        let n = self.vcis().len();
        for i in 0..n {
            self.fabric.close_context(self.vcis().get(i).ctx_index);
        }
        self.finalized.store(true, Ordering::Release);
    }

    /// MPI_COMM_WORLD: rank = process id, mapped to the fallback VCI,
    /// carrying the process-default policy.
    pub fn comm_world(&self) -> Comm {
        Comm {
            id: 0,
            vci: FALLBACK_VCI,
            size: self.nprocs(),
            rank: self.rank(),
            kind: CommKind::Procs,
            policy: self.default_policy.clone(),
        }
    }

    /// Allocate the next communicator id (shared by dup and endpoint
    /// creation so that symmetric collective creation orders yield
    /// identical ids on every process).
    pub(super) fn alloc_comm_id(&self) -> u64 {
        self.next_comm_id.fetch_add(1, Ordering::AcqRel)
    }

    /// MPI_Comm_dup: a new communicator with its own VCI from the pool
    /// (or the fallback when the pool is empty), inheriting the parent's
    /// policy. Collective: call on every process in creation order;
    /// assignment is symmetric because pools start identical and
    /// assignment order matches.
    pub fn comm_dup(&self, parent: &Comm) -> Comm {
        self.comm_dup_with_info(parent, &Info::new())
    }

    /// MPI_Comm_dup_with_info: like [`MpiProc::comm_dup`], with the new
    /// communicator's [`CommPolicy`] resolved from `info` keys over the
    /// parent's policy (see `mpi::policy` for the vocabulary). All members
    /// must pass identical info — the policy is part of the wire contract,
    /// like `num_vcis`.
    pub fn comm_dup_with_info(&self, parent: &Comm, info: &Info) -> Comm {
        let id = self.alloc_comm_id();
        padvance(self.backend, self.costs.instructions(200)); // comm bookkeeping
        let vci = self.vcis().assign(id);
        let policy = Arc::new(parent.policy.with_info(info));
        let c = Comm {
            id,
            vci,
            size: parent.size,
            rank: parent.rank,
            kind: parent.kind.clone(),
            policy,
        };
        self.comms.lock(LockClass::HostComms).push(c.clone());
        self.register_comm(&c);
        c
    }

    /// MPI_Comm_split-with-info: collective over `parent`'s members. Every
    /// member calls with its `(color, key, info)`; members sharing a color
    /// form a new communicator, ranked by `(key, parent rank)`, with a
    /// policy resolved from `info` over the parent's. Membership is
    /// exchanged with an allgather over the parent (real split semantics);
    /// the new comm id is derived deterministically from
    /// `(parent id, per-parent split order, color)`, so all members of a
    /// color agree on it and different colors get distinct ids — the same
    /// symmetry contract as `comm_dup`'s creation-order ids, scoped per
    /// parent so subgroups splitting independently cannot diverge.
    pub fn comm_split_with_info(&self, parent: &Comm, color: u64, key: u64, info: &Info) -> Comm {
        assert!(
            !parent.is_endpoints(),
            "comm_split_with_info is defined on process communicators"
        );
        let colors = self.allgather_u64(parent, color);
        let keys = self.allgather_u64(parent, key);
        let mut members: Vec<usize> = (0..parent.size).filter(|&r| colors[r] == color).collect();
        members.sort_by_key(|&r| (keys[r], r));
        let my_rank = members
            .iter()
            .position(|&r| r == parent.rank)
            .expect("calling rank belongs to its own color");
        // Parent ranks -> process ids (works for nested Group parents).
        let procs: Vec<usize> = members.iter().map(|&r| self.route(parent, r).0).collect();
        padvance(self.backend, self.costs.instructions(400)); // split bookkeeping
        let seq = {
            let mut t = self.split_seqs.lock(LockClass::HostSplitSeqs);
            let e = t.entry(parent.id).or_insert(0);
            *e += 1;
            *e
        };
        let z = parent.id ^ seq.rotate_left(32) ^ color.wrapping_mul(0x9E3779B97F4A7C15);
        let id = 0x5C00_0000_0000_0000 | (crate::util::mix64(z) & 0x00FF_FFFF_FFFF_FFFF);
        let vci = self.vcis().assign(id);
        let policy = Arc::new(parent.policy.with_info(info));
        let c = Comm {
            id,
            vci,
            size: members.len(),
            rank: my_rank,
            kind: CommKind::Group { procs: Arc::new(procs) },
            policy,
        };
        self.comms.lock(LockClass::HostComms).push(c.clone());
        self.register_comm(&c);
        c
    }

    /// MPI_Comm_free: return the VCI to the pool and tear the per-comm
    /// policy state down — the policy table entry, the sharded matching
    /// engine, and every VCI's cached engine handle (a freed comm must not
    /// pin shard engines for the rest of the process lifetime; finalize
    /// asserts it did not).
    pub fn comm_free(&self, comm: Comm) {
        self.vcis().release(comm.vci);
        {
            let mut t = self.comms.lock(LockClass::HostComms);
            t.retain(|c| c.id != comm.id);
        }
        self.unregister_comm(&comm);
    }

    /// Record a newly created communicator's policy: the policy table (for
    /// receive-side engine creation), the stripe-lane pins (ordered and
    /// endpoints comms exclude their VCIs from striping), and adoption of
    /// any engine a racing striped arrival created with the default shape.
    pub(super) fn register_comm(&self, comm: &Comm) {
        self.policies.lock(LockClass::HostPolicies).insert(comm.id, comm.policy.clone());
        match &comm.kind {
            CommKind::Endpoints { vcis, .. } => {
                for &v in vcis.iter() {
                    self.pin_ordered_lane(v);
                }
            }
            _ if !comm.policy.striped() => self.pin_ordered_lane(comm.vci),
            _ => {}
        }
        // Dedicated collective lanes are placed EAGERLY, not on first
        // collective: nonblocking collectives let ranks reach their first
        // collective on different comms in different orders (rank 0 may
        // issue iallreduce(A) then iallreduce(B) while rank 1 overlaps
        // them B-first), so first-use order is not wire-symmetric —
        // comm-creation order is. (Pre-init registration skips this; the
        // lane is then placed lazily by `dedicated_coll_lane`, still in a
        // symmetric order because pre-init comms are created in lockstep.)
        if matches!(comm.policy.collectives, CollectivesMode::Dedicated)
            && !comm.is_endpoints()
            && self.vcis.get().is_some()
        {
            self.dedicated_coll_lane(comm);
        }
        self.adopt_policy_engine(comm.id, &comm.policy);
    }

    /// Reverse of [`MpiProc::register_comm`], at communicator free.
    pub(super) fn unregister_comm(&self, comm: &Comm) {
        // Freeing a streamed comm implies unbind (owner only — asserted).
        self.stream_teardown_on_free(comm);
        self.policies.lock(LockClass::HostPolicies).remove(&comm.id);
        match &comm.kind {
            CommKind::Endpoints { vcis, .. } => {
                for &v in vcis.iter() {
                    self.unpin_ordered_lane(v);
                }
            }
            _ if !comm.policy.striped() => self.unpin_ordered_lane(comm.vci),
            _ => {}
        }
        // Release the dedicated collective lane, if this comm reserved one
        // (the acceptance tripwire: a freed `vcmpi_collectives=dedicated`
        // comm must not keep its lane pinned out of the stripe set).
        let coll_lane = {
            let mut t = self.coll_lanes.lock(LockClass::HostCollLanes);
            t.remove(&comm.id)
        };
        if let Some(lane) = coll_lane {
            self.unpin_ordered_lane(lane);
        }
        self.match_engines.lock(LockClass::HostMatchEngines).remove(&comm.id);
        {
            let mut f = self.freed_comms.lock(LockClass::HostFreedComms);
            if f.len() < FREED_TRACK_CAP {
                f.insert(comm.id);
            }
        }
        self.purge_match_caches(comm.id);
    }

    /// Pin `vci_idx` out of the stripe-lane set (refcounted: several
    /// ordered comms/windows may share a lane after pool exhaustion). The
    /// fallback VCI is never a stripe lane, so it needs no pin. Also used
    /// by ordered RMA windows (`mpi::rma`).
    pub(super) fn pin_ordered_lane(&self, vci_idx: usize) {
        if vci_idx == FALLBACK_VCI {
            return;
        }
        let mut pins = self.ordered_pins.lock(LockClass::HostOrderedPins);
        *pins.entry(vci_idx).or_insert(0) += 1;
        self.stripe_excluded.pin(vci_idx);
    }

    pub(super) fn unpin_ordered_lane(&self, vci_idx: usize) {
        if vci_idx == FALLBACK_VCI {
            return;
        }
        let mut pins = self.ordered_pins.lock(LockClass::HostOrderedPins);
        if let Some(c) = pins.get_mut(&vci_idx) {
            *c -= 1;
            if *c == 0 {
                pins.remove(&vci_idx);
                self.stripe_excluded.unpin(vci_idx);
            }
        }
    }

    /// Is lane `idx` currently pinned out of the stripe set? Test/bench
    /// aid (proves ordered windows/comms protect their lanes).
    pub fn stripe_lane_pinned(&self, idx: usize) -> bool {
        self.stripe_excluded.excluded(idx)
    }

    /// Bind the calling thread to `comm`'s VCI as a *serial execution
    /// stream* (MPIX-Stream style, paper §8 "what do we lose?"): the lane
    /// is pinned out of the stripe set (one more refcount on top of the
    /// ordered-comm pin `register_comm` already took) and switched into
    /// single-writer mode — subsequent `isend`/`irecv`/`wait` by this
    /// thread on this comm go through [`Vci::with_state_stream`] and the
    /// thread-local request freelist, paying zero lock acquisitions per
    /// op. Any other thread touching the lane trips the SimSan owner
    /// check. Called explicitly (endpoints-style API) or implicitly by
    /// the first op on a `vcmpi_stream=local` communicator.
    ///
    /// Returns the bound lane index. Erroneous (panics) on: a striped or
    /// endpoints comm, a comm sharing the fallback VCI (the world lane is
    /// everyone's), a lane that already carries a stream, or a non-FG
    /// thread-safety mode (the Global CS / `unsafe_no_thread_safety`
    /// modes have no per-VCI lock to elide).
    pub fn stream_bind(&self, comm: &Comm) -> usize {
        assert!(
            !comm.is_endpoints(),
            "stream_bind: endpoints comms already name their lane explicitly (erroneous program)"
        );
        assert!(
            !comm.policy.striped(),
            "stream_bind: comm {} is striped; a serial execution stream is a single ordered \
             lane (erroneous program)",
            comm.id
        );
        assert_eq!(
            self.guard(),
            Guard::VciLock,
            "stream_bind requires the fine-grained critical-section mode (vcmpi_cs=fg): \
             coarser modes have no per-VCI lock for the stream to elide"
        );
        let lane = self.comm_vci(comm, None);
        assert_ne!(
            lane, FALLBACK_VCI,
            "stream_bind: comm {} landed on the fallback VCI (pool exhausted or world comm); \
             the shared world lane cannot become single-writer",
            comm.id
        );
        let token = thread_token();
        // Pin first: from here the lane is out of the stripe set even if
        // the owner bit is not yet visible to a concurrent sweep.
        self.pin_ordered_lane(lane);
        let v = self.vcis().get(lane).clone();
        v.stream_set_owner(token);
        self.streams.lock(LockClass::HostStreams).insert(lane, token);
        // Ownership transition under the lane's lock: publishes a real
        // happens-before edge from every earlier locked access to the new
        // owner's plain-cell accesses, and drains any lightweight releases
        // other threads deferred onto this lane pre-bind (the fast path
        // never drains — nothing can defer onto a bound lane).
        v.stream_transition(self.guard());
        // Pre-charge the lane-local request freelist so the first window
        // of stream ops never touches the shared slab lock.
        self.stream_prefill(lane);
        padvance(self.backend, self.costs.instructions(300)); // bind bookkeeping
        lane
    }

    /// Undo [`MpiProc::stream_bind`]: drain the calling thread's request
    /// freelist back to the shared slab, hand the lane back to the locked
    /// world (with a locked transition so the next lock holder acquires
    /// the stream's writes), and return it to the stripe set. Must be
    /// called by the owning thread; `comm_free` on a streamed comm does
    /// this implicitly.
    pub fn stream_unbind(&self, comm: &Comm) {
        let lane = self.comm_vci(comm, None);
        self.stream_unbind_lane(lane);
    }

    fn stream_unbind_lane(&self, lane: usize) {
        let v = self.vcis().get(lane).clone();
        let me = thread_token();
        assert!(
            v.stream_owned_by(me),
            "stream_unbind: lane {lane} is not stream-owned by thread token {me} \
             (owner: {}); only the binding thread may unbind (erroneous program)",
            v.stream_owner()
        );
        self.stream_drain_freelist(lane);
        // Reconcile purges that skipped this lane while it was
        // single-writer: freed comms must not stay cached here (the
        // finalize freed-comm tripwire sweeps every lane).
        let freed: Vec<u64> = {
            let f = self.freed_comms.lock(LockClass::HostFreedComms);
            f.iter().copied().collect()
        };
        if !freed.is_empty() {
            v.with_state_stream(|st| {
                st.match_cache.retain(|id, _| !freed.contains(id));
            });
        }
        // Release edge while still the owner: the transition's locked
        // touch of the witness cell publishes the stream's plain-cell
        // writes to the next locked accessor.
        v.stream_transition(self.guard());
        v.stream_clear_owner();
        self.streams.lock(LockClass::HostStreams).remove(&lane);
        self.unpin_ordered_lane(lane);
        padvance(self.backend, self.costs.instructions(300)); // unbind bookkeeping
    }

    /// Stream teardown hook for `comm_free`/`unregister_comm`: if this
    /// comm's lane carries a live stream, the freeing thread must be its
    /// owner (then the free implies unbind); a free from any other thread
    /// is a cross-thread touch of a single-writer lane.
    fn stream_teardown_on_free(&self, comm: &Comm) {
        if comm.is_endpoints() || self.vcis.get().is_none() {
            return;
        }
        let lane = self.comm_vci(comm, None);
        let owner = { self.streams.lock(LockClass::HostStreams).get(&lane).copied() };
        if let Some(token) = owner {
            assert_eq!(
                token,
                thread_token(),
                "comm {} freed while its lane {lane} is stream-owned by thread token {token}; \
                 only the stream's owner may free a streamed communicator (erroneous program)",
                comm.id
            );
            self.stream_unbind_lane(lane);
        }
    }

    /// Is lane `idx` currently bound as a serial execution stream?
    /// Test/bench aid.
    pub fn stream_lane_owned(&self, idx: usize) -> bool {
        self.vcis().get(idx).is_stream_owned()
    }

    /// If a striped arrival raced this communicator's creation, an engine
    /// was lazily built with the process-default shape; replace it with
    /// one built from the registered policy via a stop-the-world adoption
    /// epoch (`CommMatch::retire_into`). The table entry is swapped to
    /// the successor FIRST, so the entry exists throughout and a
    /// concurrent striped arrival can never lazily create a third engine
    /// mid-migration — the double-adoption race the old
    /// remove/rebuild/reinsert protocol left open.
    fn adopt_policy_engine(&self, comm_id: u64, policy: &CommPolicy) {
        // Never hold the host table mutex across shard (PMutex) locks: a
        // sim-side park under a host lock would host-deadlock the DES
        // (same discipline as `reorder_stats`). Building the successor
        // under the table lock is fine — `CommMatch::new` takes no locks.
        let swapped = {
            let mut table = self.match_engines.lock(LockClass::HostMatchEngines);
            let mismatch = match table.get(&comm_id) {
                Some(old) => {
                    old.shard_count() != policy.shard_mask() + 1
                        || old.linger() != policy.wildcard_linger
                }
                None => false,
            };
            if !mismatch {
                return;
            }
            let fresh = CommMatch::new(
                self.backend,
                comm_id,
                policy.match_shards,
                policy.wildcard_linger,
            );
            let old = table
                .insert(comm_id, fresh.clone())
                .expect("mismatched engine vanished under the table lock");
            (old, fresh)
        };
        let (old, fresh) = swapped;
        // Quiesce the caches: drop every VCI's handle to `old`. The purge
        // takes each VCI's state lock, so it serializes behind in-flight
        // handlers that resolved `old` from their cache; each such handler
        // either finishes depositing before the drain below (its state
        // migrates) or observes the `retired` flag under its shard lock
        // and retries through the table, which has resolved `fresh` since
        // the swap above.
        self.purge_match_caches(comm_id);
        // Retire: under ALL of old's shard locks (ascending index — the
        // wildcard-epoch pattern), flag it and migrate its queues whole.
        old.retire_into(&fresh);
    }

    /// Drop `comm_id`'s cached engine handle from every VCI (comm free or
    /// engine adoption). Off the critical path: takes each VCI's state in
    /// turn under the configured guard discipline.
    fn purge_match_caches(&self, comm_id: u64) {
        if self.vcis.get().is_none() {
            return; // pre-init registration (world): nothing cached yet
        }
        let _cs = self.enter_cs();
        let guard = self.guard();
        for i in 0..self.vcis().len() {
            let vci = self.vcis().get(i).clone();
            if vci.is_stream_owned() {
                // Single-writer lanes may only be touched by their owner.
                // A foreign lane's stale entry is reconciled at its
                // unbind (`stream_unbind_lane` drops freed-comm cache
                // entries), keeping the finalize tripwire sound.
                if vci.stream_owned_by(thread_token()) {
                    vci.with_state_stream(|st| {
                        st.match_cache.remove(&comm_id);
                    });
                }
                continue;
            }
            vci.with_state(guard, |st| {
                st.match_cache.remove(&comm_id);
            });
        }
    }

    /// Resolve a communicator rank to (target process, target ctx index).
    pub fn route(&self, comm: &Comm, rank: usize) -> (usize, usize) {
        match &comm.kind {
            CommKind::Procs => {
                let proc = rank;
                let remote_ctxs = self.fabric.open_count(proc).max(1);
                (proc, comm.vci % remote_ctxs)
            }
            CommKind::Group { procs } => {
                let proc = procs[rank];
                let remote_ctxs = self.fabric.open_count(proc).max(1);
                (proc, comm.vci % remote_ctxs)
            }
            CommKind::Endpoints { per_proc, vcis } => {
                let proc = rank / per_proc;
                let ep = rank % per_proc;
                let remote_ctxs = self.fabric.open_count(proc).max(1);
                (proc, vcis[ep] % remote_ctxs)
            }
        }
    }

    /// The local VCI index an operation on `comm` (issued by the calling
    /// thread, in the given role) maps to.
    pub fn comm_vci(&self, comm: &Comm, my_endpoint: Option<usize>) -> usize {
        match &comm.kind {
            CommKind::Procs | CommKind::Group { .. } => comm.vci % self.vcis().len(),
            CommKind::Endpoints { vcis, .. } => {
                let ep = my_endpoint.expect("endpoint comms require an endpoint identity");
                vcis[ep] % self.vcis().len()
            }
        }
    }

    /// MPI-4.0 hint path (paper §7): with `mpi_assert_no_any_source` +
    /// `mpi_assert_no_any_tag` asserted **on this communicator's policy**,
    /// traffic within ONE communicator may spread over VCIs by its
    /// fully-specified envelope — matching stays correct because both
    /// sides can compute the same stream from (comm, source rank, tag).
    /// Falls back to the communicator's VCI when the hints are not
    /// asserted (or with a single-VCI pool).
    pub fn vci_for_envelope(&self, comm: &Comm, src_rank: usize, tag: i32) -> usize {
        if comm.is_endpoints()
            || !(comm.policy.no_any_source && comm.policy.no_any_tag)
            || self.vcis().len() <= 1
        {
            return self.comm_vci(comm, None);
        }
        // SplitMix-style scramble of the full envelope.
        scrambled_lane(
            comm.id
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add((src_rank as u64) << 32)
                .wrapping_add(tag as u32 as u64),
            self.vcis().len(),
        )
    }

    /// Does per-message VCI striping apply to two-sided traffic on `comm`?
    /// Decided by the communicator's own policy (info keys at creation;
    /// the process config is only the default) — a hot striped comm and a
    /// latency-ordered comm coexist in one process. Endpoints
    /// communicators are excluded (each endpoint IS a dedicated VCI —
    /// striping would defeat their contract). Deliberately NOT a function
    /// of the local pool size: the predicate decides whether receives post
    /// into the sharded engine, and it must match the sender's decision to
    /// mark envelopes striped even when one side's hardware granted fewer
    /// contexts (a single-VCI pool then stripes degenerately onto its one
    /// lane).
    pub fn striping_active(&self, comm: &Comm) -> bool {
        comm.policy.striped() && !comm.is_endpoints()
    }

    /// The sharded matching engine for a striped communicator (created on
    /// first use; all two-sided traffic of a striped comm funnels here
    /// instead of the per-VCI engines). The engine's shape — shard count
    /// and wildcard linger — comes from the communicator's **registered
    /// policy**; an unknown comm id (a striped arrival racing the local
    /// creation call) builds with the process-default shape and is adopted
    /// (state migrated) when the registration lands. A registered
    /// `striping=off` policy reaching this path means the sender striped
    /// where we would not — a wire-contract violation, counted in
    /// [`MpiProc::policy_mismatch_count`].
    pub fn comm_match(&self, comm_id: u64) -> Arc<CommMatch> {
        let mut table = self.match_engines.lock(LockClass::HostMatchEngines);
        table
            .entry(comm_id)
            .or_insert_with(|| {
                let (shards, linger, off) = {
                    let p = self.policies.lock(LockClass::HostPolicies);
                    match p.get(&comm_id) {
                        Some(pol) => (pol.match_shards, pol.wildcard_linger, !pol.striped()),
                        None => (
                            self.default_policy.match_shards,
                            self.default_policy.wildcard_linger,
                            false,
                        ),
                    }
                };
                if off {
                    self.policy_mismatches.fetch_add(1, Ordering::Relaxed);
                }
                CommMatch::new(self.backend, comm_id, shards, linger)
            })
            .clone()
    }

    /// Does a sharded matching engine currently exist for `comm_id`?
    /// Test/bench aid: proves which communicators carried striped traffic
    /// (an ordered comm must never grow one).
    pub fn has_match_engine(&self, comm_id: u64) -> bool {
        self.match_engines.lock(LockClass::HostMatchEngines).contains_key(&comm_id)
    }

    /// Striped envelopes seen for communicators whose registered policy
    /// says `striping=off` (wire-contract violations). Diagnostic counter.
    pub fn policy_mismatch_count(&self) -> u64 {
        self.policy_mismatches.load(Ordering::Relaxed)
    }

    /// Lock epochs opened as local no-op grants because the window
    /// promised `mpi_assert_no_locks`. Test/bench aid: proves the elision
    /// actually fired (paired with [`MpiProc::lock_wire_req_count`]).
    pub fn lock_elision_count(&self) -> u64 {
        self.lock_elisions.load(Ordering::Relaxed)
    }

    /// Lock acquisitions that paid the real protocol (OPA wire round trip
    /// or IB NIC atomics).
    pub fn lock_wire_req_count(&self) -> u64 {
        self.lock_wire_reqs.load(Ordering::Relaxed)
    }

    /// [`MpiProc::comm_match`] through the calling VCI's cache: the hot
    /// striped paths run with a VCI's state held anyway, so the engine
    /// handle is resolved there and the process-wide table is touched
    /// only on the first message a VCI sees for a communicator.
    pub(super) fn cached_comm_match(&self, st: &mut VciState, comm_id: u64) -> Arc<CommMatch> {
        st.match_cache.entry(comm_id).or_insert_with(|| self.comm_match(comm_id)).clone()
    }

    /// Next sequence number of the (comm, dst) striped send stream. The
    /// counter is shared by every thread and VCI of this process — that is
    /// what makes the stream a single FIFO the receiver can restore.
    /// Modeled as a shared atomic fetch-add: one RMW plus a cache-line
    /// transfer (the line ping-pongs between sender threads).
    pub(super) fn next_stripe_seq(&self, comm_id: u64, dst: usize) -> u64 {
        padvance(self.backend, self.costs.atomic_rmw + self.costs.cacheline_transfer);
        let mut t = self.stripe_seq.lock(LockClass::HostStripeSeq);
        let e = t.entry((comm_id, dst)).or_insert(0);
        *e += 1;
        *e
    }

    /// Stripe VCI for one message, per the communicator's policy.
    /// Round-robin walks the pool with a process-wide cursor; hashed
    /// scrambles (comm, dst, seq) so a message keeps its VCI
    /// deterministically without shared state. Both exclude the fallback
    /// VCI 0 (like the hinted envelope spread): it is the shared lane
    /// every pool-exhausted communicator funnels through, so striping onto
    /// it would contend with funneled traffic. Lanes pinned by ordered /
    /// endpoints communicators are skipped the same way — their
    /// latency-sensitive traffic never queues behind striped bulk; if
    /// every lane is pinned, the message funnels through the comm's home
    /// VCI (still marked striped, so both sides agree on the path).
    pub(super) fn stripe_vci(&self, comm: &Comm, dst: usize, seq: u64) -> usize {
        let n = self.vcis().len();
        if n <= 1 {
            // Degenerate pool (hardware granted one context): stripe onto
            // the only lane. The envelope is still marked striped so both
            // sides agree on the matching path.
            return FALLBACK_VCI;
        }
        match comm.policy.striping {
            VciStriping::RoundRobin => self
                .rr_stripe_lane(n)
                .unwrap_or_else(|| self.comm_vci(comm, None)),
            VciStriping::HashedByRequest => {
                let z = crate::util::mix64(
                    comm.id
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add((dst as u64) << 32)
                        .wrapping_add(seq),
                );
                probe_stripe_lane(z, n, &self.stripe_excluded)
                    .unwrap_or_else(|| self.comm_vci(comm, None))
            }
            VciStriping::Off => self.comm_vci(comm, None),
        }
    }

    /// Round-robin selection of the next un-pinned stripe lane (the
    /// process-wide cursor shared by two-sided and RMA striping, so
    /// concurrent striped traffic naturally fans out). `None` when every
    /// stripe lane is pinned.
    fn rr_stripe_lane(&self, n: usize) -> Option<usize> {
        for _ in 0..n - 1 {
            let lane = 1 + self.stripe_rr.fetch_add(1, Ordering::Relaxed) % (n - 1);
            if !self.stripe_excluded.excluded(lane) {
                return Some(lane);
            }
        }
        None
    }

    /// Stripe lane for one RMA op on a striped window, per the window's
    /// [`WinPolicy`]: round-robin walks the pool with the shared cursor;
    /// hashed scrambles (window id, target, op handle) so an op keeps its
    /// lane deterministically. Exclusions mirror [`MpiProc::stripe_vci`]:
    /// never the fallback VCI, never a lane pinned by an ordered comm,
    /// endpoints comm, or ordered window; if every stripe lane is pinned
    /// the op funnels through the window's home VCI (still ack-counted,
    /// so both sides agree on the completion protocol).
    pub(super) fn stripe_win_vci(&self, win: &Window, target: usize, seq: u64) -> usize {
        let n = self.vcis().len();
        let home = win.vci % n;
        if n <= 1 {
            return FALLBACK_VCI;
        }
        match win.policy.striping {
            VciStriping::RoundRobin => self.rr_stripe_lane(n).unwrap_or(home),
            VciStriping::HashedByRequest => {
                let z = crate::util::mix64(
                    win.id
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add((target as u64) << 32)
                        .wrapping_add(seq),
                );
                probe_stripe_lane(z, n, &self.stripe_excluded).unwrap_or(home)
            }
            VciStriping::Off => home,
        }
    }

    /// Drop window `win_id`'s striped-completion counters from every VCI
    /// (window free). Off the critical path, like
    /// [`MpiProc::purge_match_caches`].
    pub(super) fn purge_rma_counters(&self, win_id: u64) {
        if self.vcis.get().is_none() {
            return;
        }
        let _cs = self.enter_cs();
        let guard = self.guard();
        for i in 0..self.vcis().len() {
            let vci = self.vcis().get(i).clone();
            if vci.is_stream_owned() {
                // Stream lanes are pinned out of RMA striping, so they
                // carry no striped-completion counters; skip rather than
                // touch single-writer state from a foreign thread.
                if vci.stream_owned_by(thread_token()) {
                    vci.with_state_stream(|st| {
                        st.rma_issued.retain(|(w, _), _| *w != win_id);
                        st.rma_acked.retain(|(w, _), _| *w != win_id);
                        st.lock_granted.retain(|h| (h >> 40) != win_id);
                    });
                }
                continue;
            }
            vci.with_state(guard, |st| {
                st.rma_issued.retain(|(w, _), _| *w != win_id);
                st.rma_acked.retain(|(w, _), _| *w != win_id);
                st.lock_granted.retain(|h| (h >> 40) != win_id);
            });
        }
    }

    /// Shard-anchored request allocation: the VCI whose request cache a
    /// striped receive with concrete source `src` allocates from. Derived
    /// from the stream's matching shard, so concurrent receivers posting
    /// for different sources spread their allocation locks over the pool
    /// instead of all funneling through the communicator's home VCI — the
    /// last shared lock on the striped receive-post path. Single-shard
    /// policies (the PR-1 home-engine arm) and degenerate pools keep the
    /// home VCI.
    pub(super) fn shard_anchor_vci(&self, comm: &Comm, src: usize) -> usize {
        let n = self.vcis().len();
        let shard_mask = comm.policy.shard_mask();
        if n <= 1 || shard_mask == 0 {
            return self.comm_vci(comm, None);
        }
        let shard = super::shard::shard_index(comm.id, src, shard_mask);
        let z = crate::util::mix64(
            comm.id
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(0xA5A5_0000u64)
                .wrapping_add(shard as u64),
        );
        // Probe past pinned lanes (like hashed stripe selection): the
        // anchor is purely local, but allocating on an ordered comm's
        // lane would contend with exactly the latency traffic the pin
        // protects. All lanes pinned degenerates to the home VCI.
        probe_stripe_lane(z, n, &self.stripe_excluded)
            .unwrap_or_else(|| self.comm_vci(comm, None))
    }

    /// The lane space collective segments may target on `comm`: the local
    /// pool, bounded by the smallest context pool any member actually
    /// opened (hardware may grant a process fewer contexts than requested
    /// — paper §4.2's "smaller pool" path). Bounding by the comm-wide
    /// minimum makes the deterministic lane derivations below
    /// wire-symmetric even across asymmetric pools: every derived lane is
    /// `< space <=` every member's pool, so the mirror-context reduction
    /// (`lane % remote_open`) is the identity on both sides and a
    /// sender's segment always lands on the lane the receiver posted.
    /// Pure function of post-init state (open counts are final once init
    /// completes, and collectives only run after init).
    fn coll_lane_space(&self, comm: &Comm) -> usize {
        let mut space = self.vcis().len();
        match &comm.kind {
            CommKind::Procs => {
                for p in 0..comm.size {
                    space = space.min(self.fabric.open_count(p).max(1));
                }
            }
            CommKind::Group { procs } => {
                for &p in procs.iter() {
                    space = space.min(self.fabric.open_count(p).max(1));
                }
            }
            // Unreachable from the collectives lane paths (endpoints
            // comms return None before consulting the space).
            CommKind::Endpoints { .. } => {}
        }
        space
    }

    /// The dedicated collective lane of a `vcmpi_collectives=dedicated`
    /// communicator, reserved eagerly at [`MpiProc::register_comm`] and
    /// placed on the **least-loaded** unpinned lane of the comm's minimum
    /// member pool ([`MpiProc::coll_lane_space`]). Load is counted only
    /// from prior dedicated placements in this table — a pure function of
    /// the comm-creation sequence, which the collective wire contract
    /// already requires to be identical on every member (the same
    /// symmetry argument as `num_vcis`; process-local pin state is
    /// deliberately NOT probed). Ties break by a scrambled probe start
    /// derived from the comm id, so two comms created in the same order
    /// on every rank still agree on a lane while avoiding a fixed bias
    /// toward lane 1. This replaces the old pure comm-id hash, under
    /// which two dedicated comms could collide on one lane and serialize
    /// each other's collectives.
    ///
    /// Reserving pins the lane out of the stripe-lane set, so a hot
    /// striped comm's p2p storm sharing the pool cannot
    /// head-of-line-block this comm's collectives; `comm_free` releases
    /// the pin. Also a test/bench aid (proves the reserve/release
    /// lifecycle via `stripe_lane_pinned`).
    pub fn dedicated_coll_lane(&self, comm: &Comm) -> usize {
        let space = self.coll_lane_space(comm);
        if space <= 1 {
            return FALLBACK_VCI;
        }
        let mut lanes = self.coll_lanes.lock(LockClass::HostCollLanes);
        if let Some(&l) = lanes.get(&comm.id) {
            return l;
        }
        // Placement load per candidate lane (lanes 1..space; lane 0 is
        // the home/fallback VCI and never dedicated). Placements outside
        // this comm's space (a wider sibling comm's lane) don't contend
        // for these candidates and are ignored.
        let mut load = vec![0u32; space];
        for &l in lanes.values() {
            if l < space {
                load[l] += 1;
            }
        }
        let start = scrambled_lane(
            comm.id.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xC011_EC71),
            space,
        );
        let mut lane = start;
        for k in 0..space - 1 {
            let cand = 1 + (start - 1 + k) % (space - 1);
            if load[cand] < load[lane] {
                lane = cand;
            }
        }
        // Pin while holding the table lock: a racing placement on
        // another thread blocks on the mutex above and then finds the
        // entry, so the pin refcount rises exactly once per comm.
        self.pin_ordered_lane(lane);
        lanes.insert(comm.id, lane);
        lane
    }

    /// Topology-aware segment count for one pipelined collective chunk of
    /// `chunk_bytes`, used when the comm's policy says
    /// `vcmpi_coll_segments=auto`. Balances the fabric cost model's
    /// per-byte DMA time against the fixed per-segment launch cost: with
    /// `k` segments the pipeline's exposed latency is roughly
    /// `k·(wire_latency + nic_inject) + dma(chunk)/k`, minimized at
    /// `k = sqrt(dma(chunk) / (wire_latency + nic_inject))`. Small
    /// chunks collapse to one segment; chunks past the rendezvous
    /// threshold get at least enough segments for each to stay on the
    /// eager path. Clamped to `1..=`[`MAX_COLL_SEGMENTS`]. Symmetric:
    /// every member sees the same cost model and chunk size.
    pub fn auto_coll_segments(&self, chunk_bytes: usize) -> usize {
        if chunk_bytes == 0 {
            return 1;
        }
        let per_seg = (self.costs.wire_latency + self.costs.nic_inject).max(1);
        let balanced = (self.costs.dma_cost(chunk_bytes) as f64 / per_seg as f64).sqrt() as usize;
        let eager_floor = chunk_bytes.div_ceil(self.costs.rendezvous_threshold.max(1));
        balanced.max(eager_floor).clamp(1, MAX_COLL_SEGMENTS)
    }

    /// The VCI override for one collective segment on `comm`, per its
    /// policy's `vcmpi_collectives` mode. `None` (inherit) routes the
    /// segment through the communicator's regular two-sided path — a
    /// striped comm stripes it per message with receiver-side reordering,
    /// an ordered comm funnels it through the home VCI. `Dedicated`
    /// forces the comm's reserved lane. `Striped` spreads segments over
    /// the comm's [`coll_lane_space`](MpiProc::coll_lane_space) by the
    /// pure (comm, sender rank, tag) envelope hash — the same
    /// [`scrambled_lane`] formula as [`MpiProc::vci_for_envelope`], legal
    /// without the §7 hint assertions because the collective internal tag
    /// space never posts wildcards; per-segment tags fan one collective's
    /// segments across many lanes, and both sides derive the same lane
    /// from the envelope alone.
    pub(super) fn coll_segment_vci(&self, comm: &Comm, src_rank: usize, tag: i32) -> Option<usize> {
        if comm.is_endpoints() {
            return None;
        }
        match comm.policy.collectives {
            CollectivesMode::Inherit => None,
            CollectivesMode::Dedicated => Some(self.dedicated_coll_lane(comm)),
            CollectivesMode::Striped => {
                let space = self.coll_lane_space(comm);
                if space <= 1 {
                    return Some(FALLBACK_VCI);
                }
                Some(scrambled_lane(
                    comm.id
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add((src_rank as u64) << 32)
                        .wrapping_add(tag as u32 as u64),
                    space,
                ))
            }
        }
    }

    /// Which VCI a progress call on behalf of a request mapped to
    /// `req_vci` should poll. `striped`/`doorbell` come from the request's
    /// own communicator policy (recorded in the request slot at
    /// initiation): a striped comm's traffic lands on every stripe lane,
    /// so its waiters sweep the pool round-robin (pinning to the request's
    /// VCI could starve a stream whose gap-filling message sits on another
    /// context); an ordered comm's waiter polls only the request's VCI,
    /// per the configured progress model.
    ///
    /// With `doorbell` the sweep consults the pool's rx-nonempty bitmask:
    /// the rotation lands on the next VCI whose doorbell is rung, and
    /// `None` means *no* VCI has anything queued — the caller skips the
    /// poll entirely instead of paying an empty CQ read per VCI. Either
    /// way the sweep covers only lanes serving striped comms: lanes pinned
    /// by ordered/endpoints communicators are skipped (their owners poll
    /// them; the paranoid global round remains the backstop).
    pub(super) fn stripe_poll_target(
        &self,
        req_vci: usize,
        striped: bool,
        doorbell: bool,
    ) -> Option<usize> {
        let n = self.vcis().len();
        if !striped || n <= 1 {
            return Some(req_vci);
        }
        let cursor = self.stripe_poll_rr.fetch_add(1, Ordering::Relaxed) % n;
        let mask = &self.stripe_excluded;
        if !doorbell {
            if !mask.any() {
                return Some(cursor);
            }
            // The fallback lane (0) is never pinned, so this circular
            // scan always lands on an un-pinned index.
            let mut idx = cursor;
            while mask.excluded(idx) {
                idx = (idx + 1) % n;
            }
            return Some(idx);
        }
        let bell = self.vcis().doorbell().clone();
        if !mask.any() {
            return bell.next_set(cursor, n);
        }
        let mut start = cursor;
        for _ in 0..n {
            match bell.next_set(start, n) {
                None => return None,
                Some(idx) if !mask.excluded(idx) => return Some(idx),
                Some(idx) => start = (idx + 1) % n,
            }
        }
        // Every rung doorbell sits on a pinned lane (possible when all
        // stripe lanes are pinned and striped traffic funnels through a
        // pinned home). Degrade to a plain poll like the non-doorbell
        // sweep rather than skipping — returning None here would leave
        // liveness to the paranoid global round alone. The degraded poll
        // must still respect single-writer lanes: a pinned *ordered* lane
        // merely wastes the poll, but sweeping a stream-owned lane from a
        // foreign thread is a data race (and trips the SimSan owner
        // check), so step past those like the pin mask steps past pins.
        Some(self.non_stream_lane(cursor, n))
    }

    /// First lane at or after `start` (circularly) not bound as a serial
    /// execution stream. The fallback lane 0 can never be stream-owned
    /// (`stream_bind` rejects it), so the scan always terminates on a
    /// sweepable lane.
    fn non_stream_lane(&self, start: usize, n: usize) -> usize {
        let mut idx = start;
        for _ in 0..n {
            if !self.vcis().get(idx).is_stream_owned() {
                return idx;
            }
            idx = (idx + 1) % n;
        }
        FALLBACK_VCI
    }

    /// Stale/duplicate/malformed wire control messages dropped so far
    /// (instead of panicking). Diagnostic counter.
    pub fn stale_ctrl_drop_count(&self) -> u64 {
        self.stale_ctrl_drops.load(Ordering::Relaxed)
    }

    /// Reorder-stage diagnostics summed over all VCIs *and* all sharded
    /// communicator engines: (duplicate-seq drops, striped arrivals
    /// currently parked).
    pub fn reorder_stats(&self) -> (u64, usize) {
        let _cs = self.enter_cs();
        let guard = self.guard();
        let mut dups = 0u64;
        let mut parked = 0usize;
        for i in 0..self.vcis().len() {
            let v = self.vcis().get(i).clone();
            let (d, p) = if v.is_stream_owned() {
                if !v.stream_owned_by(thread_token()) {
                    // Foreign single-writer lane: skip (diagnostics only;
                    // the owner's own calls and the post-unbind sweep see
                    // its counters).
                    continue;
                }
                v.with_state_stream(|st| {
                    (st.matching.dup_seq_drops(), st.matching.reorder_parked())
                })
            } else {
                v.with_state(guard, |st| {
                    (st.matching.dup_seq_drops(), st.matching.reorder_parked())
                })
            };
            dups += d;
            parked += p;
        }
        let engines: Vec<Arc<CommMatch>> = {
            let table = self.match_engines.lock(LockClass::HostMatchEngines);
            table.values().cloned().collect()
        };
        for cm in engines {
            let (d, p) = cm.reorder_stats();
            dups += d;
            parked += p;
        }
        (dups, parked)
    }

    /// Wildcard-epoch statistics summed over this process's sharded
    /// communicator engines.
    pub fn epoch_stats(&self) -> EpochStats {
        let table = self.match_engines.lock(LockClass::HostMatchEngines);
        let mut total = EpochStats::default();
        for cm in table.values() {
            let s = cm.epoch_stats();
            total.flips += s.flips;
            total.unflips += s.unflips;
            total.wildcard_posts += s.wildcard_posts;
        }
        total
    }

    /// Striped sweeps skipped because no rx doorbell was rung.
    pub fn doorbell_skip_count(&self) -> u64 {
        self.doorbell_skips.load(Ordering::Relaxed)
    }

    /// Context polls that found nothing ready.
    pub fn empty_poll_count(&self) -> u64 {
        self.empty_polls.load(Ordering::Relaxed)
    }

    /// Cooperative yield used inside progress/wait loops.
    pub fn relax(&self) {
        pyield(self.backend);
    }

    // -----------------------------------------------------------------
    // Lane failover (deterministic fault injection — see fabric::fault)
    // -----------------------------------------------------------------

    /// Deterministic survivor choice: the first pool lane that is not the
    /// dead lane, not already failed over, not bound as a serial
    /// execution stream, and whose hardware context is still alive. Lane
    /// 0 (the fallback funnel) is a legal survivor — it can never be
    /// stream-owned. First-index order keeps the choice a pure function
    /// of (pool, kill schedule), so a seeded replay picks the same lane.
    fn pick_survivor(&self, dead: usize, failed: &HashMap<usize, usize>) -> Option<usize> {
        (0..self.vcis().len()).find(|&i| {
            i != dead
                && !failed.contains_key(&i)
                && !self.vcis().get(i).is_stream_owned()
                && !self.fabric.ctx_killed(self.vcis().get(i).ctx_index)
        })
    }

    /// Quarantine a hard-failed VCI lane and migrate its state to a
    /// survivor (the recovery half of the deterministic fault layer; see
    /// docs/ARCHITECTURE.md § "Fault model & lane failover"). Returns
    /// true iff this call performed the migration — a second detection
    /// of the same dead lane (any thread) is a counted no-op.
    ///
    /// Sequence, each step shaped by a lock-discipline constraint:
    ///  1. Idempotence gate + survivor choice under the `HostFailover`
    ///     leaf lock, released before any VCI lock is taken (host
    ///     mutexes must never be held across a PMutex park).
    ///  2. Publish the redirects — pool (`VciPool::set_redirect`) for
    ///     local ops and polls, fabric (`install_ctx_redirect`) for
    ///     inbound wire frames still targeting the dead context.
    ///  3. Quarantine the dead lane out of the stripe set and transfer
    ///     its ordered-pin refcounts and dedicated collective lanes to
    ///     the survivor.
    ///  4. Migrate matching/completion state dead -> survivor strictly
    ///     SEQUENTIALLY: take under the dead lane's lock, release,
    ///     absorb under the survivor's — the Vci lock class forbids
    ///     holding two at once.
    ///
    /// A lane bound as a serial execution stream cannot fail over
    /// transparently (the single-writer contract pins it 1:1 to its
    /// context); that case is a deterministic diagnostic panic telling
    /// the owner to rebind.
    pub(super) fn failover_vci(&self, dead: usize) -> bool {
        let survivor = {
            let mut failed = self.failed_lanes.lock(LockClass::HostFailover);
            if failed.contains_key(&dead) {
                return false;
            }
            let dv = self.vcis().get(dead);
            assert!(
                !dv.is_stream_owned(),
                "VCI lane {dead} (ctx {}) hard-failed at t={}ns while bound as a serial \
                 execution stream: a stream pins its lane 1:1, so transparent failover would \
                 break the single-writer contract — the owner must rebind (stream_unbind + \
                 stream_bind on a surviving lane) to recover",
                dv.ctx_index,
                pnow(self.backend),
            );
            let survivor = self.pick_survivor(dead, &failed).unwrap_or_else(|| {
                panic!(
                    "VCI lane {dead} hard-failed at t={}ns with no survivor left: every \
                     other lane is already failed, stream-owned, or on a killed context",
                    pnow(self.backend),
                )
            });
            failed.insert(dead, survivor);
            survivor
        };
        let dv = self.vcis().get(dead).clone();
        let sv = self.vcis().get(survivor).clone();
        dv.set_failed();
        // Publish the redirects: from here, new local ops resolve to the
        // survivor and the fabric delivers frames aimed at the dead
        // context to the survivor's (the reliability layer's logical
        // channel keys keep sequence continuity across the switch).
        self.vcis().set_redirect(dead, survivor);
        self.fabric.install_ctx_redirect(dv.ctx_index, sv.ctx_index);
        // Quarantine the dead lane out of the stripe set and move its
        // ordered-comm pins onto the survivor, in one pin-table critical
        // section. The fallback lane is exempt on both ends: lane 0 is
        // never a stripe lane, carries no pins, and the sweep's circular
        // scans rely on it staying unpinned.
        if dead != FALLBACK_VCI {
            let mut pins = self.ordered_pins.lock(LockClass::HostOrderedPins);
            let inherited = pins.get(&dead).copied().unwrap_or(0);
            *pins.entry(dead).or_insert(0) += 1; // quarantine pin, never released
            self.stripe_excluded.pin(dead);
            if inherited > 0 && survivor != FALLBACK_VCI {
                *pins.entry(survivor).or_insert(0) += inherited;
                self.stripe_excluded.pin(survivor);
            }
        }
        // Dedicated collective lanes parked on the dead lane move whole:
        // their segments' wire derivation is unchanged (remote members
        // are healthy), only the local issue/poll lane switches.
        {
            let mut lanes = self.coll_lanes.lock(LockClass::HostCollLanes);
            for l in lanes.values_mut() {
                if *l == dead {
                    *l = survivor;
                }
            }
        }
        // State migration, sequential. Everything a waiter could still
        // depend on moves; the dead lane's request cache stays parked
        // (ids idle until finalize — bounded, never reused).
        let guard = self.guard();
        let moved = dv.with_state(guard, |st| MigratedLane {
            matching: st.matching.take_parts(),
            pending_sends: std::mem::take(&mut st.pending_sends),
            acked: std::mem::take(&mut st.acked),
            rma_issued: std::mem::take(&mut st.rma_issued),
            rma_acked: std::mem::take(&mut st.rma_acked),
            get_done: std::mem::take(&mut st.get_done),
            fetch_done: std::mem::take(&mut st.fetch_done),
            lock_granted: std::mem::take(&mut st.lock_granted),
            send_seq: std::mem::take(&mut st.send_seq),
            // Dropped, not migrated: the survivor re-resolves engine
            // handles through the process table on first use.
            match_cache: std::mem::take(&mut st.match_cache),
        });
        sv.with_state(guard, |st| {
            st.matching.absorb_parts(moved.matching);
            st.pending_sends.extend(moved.pending_sends);
            st.acked.extend(moved.acked);
            for (k, v) in moved.rma_issued {
                *st.rma_issued.entry(k).or_insert(0) += v;
            }
            for (k, v) in moved.rma_acked {
                *st.rma_acked.entry(k).or_insert(0) += v;
            }
            st.get_done.extend(moved.get_done);
            st.fetch_done.extend(moved.fetch_done);
            st.lock_granted.extend(moved.lock_granted);
            for (k, v) in moved.send_seq {
                let e = st.send_seq.entry(k).or_insert(0);
                *e = (*e).max(v);
            }
        });
        drop(moved.match_cache);
        super::instrument::count_failover();
        super::instrument::record_failover();
        // Flush anything the dead context's unacked ring still owes the
        // wire: retransmits re-roll their fault decision and re-inject
        // immediately instead of waiting for the next timeout sweep.
        self.fabric.drive_retransmits();
        true
    }

    /// The survivor lane `idx` failed over to, if it hard-failed.
    /// Test/bench aid (proves the quarantine/migration lifecycle).
    pub fn failed_lane_target(&self, idx: usize) -> Option<usize> {
        self.failed_lanes.lock(LockClass::HostFailover).get(&idx).copied()
    }
}

/// State moved off a dead lane by [`MpiProc::failover_vci`]: everything
/// in a `VciState` an in-flight operation could still depend on. Taken
/// whole under the dead lane's lock, absorbed under the survivor's — the
/// two locks are never held together.
struct MigratedLane {
    matching: super::matching::MatchingParts,
    pending_sends: HashMap<u64, super::vci::PendingSend>,
    acked: HashSet<u64>,
    rma_issued: HashMap<(u64, usize), u64>,
    rma_acked: HashMap<(u64, usize), u64>,
    get_done: HashMap<u64, Vec<u8>>,
    fetch_done: HashMap<u64, Vec<u8>>,
    lock_granted: HashSet<u64>,
    send_seq: HashMap<(u64, usize), u64>,
    match_cache: HashMap<u64, Arc<CommMatch>>,
}

/// Virtual-time budget for any single unbounded progress-spin window
/// (`wait_grant`, flush watermarks, `coll_wait`, fetch-op spins): far
/// past any legitimate wait in the shipped scenarios, comfortably before
/// the DES's own 300s wall so the diagnostic names the stuck wait
/// instead of the generic time-limit abort.
pub(super) const SPIN_DEADLINE_NS: u64 = 120_000_000_000;

/// Diagnostic watchdog for unbounded progress-spin loops (sim backend
/// only — native time is wall-clock). Construct at wait entry, call
/// [`SpinDeadline::check`] each iteration with a closure naming the
/// window/target/lane; past the deadline it panics with that context —
/// the deadlock diagnostic the fault plans' dropped-frame storms turn
/// from a silent hang into an actionable message.
pub(super) struct SpinDeadline {
    deadline: u64,
    backend: Backend,
}

impl SpinDeadline {
    pub(super) fn new(backend: Backend) -> Self {
        SpinDeadline {
            deadline: pnow(backend).saturating_add(SPIN_DEADLINE_NS),
            backend,
        }
    }

    #[track_caller]
    pub(super) fn check(&self, context: impl FnOnce() -> String) {
        if self.backend == Backend::Sim && pnow(self.backend) > self.deadline {
            panic!(
                "progress spin exceeded {}s of virtual time: {}",
                SPIN_DEADLINE_NS / 1_000_000_000,
                context()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::PinMask;

    #[test]
    fn pin_mask_covers_lanes_beyond_one_word() {
        // The old single-u64 mask silently ignored lanes >= 64; the word
        // array must pin and probe them like any other lane.
        let m = PinMask::new(130);
        assert!(!m.any());
        for idx in [1usize, 63, 64, 100, 129] {
            assert!(!m.excluded(idx));
            m.pin(idx);
            assert!(m.excluded(idx), "lane {idx} should pin");
        }
        assert!(m.any());
        assert!(!m.excluded(65), "neighbors stay unpinned");
        for idx in [1usize, 63, 64, 100, 129] {
            m.unpin(idx);
            assert!(!m.excluded(idx));
        }
        assert!(!m.any());
    }

    #[test]
    fn pin_mask_is_idempotent_per_bit() {
        // The refcounting lives in `ordered_pins`; the mask itself is a
        // set — double-pinning one lane must not wedge the pinned count.
        let m = PinMask::new(4);
        m.pin(2);
        m.pin(2);
        assert!(m.any());
        m.unpin(2);
        assert!(!m.any(), "count tracks distinct pinned lanes, not pin calls");
        assert!(!m.excluded(2));
    }

    #[test]
    fn pin_mask_out_of_range_reads_are_unpinned() {
        let m = PinMask::new(8);
        assert!(!m.excluded(512), "beyond-capacity lanes read unpinned");
    }

    #[test]
    fn poll_target_never_sweeps_a_stream_owned_lane() {
        // Satellite fix: no progress sweep — masked scan or doorbell
        // degrade — may land on a single-writer VCI from a foreign thread.
        use crate::fabric::{FabricConfig, Interconnect, Network};
        use crate::mpi::config::MpiConfig;
        use crate::platform::Backend;
        use crate::sim::CostModel;
        use std::sync::Arc;

        let net = Network::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 1,
                procs_per_node: 1,
                max_contexts_per_node: 8,
            },
            Backend::Native,
            Arc::new(CostModel::default()),
        );
        let mut cfg = MpiConfig::optimized(1);
        cfg.num_vcis = 4;
        let proc = super::MpiProc::new(net.proc_fabric(0), cfg);
        proc.init();
        let world = proc.comm_world();
        let comm = proc.comm_dup(&world);
        let lane = proc.stream_bind(&comm);
        assert_ne!(lane, super::FALLBACK_VCI);
        assert!(proc.stream_lane_owned(lane));
        assert!(proc.stripe_lane_pinned(lane), "a stream lane is pinned out of the stripe set");
        let n = proc.vcis().len();
        // The masked circular scan steps past the stream lane on every
        // rotation (stream lanes ride the same pin mask as ordered pins).
        for _ in 0..4 * n {
            let target = proc.stripe_poll_target(super::FALLBACK_VCI, true, false);
            assert_ne!(target, Some(lane), "masked sweep landed on a stream-owned lane");
        }
        // The doorbell degrade path polls `non_stream_lane(cursor)`: from
        // any cursor — including the stream lane itself — the degraded
        // poll must step past single-writer lanes.
        for start in 0..n {
            assert_ne!(
                proc.non_stream_lane(start, n),
                lane,
                "doorbell degrade from cursor {start} swept a stream-owned lane"
            );
        }
        // Unbind returns the lane to the sweepable set.
        proc.stream_unbind(&comm);
        assert!(!proc.stream_lane_owned(lane));
        assert_eq!(proc.non_stream_lane(lane, n), lane);
        proc.comm_free(comm);
        proc.finalize();
    }
}
