//! `vcmpi` — the MPI-3.1-subset library with internal multi-VCI support
//! (the paper's contribution) plus the user-visible Endpoints extension
//! (the proposal it argues against).
//!
//! Module map (see DESIGN.md §5):
//!  * [`config`] — every knob the paper ablates (per-comm knobs demoted
//!    to process-wide defaults)
//!  * [`policy`] — per-communicator `CommPolicy` resolved from MPI-4
//!    info keys (striping / shards / linger / doorbell / assertions)
//!  * [`vci`] — VCI objects, pool, mapping policies, lock discipline
//!  * [`matching`] — <comm, rank, tag> matching with wildcards + ordering
//!  * [`shard`] — per-source sharded matching + wildcard epochs (striping)
//!  * [`request`] — global pool / per-VCI caches / lightweight requests
//!  * [`p2p`] — isend/irecv/ssend/wait and the eager/rendezvous protocols
//!  * [`progress`] — per-VCI / global / hybrid progress + wire handlers
//!  * [`rma`] — windows, put/get/accumulate/fetch-op, flush, win_free
//!  * [`collectives`] — barrier/bcast/allgather/allreduce over p2p
//!  * [`coll_nb`] — nonblocking collectives: resumable segment schedules
//!    advanced by progress hook 0 (`MPI_Iallreduce`/`MPI_Ibcast`)
//!  * [`endpoints`] — user-visible endpoints (comparison arm)
//!  * [`proc`] — process state, MPI_Init/Finalize, connection setup
//!  * [`world`] — cluster runner: spawns processes x threads on either
//!    backend and runs a workload closure per thread
//!  * [`instrument`] — lock/atomic counters (Table 1)

pub mod coll_nb;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod endpoints;
pub mod instrument;
pub mod matching;
pub mod p2p;
pub mod policy;
pub mod proc;
pub mod progress;
pub mod request;
pub mod rma;
pub mod shard;
pub mod vci;
pub mod world;

pub use coll_nb::{CollReq, RedOp};
pub use comm::{Comm, CommKind};
pub use config::{CsMode, Hints, MpiConfig, VciPolicy, VciStriping};
pub use matching::{Src, Tag};
pub use policy::{CollectivesMode, CommPolicy, Info, MAX_COLL_SEGMENTS, WinPolicy};
pub use shard::{CommMatch, EpochStats};
pub use proc::MpiProc;
pub use request::Request;
pub use rma::{GetHandle, Window};
pub use crate::fabric::LockKind;
pub use world::{run_cluster, ClusterSpec, RunReport};
