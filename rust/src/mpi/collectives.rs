//! Collectives built over point-to-point: barrier (dissemination), bcast
//! (binomial), allgather (ring), allreduce (ring, bandwidth-optimal — used
//! by the dist-train coordinator for gradient exchange).
//!
//! Collectives use a reserved internal tag space so they never match user
//! traffic on the same communicator.

use super::matching::{Src, Tag};
use super::proc::MpiProc;
use super::Comm;

/// Base of the internal (collective) tag space.
pub const INTERNAL_TAG_BASE: i32 = 1 << 24;

impl MpiProc {
    /// MPI_Barrier: dissemination algorithm — ceil(log2(n)) rounds.
    pub fn barrier(&self, comm: &Comm) {
        self.barrier_progressing(comm, None);
    }

    /// Barrier that additionally progresses `extra_vci` while waiting —
    /// models MPI_Win_free's "keep progressing my window's VCI" behavior
    /// (paper Fig. 15).
    pub fn barrier_progressing(&self, comm: &Comm, extra_vci: Option<usize>) {
        let n = comm.size;
        if n <= 1 {
            return;
        }
        let me = comm.rank;
        let mut k = 0u32;
        while (1usize << k) < n {
            let dist = 1usize << k;
            let dst = (me + dist) % n;
            let src = (me + n - dist) % n;
            let tag = INTERNAL_TAG_BASE + k as i32;
            let sreq = self.isend(comm, dst, tag, &[]);
            let rreq = self.irecv(comm, Src::Rank(src), Tag::Value(tag));
            if let Some(v) = extra_vci {
                // Poke the extra VCI between waits (win_free semantics).
                let _cs = self.enter_cs();
                self.progress_vci(v);
            }
            self.wait(sreq);
            self.wait(rreq);
            k += 1;
        }
    }

    /// MPI_Bcast (binomial tree) of a byte buffer from `root`.
    pub fn bcast(&self, comm: &Comm, root: usize, data: Option<Vec<u8>>) -> Vec<u8> {
        let n = comm.size;
        if n <= 1 {
            return data.expect("root must supply data");
        }
        let me = (comm.rank + n - root) % n; // virtual rank with root at 0
        let tag = INTERNAL_TAG_BASE + 1024;
        let mut buf = data;
        // Receive from parent (virtual rank: clear lowest set bit).
        if me != 0 {
            let parent_virt = me & (me - 1);
            let parent = (parent_virt + root) % n;
            let got = self.recv(comm, Src::Rank(parent), Tag::Value(tag));
            buf = Some(got);
        }
        let buf = buf.expect("bcast buffer");
        // Send to children: me + 2^j for j past my lowest set bit.
        let lowbit = if me == 0 { usize::BITS } else { me.trailing_zeros() };
        let mut j = 0u32;
        while j < lowbit && (me | (1 << j)) < n {
            if (1usize << j) > me {
                // children are me + 2^j where 2^j > me's low bits region
            }
            let child_virt = me | (1 << j);
            if child_virt != me && child_virt < n {
                let child = (child_virt + root) % n;
                self.send(comm, child, tag, &buf);
            }
            j += 1;
        }
        buf
    }

    /// MPI_Allgather of one u64 per rank (used by init's address exchange).
    pub fn allgather_u64(&self, comm: &Comm, mine: u64) -> Vec<u64> {
        let bytes =
            self.allgather_bytes(comm, &mine.to_le_bytes());
        bytes
            .iter()
            .map(|b| u64::from_le_bytes(b.as_slice().try_into().expect("8-byte entries")))
            .collect()
    }

    /// MPI_Allgather (ring): every rank contributes `mine`, gets all
    /// contributions in rank order.
    pub fn allgather_bytes(&self, comm: &Comm, mine: &[u8]) -> Vec<Vec<u8>> {
        let n = comm.size;
        let me = comm.rank;
        let mut out: Vec<Option<Vec<u8>>> = vec![None; n];
        out[me] = Some(mine.to_vec());
        if n == 1 {
            return out.into_iter().map(|o| o.unwrap()).collect();
        }
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let tag = INTERNAL_TAG_BASE + 2048;
        // Ring: at step s, send the block that originated at (me - s) and
        // receive the block that originated at (me - s - 1).
        for s in 0..n - 1 {
            let send_origin = (me + n - s) % n;
            let recv_origin = (me + n - s - 1) % n;
            let block = out[send_origin].clone().expect("pipeline invariant");
            let sreq = self.isend(comm, right, tag + s as i32, &block);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag + s as i32));
            let data = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            out[recv_origin] = Some(data);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    /// Ring allreduce (sum) over an f32 buffer — the gradient-exchange
    /// workhorse. Bandwidth-optimal: 2(n-1) steps over n chunks.
    pub fn allreduce_f32(&self, comm: &Comm, data: &mut [f32]) {
        let n = comm.size;
        if n == 1 {
            return;
        }
        let me = comm.rank;
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let len = data.len();
        // Chunk boundaries (n chunks, last may be ragged).
        let bounds: Vec<(usize, usize)> = (0..n)
            .map(|i| {
                let per = len.div_ceil(n);
                let lo = (i * per).min(len);
                let hi = ((i + 1) * per).min(len);
                (lo, hi)
            })
            .collect();
        let tag = INTERNAL_TAG_BASE + 4096;
        // Phase 1: reduce-scatter. After step s, rank r owns the full sum
        // of chunk (r+1-... ) — standard ring schedule.
        for s in 0..n - 1 {
            let send_chunk = (me + n - s) % n;
            let recv_chunk = (me + n - s - 1) % n;
            let (lo, hi) = bounds[send_chunk];
            let payload: Vec<u8> = data[lo..hi].iter().flat_map(|f| f.to_le_bytes()).collect();
            let sreq = self.isend(comm, right, tag + s as i32, &payload);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag + s as i32));
            let got = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            let (rlo, rhi) = bounds[recv_chunk];
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                if rlo + i < rhi {
                    data[rlo + i] += f32::from_le_bytes(chunk.try_into().unwrap());
                }
            }
        }
        // Phase 2: allgather the reduced chunks.
        let tag2 = tag + n as i32;
        for s in 0..n - 1 {
            let send_chunk = (me + 1 + n - s) % n;
            let recv_chunk = (me + n - s) % n;
            let (lo, hi) = bounds[send_chunk];
            let payload: Vec<u8> = data[lo..hi].iter().flat_map(|f| f.to_le_bytes()).collect();
            let sreq = self.isend(comm, right, tag2 + s as i32, &payload);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag2 + s as i32));
            let got = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            let (rlo, rhi) = bounds[recv_chunk];
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                if rlo + i < rhi {
                    data[rlo + i] = f32::from_le_bytes(chunk.try_into().unwrap());
                }
            }
        }
    }

    /// Allreduce a single f64 (sum) — convenience for scalar metrics.
    pub fn allreduce_scalar(&self, comm: &Comm, x: f64) -> f64 {
        let all = self.allgather_bytes(comm, &x.to_le_bytes());
        all.iter()
            .map(|b| f64::from_le_bytes(b.as_slice().try_into().unwrap()))
            .sum()
    }
}
