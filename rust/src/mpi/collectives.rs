//! Collectives built over point-to-point — **segmented, pipelined, and
//! multi-lane** (the per-comm collectives policy): barrier (dissemination
//! with pre-posted rounds), bcast (binomial tree with segment pipelining
//! down the tree), allgather (ring with pre-posted step receives), and
//! allreduce (segmented ring, bandwidth-optimal — the gradient-exchange
//! workhorse of the dist-train coordinator).
//!
//! # Segmentation and pipelining
//!
//! The old collectives serialized every ring/tree step through blocking
//! `wait` pairs on one logical channel: the whole chunk had to cross the
//! wire — and be handled by the target — before the next step started.
//! Now each allreduce ring step's chunk (and each bcast tree hop's
//! payload) is split into `vcmpi_coll_segments` independently tagged
//! nonblocking transfers:
//!
//! * every step's receives are **pre-posted** (sources and tags are fully
//!   determined up front), so arrivals never wait in unexpected queues;
//! * a segment is reduced — and the *next* step's copy of it forwarded —
//!   the moment it lands, while the remaining segments of the same step
//!   are still in flight (reduce-scatter step *s+1*'s injection overlaps
//!   step *s*'s tail);
//! * small payloads degenerate gracefully: the per-chunk segment count
//!   never exceeds the chunk's element count, so a scalar allreduce costs
//!   exactly the classic 2(n-1) tiny messages.
//!
//! # Lane mapping (the `vcmpi_collectives` decision table)
//!
//! | `vcmpi_collectives` | comm's `vcmpi_striping` | segment path | lanes used |
//! |---------------------|-------------------------|--------------|------------|
//! | `inherit` (default) | `off`                   | plain nonblocking isend/irecv | the comm's home VCI (or the §7 hinted spread) |
//! | `inherit`           | `rr`\|`hash`            | striped isend (seq reorder, shard engine) | stripe lanes, per message |
//! | `dedicated`         | any                     | explicit-lane isend/irecv | ONE reserved lane, **pinned** out of the stripe set |
//! | `striped`           | any                     | explicit-lane isend/irecv | `1 + hash(comm, sender, tag) % (pool-1)`, per segment |
//!
//! `dedicated` reserves (pins) the least-loaded lane at comm creation,
//! deterministically across ranks — see `MpiProc::dedicated_coll_lane` —
//! so a hot striped comm's p2p storm sharing the pool can never
//! head-of-line-block an allreduce, and two dedicated comms land on
//! distinct lanes while the pool has them;
//! the pin is released at `comm_free`. `striped` spreads a single
//! collective's segments over the pool by the pure envelope hash (legal
//! without the §7 wildcard assertions because this tag space never posts
//! wildcards); pins are *not* probed — pin state is process-local and
//! probing it would break the wire-contract symmetry of the lane choice,
//! so a segment may occasionally share a pinned lane.
//!
//! # Blocking vs nonblocking (the operation rows of the decision table)
//!
//! | operation | shape | driven by |
//! |-----------|-------|-----------|
//! | `barrier` / `allgather_*` | blocking, pre-posted rounds/steps | the calling thread's `wait`s |
//! | `bcast` | `ibcast` + `coll_wait` | progress hook 0 + the waiter |
//! | `allreduce_f32` / `allreduce_scalar` | `iallreduce` + `coll_wait` | progress hook 0 + the waiter |
//! | `iallreduce` / `ibcast` (`mpi::coll_nb`) | resumable [`CollSched`](super::coll_nb::CollSched) state machine | **any** thread's progress call (hook 0), `coll_wait`/`coll_test` |
//!
//! The nonblocking forms are the primitive: initiation pre-posts the
//! FULL receive schedule (every phase/step/segment — legal because the
//! tag space below is unique per position) and registers a resumable
//! schedule that every progress iteration's `check_hooks` advances, so
//! the collective proceeds while the initiator computes (the trainer's
//! bucket overlap). The blocking forms are literally initiate + wait —
//! one engine, so blocking/nonblocking results are bit-identical by
//! construction. See `mpi::coll_nb` for the state-machine and
//! progress-hook contract (lock ordering, re-entrancy, retirement).
//!
//! # Internal tag space
//!
//! Collectives use a reserved tag space (`>= INTERNAL_TAG_BASE`) so they
//! never match user traffic on the same communicator, partitioned per
//! (collective op, ring/tree position, segment):
//!
//! * barrier: `INTERNAL_TAG_BASE + round`
//! * bcast: `INTERNAL_TAG_BASE + 1024 + segment`
//! * allgather: `INTERNAL_TAG_BASE + 2048 + step`
//! * allreduce: `INTERNAL_TAG_BASE + 4096 +
//!   (phase·(n-1) + step)·MAX_COLL_SEGMENTS + segment`
//!
//! Collectives on one communicator are non-concurrent (MPI's ordering
//! rule), so tags may be reused across invocations — which is also why
//! at most ONE nonblocking collective may be outstanding per
//! communicator (enforced at initiation; overlap uses distinct comms).

use super::coll_nb::RedOp;
use super::instrument;
use super::matching::{Src, Tag};
use super::policy::MAX_COLL_SEGMENTS;
use super::proc::MpiProc;
use super::request::Request;
use super::Comm;

/// Base of the internal (collective) tag space.
pub const INTERNAL_TAG_BASE: i32 = 1 << 24;
const BCAST_TAG: i32 = INTERNAL_TAG_BASE + 1024;
const ALLGATHER_TAG: i32 = INTERNAL_TAG_BASE + 2048;
const ALLREDUCE_TAG: i32 = INTERNAL_TAG_BASE + 4096;

/// Even split of `len` items into `parts` pieces: bounds of piece `i`.
/// Pure function of its inputs — every rank derives identical chunk and
/// segment boundaries from the shared payload length.
pub(super) fn part_bounds(len: usize, parts: usize, i: usize) -> (usize, usize) {
    let per = len.div_ceil(parts);
    ((i * per).min(len), ((i + 1) * per).min(len))
}

/// Allreduce segment tag: unique per (phase, ring step, segment) for an
/// n-rank ring — the tag layout the module doc specifies, shared by the
/// blocking wrapper and the nonblocking schedule (`mpi::coll_nb`).
pub(super) fn allreduce_tag(n: usize, phase: usize, step: usize, g: usize) -> i32 {
    ALLREDUCE_TAG + ((phase * (n - 1) + step) * MAX_COLL_SEGMENTS + g) as i32
}

/// Bcast segment tag (one tag per segment; every tree level reuses it —
/// sources differ per hop, so matching stays unambiguous).
pub(super) fn bcast_tag(g: usize) -> i32 {
    BCAST_TAG + g as i32
}

impl MpiProc {
    /// Issue one collective-internal segment send on `comm` (lane per the
    /// policy's collectives mode), with Table-1 accounting.
    pub(super) fn coll_isend(&self, comm: &Comm, dst: usize, tag: i32, data: &[u8]) -> Request {
        let lane = self.coll_segment_vci(comm, comm.rank, tag);
        instrument::count_coll_segment();
        if lane.is_some_and(|l| l != self.comm_vci(comm, None)) {
            instrument::count_coll_lane_spread();
        }
        self.isend_coll(comm, dst, tag, data, lane)
    }

    /// Post one collective-internal segment receive from concrete source
    /// `src` (the collective tag space never uses wildcards — that is what
    /// makes the multi-lane mapping symmetric on both sides).
    pub(super) fn coll_irecv(&self, comm: &Comm, src: usize, tag: i32) -> Request {
        let lane = self.coll_segment_vci(comm, src, tag);
        self.irecv_coll(comm, Src::Rank(src), Tag::Value(tag), lane)
    }

    /// MPI_Barrier: dissemination algorithm — ceil(log2(n)) rounds.
    pub fn barrier(&self, comm: &Comm) {
        self.barrier_progressing(comm, None);
    }

    /// Barrier that additionally progresses `extra_vci` while waiting —
    /// models MPI_Win_free's "keep progressing my window's VCI" behavior
    /// (paper Fig. 15).
    ///
    /// All rounds' receives are pre-posted up front; the round-`k` *send*
    /// is still posted only after round `k-1`'s receive completed — that
    /// ordering is what makes dissemination a barrier (a rank's round-`k`
    /// message certifies it has transitively heard from `2^k` ranks), so
    /// sends can never be batch-pre-posted.
    pub fn barrier_progressing(&self, comm: &Comm, extra_vci: Option<usize>) {
        let n = comm.size;
        if n <= 1 {
            return;
        }
        let me = comm.rank;
        let rounds = (usize::BITS - (n - 1).leading_zeros()) as usize;
        let rreqs: Vec<Request> = (0..rounds)
            .map(|k| {
                let src = (me + n - (1usize << k)) % n;
                self.coll_irecv(comm, src, INTERNAL_TAG_BASE + k as i32)
            })
            .collect();
        let mut sreqs = Vec::with_capacity(rounds);
        for (k, rreq) in rreqs.into_iter().enumerate() {
            let dst = (me + (1usize << k)) % n;
            sreqs.push(self.coll_isend(comm, dst, INTERNAL_TAG_BASE + k as i32, &[]));
            if let Some(v) = extra_vci {
                // Poke the extra VCI between waits (win_free semantics).
                let _cs = self.enter_cs();
                self.progress_vci(v);
            }
            self.wait(rreq);
        }
        self.waitall(sreqs);
    }

    /// MPI_Bcast (binomial tree) of a byte buffer from `root`, segment-
    /// pipelined: an interior node forwards each segment to its children
    /// the moment it arrives, so segment `g` travels tree level `l → l+1`
    /// while segment `g+1` is still in flight toward level `l` — the tree
    /// streams instead of storing-and-forwarding whole payloads.
    ///
    /// Literally [`MpiProc::ibcast`] + [`MpiProc::coll_wait`] — one
    /// engine for both forms. The segment count is the policy's static
    /// `vcmpi_coll_segments` (part of the wire contract — non-roots size
    /// their receive posts from it without knowing the payload length;
    /// ragged or empty trailing segments are fine).
    pub fn bcast(&self, comm: &Comm, root: usize, data: Option<Vec<u8>>) -> Vec<u8> {
        self.coll_wait(self.ibcast(comm, root, data))
    }

    /// MPI_Allgather of one u64 per rank (used by init's address exchange).
    pub fn allgather_u64(&self, comm: &Comm, mine: u64) -> Vec<u64> {
        self.allgather_bytes(comm, &mine.to_le_bytes())
            .iter()
            .map(|b| u64::from_le_bytes(b.as_slice().try_into().expect("8-byte entries")))
            .collect()
    }

    /// MPI_Allgather (ring): every rank contributes `mine`, gets all
    /// contributions in rank order. All step receives are pre-posted up
    /// front and sends are only waited once the ring completes; the block
    /// sent at step `s` is the one received at step `s-1`, so sends are
    /// data-dependent and the pipeline is receive-bounded by design.
    pub fn allgather_bytes(&self, comm: &Comm, mine: &[u8]) -> Vec<Vec<u8>> {
        let n = comm.size;
        let me = comm.rank;
        let mut out: Vec<Option<Vec<u8>>> = vec![None; n];
        out[me] = Some(mine.to_vec());
        if n == 1 {
            return out.into_iter().map(|o| o.unwrap()).collect();
        }
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let rreqs: Vec<Request> = (0..n - 1)
            .map(|s| self.coll_irecv(comm, left, ALLGATHER_TAG + s as i32))
            .collect();
        let mut sreqs = Vec::with_capacity(n - 1);
        let mut block = mine.to_vec();
        for (s, rreq) in rreqs.into_iter().enumerate() {
            let recv_origin = (me + n - s - 1) % n;
            sreqs.push(self.coll_isend(comm, right, ALLGATHER_TAG + s as i32, &block));
            let data = self.wait(rreq).expect("ring recv");
            out[recv_origin] = Some(data.clone());
            block = data;
        }
        self.waitall(sreqs);
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    /// Ring allreduce (sum) over an f32 buffer — the gradient-exchange
    /// workhorse. Literally [`MpiProc::iallreduce_f32`] +
    /// [`MpiProc::coll_wait_f32`]: the segmented, pipelined 2(n-1)-step
    /// ring schedule of `mpi::coll_nb`, driven to completion by the
    /// caller (and any concurrent progress). Reduction order per element
    /// matches the classic ring, so results are bit-identical across
    /// policies and across the blocking/nonblocking forms.
    pub fn allreduce_f32(&self, comm: &Comm, data: &mut [f32]) {
        if comm.size <= 1 {
            return;
        }
        let req = self.iallreduce_f32(comm, data);
        self.coll_wait_f32(req, data);
    }

    /// The seed's lockstep ring allreduce — whole-chunk blocking wait
    /// pairs on the communicator's regular path — kept verbatim as the
    /// ablation baseline for `bench::coll_rate` (and the figure of merit
    /// the CI gate compares the segmented multi-lane path against). New
    /// code should use [`MpiProc::allreduce_f32`].
    #[doc(hidden)]
    pub fn allreduce_f32_lockstep(&self, comm: &Comm, data: &mut [f32]) {
        let n = comm.size;
        if n == 1 {
            return;
        }
        let me = comm.rank;
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let len = data.len();
        let bounds: Vec<(usize, usize)> = (0..n).map(|i| part_bounds(len, n, i)).collect();
        let tag = ALLREDUCE_TAG;
        // Phase 1: reduce-scatter, one whole chunk per lockstep step.
        for s in 0..n - 1 {
            let send_chunk = (me + n - s) % n;
            let recv_chunk = (me + n - s - 1) % n;
            let (lo, hi) = bounds[send_chunk];
            let payload: Vec<u8> = data[lo..hi].iter().flat_map(|f| f.to_le_bytes()).collect();
            let sreq = self.isend(comm, right, tag + s as i32, &payload);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag + s as i32));
            let got = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            let (rlo, rhi) = bounds[recv_chunk];
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                if rlo + i < rhi {
                    data[rlo + i] += f32::from_le_bytes(chunk.try_into().unwrap());
                }
            }
        }
        // Phase 2: allgather the reduced chunks.
        let tag2 = tag + n as i32;
        for s in 0..n - 1 {
            let send_chunk = (me + 1 + n - s) % n;
            let recv_chunk = (me + n - s) % n;
            let (lo, hi) = bounds[send_chunk];
            let payload: Vec<u8> = data[lo..hi].iter().flat_map(|f| f.to_le_bytes()).collect();
            let sreq = self.isend(comm, right, tag2 + s as i32, &payload);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag2 + s as i32));
            let got = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            let (rlo, rhi) = bounds[recv_chunk];
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                if rlo + i < rhi {
                    data[rlo + i] = f32::from_le_bytes(chunk.try_into().unwrap());
                }
            }
        }
    }

    /// Allreduce a single f64 (sum) — convenience for scalar metrics.
    /// Routed through the segmented ring (one 8-byte element): 2(n-1)
    /// tiny messages, instead of the n² bytes the old allgather-everything
    /// implementation moved.
    pub fn allreduce_scalar(&self, comm: &Comm, x: f64) -> f64 {
        let req = self.iallreduce(comm, &x.to_le_bytes(), RedOp::SumF64);
        f64::from_le_bytes(self.coll_wait(req).as_slice().try_into().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::part_bounds;

    #[test]
    fn part_bounds_cover_exactly_and_agree() {
        for len in [0usize, 1, 7, 100, 1007] {
            for parts in [1usize, 2, 3, 8, 64] {
                let mut covered = 0;
                for i in 0..parts {
                    let (lo, hi) = part_bounds(len, parts, i);
                    assert!(lo <= hi && hi <= len);
                    assert_eq!(lo, covered, "pieces must tile contiguously");
                    covered = hi;
                }
                assert_eq!(covered, len, "pieces must cover the whole range");
            }
        }
    }
}
