//! Collectives built over point-to-point — **segmented, pipelined, and
//! multi-lane** (the per-comm collectives policy): barrier (dissemination
//! with pre-posted rounds), bcast (binomial tree with segment pipelining
//! down the tree), allgather (ring with pre-posted step receives), and
//! allreduce (segmented ring, bandwidth-optimal — the gradient-exchange
//! workhorse of the dist-train coordinator).
//!
//! # Segmentation and pipelining
//!
//! The old collectives serialized every ring/tree step through blocking
//! `wait` pairs on one logical channel: the whole chunk had to cross the
//! wire — and be handled by the target — before the next step started.
//! Now each allreduce ring step's chunk (and each bcast tree hop's
//! payload) is split into `vcmpi_coll_segments` independently tagged
//! nonblocking transfers:
//!
//! * every step's receives are **pre-posted** (sources and tags are fully
//!   determined up front), so arrivals never wait in unexpected queues;
//! * a segment is reduced — and the *next* step's copy of it forwarded —
//!   the moment it lands, while the remaining segments of the same step
//!   are still in flight (reduce-scatter step *s+1*'s injection overlaps
//!   step *s*'s tail);
//! * small payloads degenerate gracefully: the per-chunk segment count
//!   never exceeds the chunk's element count, so a scalar allreduce costs
//!   exactly the classic 2(n-1) tiny messages.
//!
//! # Lane mapping (the `vcmpi_collectives` decision table)
//!
//! | `vcmpi_collectives` | comm's `vcmpi_striping` | segment path | lanes used |
//! |---------------------|-------------------------|--------------|------------|
//! | `inherit` (default) | `off`                   | plain nonblocking isend/irecv | the comm's home VCI (or the §7 hinted spread) |
//! | `inherit`           | `rr`\|`hash`            | striped isend (seq reorder, shard engine) | stripe lanes, per message |
//! | `dedicated`         | any                     | explicit-lane isend/irecv | ONE reserved lane, **pinned** out of the stripe set |
//! | `striped`           | any                     | explicit-lane isend/irecv | `1 + hash(comm, sender, tag) % (pool-1)`, per segment |
//!
//! `dedicated` reserves (pins) a lane derived deterministically from the
//! comm id — see `MpiProc::dedicated_coll_lane` — so a hot striped comm's
//! p2p storm sharing the pool can never head-of-line-block an allreduce;
//! the pin is released at `comm_free`. `striped` spreads a single
//! collective's segments over the pool by the pure envelope hash (legal
//! without the §7 wildcard assertions because this tag space never posts
//! wildcards); pins are *not* probed — pin state is process-local and
//! probing it would break the wire-contract symmetry of the lane choice,
//! so a segment may occasionally share a pinned lane.
//!
//! # Internal tag space
//!
//! Collectives use a reserved tag space (`>= INTERNAL_TAG_BASE`) so they
//! never match user traffic on the same communicator, partitioned per
//! (collective op, ring/tree position, segment):
//!
//! * barrier: `INTERNAL_TAG_BASE + round`
//! * bcast: `INTERNAL_TAG_BASE + 1024 + segment`
//! * allgather: `INTERNAL_TAG_BASE + 2048 + step`
//! * allreduce: `INTERNAL_TAG_BASE + 4096 +
//!   (phase·(n-1) + step)·MAX_COLL_SEGMENTS + segment`
//!
//! Collectives on one communicator are non-concurrent (MPI's ordering
//! rule), so tags may be reused across invocations.

use super::instrument;
use super::matching::{Src, Tag};
use super::policy::MAX_COLL_SEGMENTS;
use super::proc::MpiProc;
use super::request::Request;
use super::Comm;

/// Base of the internal (collective) tag space.
pub const INTERNAL_TAG_BASE: i32 = 1 << 24;
const BCAST_TAG: i32 = INTERNAL_TAG_BASE + 1024;
const ALLGATHER_TAG: i32 = INTERNAL_TAG_BASE + 2048;
const ALLREDUCE_TAG: i32 = INTERNAL_TAG_BASE + 4096;

/// Even split of `len` items into `parts` pieces: bounds of piece `i`.
/// Pure function of its inputs — every rank derives identical chunk and
/// segment boundaries from the shared payload length.
fn part_bounds(len: usize, parts: usize, i: usize) -> (usize, usize) {
    let per = len.div_ceil(parts);
    ((i * per).min(len), ((i + 1) * per).min(len))
}

impl MpiProc {
    /// Issue one collective-internal segment send on `comm` (lane per the
    /// policy's collectives mode), with Table-1 accounting.
    fn coll_isend(&self, comm: &Comm, dst: usize, tag: i32, data: &[u8]) -> Request {
        let lane = self.coll_segment_vci(comm, comm.rank, tag);
        instrument::count_coll_segment();
        if lane.is_some_and(|l| l != self.comm_vci(comm, None)) {
            instrument::count_coll_lane_spread();
        }
        self.isend_coll(comm, dst, tag, data, lane)
    }

    /// Post one collective-internal segment receive from concrete source
    /// `src` (the collective tag space never uses wildcards — that is what
    /// makes the multi-lane mapping symmetric on both sides).
    fn coll_irecv(&self, comm: &Comm, src: usize, tag: i32) -> Request {
        let lane = self.coll_segment_vci(comm, src, tag);
        self.irecv_coll(comm, Src::Rank(src), Tag::Value(tag), lane)
    }

    /// Per-chunk segment count: the policy's `vcmpi_coll_segments`,
    /// bounded by the chunk's element count (at least one segment, so an
    /// empty chunk still costs exactly one empty message and the ring
    /// schedule stays uniform). Pure function of shared inputs — part of
    /// the wire contract like the tag layout.
    fn coll_segs(&self, comm: &Comm, chunk_elems: usize) -> usize {
        comm.policy.coll_segments.clamp(1, MAX_COLL_SEGMENTS).min(chunk_elems.max(1))
    }

    /// MPI_Barrier: dissemination algorithm — ceil(log2(n)) rounds.
    pub fn barrier(&self, comm: &Comm) {
        self.barrier_progressing(comm, None);
    }

    /// Barrier that additionally progresses `extra_vci` while waiting —
    /// models MPI_Win_free's "keep progressing my window's VCI" behavior
    /// (paper Fig. 15).
    ///
    /// All rounds' receives are pre-posted up front; the round-`k` *send*
    /// is still posted only after round `k-1`'s receive completed — that
    /// ordering is what makes dissemination a barrier (a rank's round-`k`
    /// message certifies it has transitively heard from `2^k` ranks), so
    /// sends can never be batch-pre-posted.
    pub fn barrier_progressing(&self, comm: &Comm, extra_vci: Option<usize>) {
        let n = comm.size;
        if n <= 1 {
            return;
        }
        let me = comm.rank;
        let rounds = (usize::BITS - (n - 1).leading_zeros()) as usize;
        let rreqs: Vec<Request> = (0..rounds)
            .map(|k| {
                let src = (me + n - (1usize << k)) % n;
                self.coll_irecv(comm, src, INTERNAL_TAG_BASE + k as i32)
            })
            .collect();
        let mut sreqs = Vec::with_capacity(rounds);
        for (k, rreq) in rreqs.into_iter().enumerate() {
            let dst = (me + (1usize << k)) % n;
            sreqs.push(self.coll_isend(comm, dst, INTERNAL_TAG_BASE + k as i32, &[]));
            if let Some(v) = extra_vci {
                // Poke the extra VCI between waits (win_free semantics).
                let _cs = self.enter_cs();
                self.progress_vci(v);
            }
            self.wait(rreq);
        }
        self.waitall(sreqs);
    }

    /// MPI_Bcast (binomial tree) of a byte buffer from `root`, segment-
    /// pipelined: an interior node forwards each segment to its children
    /// the moment it arrives, so segment `g` travels tree level `l → l+1`
    /// while segment `g+1` is still in flight toward level `l` — the tree
    /// streams instead of storing-and-forwarding whole payloads.
    ///
    /// The segment count is the policy's `vcmpi_coll_segments` (part of
    /// the wire contract — non-roots size their receive posts from it
    /// without knowing the payload length; ragged or empty trailing
    /// segments are fine).
    pub fn bcast(&self, comm: &Comm, root: usize, data: Option<Vec<u8>>) -> Vec<u8> {
        let n = comm.size;
        if n <= 1 {
            return data.expect("root must supply data");
        }
        let me = (comm.rank + n - root) % n; // virtual rank with root at 0
        let segs = comm.policy.coll_segments.clamp(1, MAX_COLL_SEGMENTS);
        // Children of virtual rank v: v + 2^j for every j below v's
        // lowest set bit (all j for the root), bounded by the comm size —
        // the binomial rule "parent = clear the lowest set bit" inverted.
        // Correct for non-power-of-two sizes and any root (regression
        // tests in tests/collectives.rs).
        let max_j = if me == 0 { usize::BITS } else { me.trailing_zeros() };
        let mut children = Vec::new();
        for j in 0..max_j {
            let child_virt = me + (1usize << j);
            if child_virt >= n {
                break;
            }
            children.push((child_virt + root) % n); // actual rank
        }
        let mut sreqs = Vec::with_capacity(children.len() * segs);
        let buf = if me == 0 {
            let buf = data.expect("root must supply data");
            for g in 0..segs {
                let (lo, hi) = part_bounds(buf.len(), segs, g);
                let tag = BCAST_TAG + g as i32;
                for &child in &children {
                    sreqs.push(self.coll_isend(comm, child, tag, &buf[lo..hi]));
                }
            }
            buf
        } else {
            let parent = ((me & (me - 1)) + root) % n;
            let rreqs: Vec<Request> = (0..segs)
                .map(|g| self.coll_irecv(comm, parent, BCAST_TAG + g as i32))
                .collect();
            let mut buf = Vec::new();
            for (g, rreq) in rreqs.into_iter().enumerate() {
                let seg = self.wait(rreq).expect("bcast segment");
                let tag = BCAST_TAG + g as i32;
                for &child in &children {
                    sreqs.push(self.coll_isend(comm, child, tag, &seg));
                }
                buf.extend_from_slice(&seg);
            }
            buf
        };
        self.waitall(sreqs);
        buf
    }

    /// MPI_Allgather of one u64 per rank (used by init's address exchange).
    pub fn allgather_u64(&self, comm: &Comm, mine: u64) -> Vec<u64> {
        self.allgather_bytes(comm, &mine.to_le_bytes())
            .iter()
            .map(|b| u64::from_le_bytes(b.as_slice().try_into().expect("8-byte entries")))
            .collect()
    }

    /// MPI_Allgather (ring): every rank contributes `mine`, gets all
    /// contributions in rank order. All step receives are pre-posted up
    /// front and sends are only waited once the ring completes; the block
    /// sent at step `s` is the one received at step `s-1`, so sends are
    /// data-dependent and the pipeline is receive-bounded by design.
    pub fn allgather_bytes(&self, comm: &Comm, mine: &[u8]) -> Vec<Vec<u8>> {
        let n = comm.size;
        let me = comm.rank;
        let mut out: Vec<Option<Vec<u8>>> = vec![None; n];
        out[me] = Some(mine.to_vec());
        if n == 1 {
            return out.into_iter().map(|o| o.unwrap()).collect();
        }
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let rreqs: Vec<Request> = (0..n - 1)
            .map(|s| self.coll_irecv(comm, left, ALLGATHER_TAG + s as i32))
            .collect();
        let mut sreqs = Vec::with_capacity(n - 1);
        let mut block = mine.to_vec();
        for (s, rreq) in rreqs.into_iter().enumerate() {
            let recv_origin = (me + n - s - 1) % n;
            sreqs.push(self.coll_isend(comm, right, ALLGATHER_TAG + s as i32, &block));
            let data = self.wait(rreq).expect("ring recv");
            out[recv_origin] = Some(data.clone());
            block = data;
        }
        self.waitall(sreqs);
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    /// Segmented, pipelined ring allreduce over a byte buffer of
    /// `elem`-byte elements, combining equal-length element-aligned slices
    /// with `reduce` (`acc ⊕= incoming`). Bandwidth-optimal 2(n-1)-step
    /// ring; each step's chunk moves as up-to-`vcmpi_coll_segments`
    /// independently tagged segments, pre-posted per step and forwarded
    /// downstream the moment each is reduced (see the module doc).
    fn allreduce_ring_segmented(
        &self,
        comm: &Comm,
        data: &mut [u8],
        elem: usize,
        reduce: &dyn Fn(&mut [u8], &[u8]),
    ) {
        let n = comm.size;
        if n <= 1 {
            return;
        }
        debug_assert_eq!(data.len() % elem, 0, "payload must be element-aligned");
        let me = comm.rank;
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let elems = data.len() / elem;
        // Byte bounds of segment g of chunk c (identical on every rank).
        let seg_bounds = |c: usize, g: usize| -> (usize, usize) {
            let (clo, chi) = part_bounds(elems, n, c);
            let (slo, shi) = part_bounds(chi - clo, self.coll_segs(comm, chi - clo), g);
            ((clo + slo) * elem, (clo + shi) * elem)
        };
        let tag_of = |phase: usize, step: usize, g: usize| -> i32 {
            ALLREDUCE_TAG + ((phase * (n - 1) + step) * MAX_COLL_SEGMENTS + g) as i32
        };
        // Chunk the ring step works on (identical formulas to the classic
        // ring schedule): phase 0 (reduce-scatter) receives chunk
        // (me - s - 1), phase 1 (allgather) receives chunk (me - s); the
        // chunk sent at step s+1 is always the chunk received at step s.
        let chunk_segs = |c: usize| -> usize {
            let (clo, chi) = part_bounds(elems, n, c);
            self.coll_segs(comm, chi - clo)
        };
        let mut sreqs: Vec<Request> = Vec::new();

        // ---- phase 1: reduce-scatter ----
        let rreqs: Vec<Vec<Request>> = (0..n - 1)
            .map(|s| {
                let recv_chunk = (me + n - s - 1) % n;
                (0..chunk_segs(recv_chunk))
                    .map(|g| self.coll_irecv(comm, left, tag_of(0, s, g)))
                    .collect()
            })
            .collect();
        // Step 0 sends my own chunk; step s+1 forwards the chunk reduced
        // at step s, segment by segment as each lands.
        for g in 0..chunk_segs(me) {
            let (lo, hi) = seg_bounds(me, g);
            sreqs.push(self.coll_isend(comm, right, tag_of(0, 0, g), &data[lo..hi]));
        }
        for (s, step_rreqs) in rreqs.into_iter().enumerate() {
            let recv_chunk = (me + n - s - 1) % n;
            for (g, rreq) in step_rreqs.into_iter().enumerate() {
                let got = self.wait(rreq).expect("allreduce segment");
                let (lo, hi) = seg_bounds(recv_chunk, g);
                debug_assert_eq!(got.len(), hi - lo, "segment length mismatch");
                reduce(&mut data[lo..hi], &got);
                if s + 1 < n - 1 {
                    // This freshly reduced segment is exactly what step
                    // s+1 sends: forward it immediately, overlapping the
                    // remaining receives of step s.
                    sreqs.push(self.coll_isend(comm, right, tag_of(0, s + 1, g), &data[lo..hi]));
                }
            }
        }

        // ---- phase 2: allgather of the reduced chunks ----
        let rreqs: Vec<Vec<Request>> = (0..n - 1)
            .map(|s| {
                let recv_chunk = (me + n - s) % n;
                (0..chunk_segs(recv_chunk))
                    .map(|g| self.coll_irecv(comm, left, tag_of(1, s, g)))
                    .collect()
            })
            .collect();
        // After reduce-scatter, rank me owns the full sum of chunk
        // (me+1) — phase 2 circulates the owned chunks.
        let own = (me + 1) % n;
        for g in 0..chunk_segs(own) {
            let (lo, hi) = seg_bounds(own, g);
            sreqs.push(self.coll_isend(comm, right, tag_of(1, 0, g), &data[lo..hi]));
        }
        for (s, step_rreqs) in rreqs.into_iter().enumerate() {
            let recv_chunk = (me + n - s) % n;
            for (g, rreq) in step_rreqs.into_iter().enumerate() {
                let got = self.wait(rreq).expect("allreduce segment");
                let (lo, hi) = seg_bounds(recv_chunk, g);
                debug_assert_eq!(got.len(), hi - lo, "segment length mismatch");
                data[lo..hi].copy_from_slice(&got);
                if s + 1 < n - 1 {
                    sreqs.push(self.coll_isend(comm, right, tag_of(1, s + 1, g), &data[lo..hi]));
                }
            }
        }
        self.waitall(sreqs);
    }

    /// Ring allreduce (sum) over an f32 buffer — the gradient-exchange
    /// workhorse. Segmented and pipelined per the comm's policy (see the
    /// module doc); reduction order per element matches the classic ring,
    /// so results are bit-identical across policies.
    pub fn allreduce_f32(&self, comm: &Comm, data: &mut [f32]) {
        if comm.size <= 1 {
            return;
        }
        let mut bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        self.allreduce_ring_segmented(comm, &mut bytes, 4, &|acc, inc| {
            for (a, b) in acc.chunks_exact_mut(4).zip(inc.chunks_exact(4)) {
                let v = f32::from_le_bytes((&a[..]).try_into().unwrap())
                    + f32::from_le_bytes(b.try_into().unwrap());
                a.copy_from_slice(&v.to_le_bytes());
            }
        });
        for (d, c) in data.iter_mut().zip(bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes(c.try_into().unwrap());
        }
    }

    /// The seed's lockstep ring allreduce — whole-chunk blocking wait
    /// pairs on the communicator's regular path — kept verbatim as the
    /// ablation baseline for `bench::coll_rate` (and the figure of merit
    /// the CI gate compares the segmented multi-lane path against). New
    /// code should use [`MpiProc::allreduce_f32`].
    #[doc(hidden)]
    pub fn allreduce_f32_lockstep(&self, comm: &Comm, data: &mut [f32]) {
        let n = comm.size;
        if n == 1 {
            return;
        }
        let me = comm.rank;
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let len = data.len();
        let bounds: Vec<(usize, usize)> = (0..n).map(|i| part_bounds(len, n, i)).collect();
        let tag = ALLREDUCE_TAG;
        // Phase 1: reduce-scatter, one whole chunk per lockstep step.
        for s in 0..n - 1 {
            let send_chunk = (me + n - s) % n;
            let recv_chunk = (me + n - s - 1) % n;
            let (lo, hi) = bounds[send_chunk];
            let payload: Vec<u8> = data[lo..hi].iter().flat_map(|f| f.to_le_bytes()).collect();
            let sreq = self.isend(comm, right, tag + s as i32, &payload);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag + s as i32));
            let got = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            let (rlo, rhi) = bounds[recv_chunk];
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                if rlo + i < rhi {
                    data[rlo + i] += f32::from_le_bytes(chunk.try_into().unwrap());
                }
            }
        }
        // Phase 2: allgather the reduced chunks.
        let tag2 = tag + n as i32;
        for s in 0..n - 1 {
            let send_chunk = (me + 1 + n - s) % n;
            let recv_chunk = (me + n - s) % n;
            let (lo, hi) = bounds[send_chunk];
            let payload: Vec<u8> = data[lo..hi].iter().flat_map(|f| f.to_le_bytes()).collect();
            let sreq = self.isend(comm, right, tag2 + s as i32, &payload);
            let rreq = self.irecv(comm, Src::Rank(left), Tag::Value(tag2 + s as i32));
            let got = self.wait(rreq).expect("ring recv");
            self.wait(sreq);
            let (rlo, rhi) = bounds[recv_chunk];
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                if rlo + i < rhi {
                    data[rlo + i] = f32::from_le_bytes(chunk.try_into().unwrap());
                }
            }
        }
    }

    /// Allreduce a single f64 (sum) — convenience for scalar metrics.
    /// Routed through the segmented ring (one 8-byte element): 2(n-1)
    /// tiny messages, instead of the n² bytes the old allgather-everything
    /// implementation moved.
    pub fn allreduce_scalar(&self, comm: &Comm, x: f64) -> f64 {
        let mut bytes = x.to_le_bytes().to_vec();
        self.allreduce_ring_segmented(comm, &mut bytes, 8, &|acc, inc| {
            let v = f64::from_le_bytes((&acc[..]).try_into().unwrap())
                + f64::from_le_bytes(inc.try_into().unwrap());
            acc.copy_from_slice(&v.to_le_bytes());
        });
        f64::from_le_bytes(bytes.as_slice().try_into().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::part_bounds;

    #[test]
    fn part_bounds_cover_exactly_and_agree() {
        for len in [0usize, 1, 7, 100, 1007] {
            for parts in [1usize, 2, 3, 8, 64] {
                let mut covered = 0;
                for i in 0..parts {
                    let (lo, hi) = part_bounds(len, parts, i);
                    assert!(lo <= hi && hi <= len);
                    assert_eq!(lo, covered, "pieces must tile contiguously");
                    covered = hi;
                }
                assert_eq!(covered, len, "pieces must cover the whole range");
            }
        }
    }
}
