//! Communicators.

use std::sync::Arc;

use super::policy::CommPolicy;

/// What a communicator's rank space denotes.
#[derive(Clone, Debug)]
pub enum CommKind {
    /// Ranks are processes (MPI_COMM_WORLD and its duplicates).
    Procs,
    /// Subgroup communicator (`comm_split_with_info`): rank `r` is process
    /// `procs[r]` — the members of one split color, ordered by key.
    Group { procs: Arc<Vec<usize>> },
    /// User-visible endpoints communicator: `per_proc` endpoint ranks per
    /// process; endpoint `e` of a process maps to local VCI `vcis[e]`
    /// (symmetric across processes). Rank r = proc * per_proc + e.
    Endpoints { per_proc: usize, vcis: Arc<Vec<usize>> },
}

/// A communicator handle (plain value: cheap to clone, like an MPI handle).
#[derive(Clone, Debug)]
pub struct Comm {
    /// Globally agreed id (0 = MPI_COMM_WORLD); also the matching key.
    pub id: u64,
    /// VCI index this communicator funnels through (paper §4.2). For
    /// endpoints communicators this is unused — each endpoint has its own.
    pub vci: usize,
    pub size: usize,
    /// Calling process's rank (process id for `Procs` communicators).
    pub rank: usize,
    pub kind: CommKind,
    /// Per-communicator policy (striping mode, match shards, wildcard
    /// linger, doorbell participation, wildcard assertions), resolved from
    /// info keys at creation — see [`crate::mpi::policy`]. Every member of
    /// the communicator derives the identical policy (wire contract).
    pub policy: Arc<CommPolicy>,
}

impl Comm {
    /// Number of endpoint ranks per process (1 for process communicators).
    pub fn ranks_per_proc(&self) -> usize {
        match &self.kind {
            CommKind::Procs | CommKind::Group { .. } => 1,
            CommKind::Endpoints { per_proc, .. } => *per_proc,
        }
    }

    pub fn is_endpoints(&self) -> bool {
        matches!(self.kind, CommKind::Endpoints { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_rank_math() {
        let c = Comm {
            id: 5,
            vci: 0,
            size: 8,
            rank: 2,
            kind: CommKind::Endpoints { per_proc: 4, vcis: Arc::new(vec![1, 2, 3, 4]) },
            policy: Arc::new(CommPolicy::default()),
        };
        assert_eq!(c.ranks_per_proc(), 4);
        assert!(c.is_endpoints());
    }

    #[test]
    fn group_comms_have_one_rank_per_proc() {
        let c = Comm {
            id: 9,
            vci: 1,
            size: 2,
            rank: 0,
            kind: CommKind::Group { procs: Arc::new(vec![0, 2]) },
            policy: Arc::new(CommPolicy::default()),
        };
        assert_eq!(c.ranks_per_proc(), 1);
        assert!(!c.is_endpoints());
    }
}
