//! The progress engine: per-VCI, global, and hybrid progress (paper §4.3),
//! plus the message handlers that implement the wire protocols.
//!
//! Correctness subtlety reproduced from the paper (Fig. 9): progressing
//! *only* the VCI of the current request can deadlock programs that are
//! valid MPI — completion of an operation on one VCI may depend on software
//! progress of another. The hybrid model runs one **global** round (all
//! VCIs) after `global_progress_interval` unsuccessful per-VCI rounds.

use std::sync::atomic::Ordering;

use crate::fabric::{P2pProtocol, Payload, WireMsg};
use crate::platform::padvance;

use super::instrument::{count_lock, LockClass};
use super::matching::{Arrival, SenderInfo, UnexpectedMsg};
use super::proc::MpiProc;
use super::vci::VciState;

impl MpiProc {
    /// One progress-engine iteration on behalf of a request mapped to
    /// `vci_idx`. Applies the configured progress model. Called from wait
    /// loops; also usable directly for "manual" progress.
    pub fn progress_for_request(&self, vci_idx: usize) {
        let _cs = self.enter_cs();
        if self.cfg.per_vci_progress {
            let vci = self.vcis().get(vci_idx);
            let fails = vci.progress_failures.load(Ordering::Relaxed);
            let interval = self.cfg.global_progress_interval;
            if interval > 0 && fails as u32 >= interval {
                vci.progress_failures.store(0, Ordering::Relaxed);
                self.progress_global_round();
            } else {
                let did = self.progress_vci(vci_idx);
                if did {
                    vci.progress_failures.store(0, Ordering::Relaxed);
                } else {
                    vci.progress_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        } else {
            // Original-MPICH style: every progress call polls everything.
            self.progress_global_round();
        }
        self.check_hooks();
        drop(_cs);
        self.relax();
    }

    /// Poll one VCI's hardware context and handle at most one message.
    /// Returns true if a message was processed.
    pub fn progress_vci(&self, vci_idx: usize) -> bool {
        let vci = self.vcis().get(vci_idx).clone();
        let guard = self.guard();
        vci.with_state(guard, |st| {
            let ctx = self.fabric.context(self.rank(), vci.ctx_index);
            match ctx.poll(&self.costs) {
                Some(msg) => {
                    self.handle_msg(st, vci.ctx_index, msg);
                    true
                }
                None => false,
            }
        })
    }

    /// One global round: poll every open VCI (locking each in FG mode —
    /// the contention cost the paper attributes to shared progress).
    pub fn progress_global_round(&self) {
        for i in 0..self.vcis().len() {
            self.progress_vci(i);
        }
    }

    /// Check the two MPICH-style progress hooks (paper §4.1: "one
    /// iteration of the progress engine takes three locks": the portal
    /// poll plus these two). The activeness check itself is a cheap atomic
    /// load; each hook's own lock is taken only when the hook is *active*
    /// (a registered nonblocking-collective schedule) — otherwise every
    /// thread's progress loop would serialize on two process-wide locks.
    pub(super) fn check_hooks(&self) {
        use super::vci::Guard;
        for hook in &self.hooks {
            padvance(self.backend, self.costs.progress_hook_check);
            if hook.active.load(Ordering::Relaxed) && self.guard() == Guard::VciLock {
                count_lock(LockClass::Hook);
                let _g = hook.lock.lock();
                // (No hook workloads are registered in this reproduction;
                // the lock models the cost structure for Table 1.)
            }
        }
    }

    /// Dispatch one arrived message. Runs with the VCI state held.
    pub(super) fn handle_msg(&self, st: &mut VciState, my_ctx_index: usize, msg: WireMsg) {
        let sender = SenderInfo { src_proc: msg.src_proc, src_ctx: msg.src_ctx, send_handle: 0 };
        match msg.payload {
            Payload::TwoSided { comm_id, src_rank, tag, seq, protocol, needs_ack, data, .. } => {
                match protocol {
                    P2pProtocol::Eager { send_handle } => {
                        padvance(self.backend, self.costs.match_cost);
                        let um = UnexpectedMsg {
                            comm_id,
                            src_rank,
                            tag,
                            seq,
                            sender: SenderInfo { send_handle, ..sender },
                            arrival: Arrival::Eager { data, needs_ack },
                        };
                        if let Some((p, um)) = st.matching.on_arrival(um) {
                            self.consume_matched(st, my_ctx_index, p.req, um);
                        }
                    }
                    P2pProtocol::Rts { send_handle } => {
                        padvance(self.backend, self.costs.match_cost);
                        let um = UnexpectedMsg {
                            comm_id,
                            src_rank,
                            tag,
                            seq,
                            sender: SenderInfo { send_handle, ..sender },
                            arrival: Arrival::Rts,
                        };
                        if let Some((p, um)) = st.matching.on_arrival(um) {
                            self.consume_matched(st, my_ctx_index, p.req, um);
                        }
                    }
                    P2pProtocol::Cts { send_handle, recv_handle } => {
                        // We are the sender: ship the parked payload.
                        let ps = st
                            .pending_sends
                            .remove(&send_handle)
                            .expect("CTS for unknown rendezvous send");
                        padvance(self.backend, self.costs.completion_process);
                        self.reply(my_ctx_index, &sender, Payload::TwoSided {
                            comm_id: ps.comm_id,
                            src_rank: 0,
                            dst_rank: ps.dst_rank,
                            tag: ps.tag,
                            seq: 0,
                            protocol: P2pProtocol::Data { recv_handle },
                            needs_ack: false,
                            data: ps.data,
                        });
                        // Sender-side completion once the DMA drains.
                        let done = crate::platform::pnow(self.backend);
                        self.slab.slot(ps.req).complete_at.store(done, Ordering::Release);
                    }
                    P2pProtocol::Data { recv_handle } => {
                        let id = recv_handle as super::request::ReqId;
                        padvance(
                            self.backend,
                            self.costs.memcpy_cost(data.len()) + self.costs.completion_process,
                        );
                        *self.slab.slot(id).data.lock().unwrap_or_else(|e| e.into_inner()) =
                            Some(data);
                        self.slab.slot(id).completed.store(1, self.charged_atomics());
                    }
                }
            }
            Payload::SendAck { send_handle } => {
                let id = send_handle as super::request::ReqId;
                padvance(self.backend, self.costs.completion_process);
                self.slab.slot(id).completed.store(1, self.charged_atomics());
            }
            // ---- software-emulated RMA (target side) ----
            Payload::RmaPut { win, offset, data, flush_handle } => {
                padvance(
                    self.backend,
                    self.costs.rma_am_handle + self.costs.memcpy_cost(data.len()),
                );
                let mem = self.fabric.window(self.rank(), win);
                mem.write(offset, &data);
                self.reply(my_ctx_index, &sender, Payload::RmaAck { flush_handle });
            }
            Payload::RmaGetReq { win, offset, len, get_handle } => {
                padvance(self.backend, self.costs.rma_am_handle + self.costs.memcpy_cost(len));
                let mem = self.fabric.window(self.rank(), win);
                let data = mem.read(offset, len);
                self.reply(my_ctx_index, &sender, Payload::RmaGetReply { get_handle, data });
            }
            Payload::RmaGetReply { get_handle, data } => {
                padvance(self.backend, self.costs.completion_process);
                st.get_done.insert(get_handle, data);
            }
            Payload::RmaAcc { win, offset, data, op, flush_handle } => {
                padvance(
                    self.backend,
                    self.costs.rma_am_handle + 2 * self.costs.memcpy_cost(data.len()),
                );
                let mem = self.fabric.window(self.rank(), win);
                super::rma::apply_accumulate(&mem, offset, &data, op);
                self.reply(my_ctx_index, &sender, Payload::RmaAck { flush_handle });
            }
            Payload::RmaFetchOp { win, offset, operand, op, fetch_handle } => {
                padvance(self.backend, self.costs.rma_am_handle);
                let mem = self.fabric.window(self.rank(), win);
                let prev = super::rma::apply_fetch_op(&mem, offset, &operand, op);
                self.reply(my_ctx_index, &sender, Payload::RmaFetchOpReply {
                    fetch_handle,
                    data: prev,
                });
            }
            Payload::RmaFetchOpReply { fetch_handle, data } => {
                padvance(self.backend, self.costs.completion_process);
                st.fetch_done.insert(fetch_handle, data);
            }
            Payload::RmaAck { flush_handle } => {
                padvance(self.backend, self.costs.completion_process);
                st.acked.insert(flush_handle);
            }
        }
    }

    /// Service-thread entry: drain every context this process owns once.
    /// Used by the OPA personality's low-frequency PSM2-style progress
    /// thread; runs the global round irrespective of the progress model.
    pub fn service_progress_round(&self) {
        if !self.initialized.load(Ordering::Acquire) {
            return;
        }
        let _cs = self.enter_cs();
        self.progress_global_round();
    }
}
