//! The progress engine: per-VCI, global, and hybrid progress (paper §4.3),
//! plus the message handlers that implement the wire protocols.
//!
//! Correctness subtlety reproduced from the paper (Fig. 9): progressing
//! *only* the VCI of the current request can deadlock programs that are
//! valid MPI — completion of an operation on one VCI may depend on software
//! progress of another. The hybrid model runs one **global** round (all
//! VCIs) after `global_progress_interval` unsuccessful per-VCI rounds.
//!
//! # Striping
//!
//! With per-message VCI striping (a per-communicator policy — see
//! `mpi::policy`), a striped communicator's arrivals land on every stripe
//! lane, so progress on behalf of its requests rotates over the pool
//! instead of pinning to the request's VCI (see
//! `MpiProc::stripe_poll_target`; the routing is recorded in the request
//! slot at initiation, so an ordered communicator's waiter in the same
//! process still polls only its own VCI). A polled striped envelope is
//! matched **on the VCI that polled it**: the handler takes only the lock
//! of the per-communicator matching shard that owns the `(comm, src)`
//! stream (see `mpi::shard`), so stripe VCIs contribute both rx
//! parallelism and matching parallelism — no batch re-route to a home
//! engine, and no per-sweep buffer to allocate. With the policy's
//! `rx_doorbell` the sweep skips entirely (one bitmask load) when no VCI
//! has anything queued, instead of paying an empty CQ read per VCI at
//! high pool sizes — and the sweep covers only lanes serving striped
//! comms: lanes pinned by ordered/endpoints communicators are skipped,
//! with the paranoid global round as the starvation backstop.
//!
//! Striped RMA (per-window policy, `mpi::rma`) rides the same machinery:
//! a striped put/accumulate arrives marked with its origin stripe lane,
//! the target answers `RmaAckCount` toward that lane's context, and the
//! origin's handler bumps the polled VCI's per-(window, target) ack
//! counter — `win_flush` sweeps the stripe lanes (doorbell-gated per the
//! window policy) until every recorded lane reaches its watermark.
//! Striped gets complete the same way: the `RmaGetReply` echoes the
//! issuing lane, parks the data under the get handle, and bumps the same
//! per-lane counter.
//!
//! Passive-target lock epochs (`mpi::rma`) add three handler arms on the
//! same dispatch: `RmaLockReq` admits the origin into the per-window FIFO
//! lock table (granting immediately or queueing behind an exclusive
//! holder), `RmaUnlock` releases it and drains the grantable FIFO prefix
//! to the waiting origins, and `RmaLockGrant` marks the origin's pending
//! handle granted so its `win_lock` spin can return. All three run under
//! the short `HostWinLocks` leaf lock; the reply injection happens after
//! it drops.
//!
//! Collective segments (see `mpi::collectives`) use explicit lanes
//! chosen symmetrically from the envelope (dedicated or hashed per
//! segment): their requests are NOT striped-flagged, so a collective
//! waiter polls exactly the lane its segment lives on, with the hybrid
//! global round as the cross-lane backstop.
//!
//! # Robustness
//!
//! No `expect`/`unwrap` panic is reachable from wire-message handling:
//! stale or duplicate control messages (a CTS for an unknown rendezvous
//! send, a replayed DATA/ack handle, an unregistered RMA window) are
//! dropped with a counted diagnostic (`MpiProc::stale_ctrl_drop_count`,
//! also surfaced process-wide via `mpi::instrument::proc_counters`).

use std::sync::atomic::Ordering;

use crate::fabric::{P2pProtocol, Payload, WireMsg};
use crate::platform::padvance;

use super::instrument::{self, LockClass};
use super::matching::{Arrival, SenderInfo, Src, UnexpectedMsg};
use super::proc::MpiProc;
use super::vci::VciState;

/// Overflow-safe `[offset, offset + len)` vs window-size check for spans
/// that arrive off the wire (a forged `offset` near `usize::MAX` must be
/// rejected, not wrap or panic).
fn span_out_of_bounds(offset: usize, len: usize, size: usize) -> bool {
    match offset.checked_add(len) {
        Some(end) => end > size,
        None => true,
    }
}

impl MpiProc {
    /// One progress-engine iteration on behalf of a request mapped to
    /// `vci_idx`, using the **process-default** policy's progress routing
    /// (striped sweep / doorbell per the default `CommPolicy`). Used for
    /// "manual" progress and by paths without a per-request policy record
    /// (RMA flushes); p2p waits use [`MpiProc::progress_with`] with the
    /// request's own flags.
    pub fn progress_for_request(&self, vci_idx: usize) {
        let striped = self.default_policy.striped();
        let doorbell = striped && self.default_policy.rx_doorbell;
        self.progress_with(vci_idx, striped, doorbell);
    }

    /// One progress-engine iteration with explicit routing: `striped`
    /// sweeps the stripe lanes instead of pinning to `vci_idx`;
    /// `doorbell` gates the sweep on the pool's rx-nonempty bitmask.
    pub(super) fn progress_with(&self, vci_idx: usize, striped: bool, doorbell: bool) {
        let _cs = self.enter_cs();
        if self.chaos {
            // Reliability-layer retransmit sweep: sim-time timeouts
            // re-inject this process's unacked frames (exponential
            // backoff, re-rolled fault decisions). Compiled to one bool
            // load when no fault plan is installed.
            self.fabric.drive_retransmits();
        }
        match self.stripe_poll_target(vci_idx, striped, doorbell) {
            None => {
                // Doorbell-gated skip: no VCI has anything queued, so the
                // whole sweep collapses to one bitmask read. A paranoid
                // global round still runs after `global_progress_interval`
                // consecutive skips, mirroring the hybrid-progress
                // fallback (a lost doorbell must degrade, not deadlock).
                padvance(self.backend, self.costs.doorbell_check);
                self.doorbell_skips.fetch_add(1, Ordering::Relaxed);
                instrument::record_doorbell_skip();
                let streak = self.skip_streak.fetch_add(1, Ordering::Relaxed) + 1;
                let interval = self.cfg.global_progress_interval;
                if interval > 0 && streak as u32 >= interval {
                    self.skip_streak.store(0, Ordering::Relaxed);
                    self.progress_global_round();
                }
            }
            Some(poll_idx) => {
                self.skip_streak.store(0, Ordering::Relaxed);
                if self.cfg.per_vci_progress {
                    let vci = self.vcis().get(poll_idx);
                    let fails = vci.progress_failures.load(Ordering::Relaxed);
                    let interval = self.cfg.global_progress_interval;
                    if interval > 0 && fails as u32 >= interval {
                        vci.progress_failures.store(0, Ordering::Relaxed);
                        self.progress_global_round();
                    } else {
                        let did = self.progress_vci(poll_idx);
                        if did {
                            vci.progress_failures.store(0, Ordering::Relaxed);
                        } else {
                            vci.progress_failures.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                } else {
                    // Original-MPICH style: every progress call polls
                    // everything.
                    self.progress_global_round();
                }
            }
        }
        self.check_hooks();
        drop(_cs);
        self.relax();
    }

    /// Poll one VCI's hardware context and handle at most one message.
    /// Returns true if a message was processed. Every message — striped or
    /// not — is handled under the polled VCI's state: striped envelopes
    /// additionally take their matching shard's lock (a leaf lock), so no
    /// second VCI lock and no re-route buffer are ever needed.
    pub fn progress_vci(&self, vci_idx: usize) -> bool {
        // Lane failover: a request pinned to a failed lane makes progress
        // on its survivor (this is the chokepoint every wait loop funnels
        // through), and a freshly killed context is detected here — the
        // poll that would have found its rx queue dead instead quarantines
        // the lane and migrates its state.
        let mut vci_idx = self.vcis().resolve(vci_idx);
        if self.chaos
            && self.lane_failover
            && self.fabric.ctx_killed(self.vcis().get(vci_idx).ctx_index)
        {
            self.failover_vci(vci_idx);
            vci_idx = self.vcis().resolve(vci_idx);
        }
        let vci = self.vcis().get(vci_idx).clone();
        let guard = self.guard();
        vci.with_state(guard, |st| {
            match self.fabric.poll_ctx(vci.ctx_index) {
                None => {
                    self.empty_polls.fetch_add(1, Ordering::Relaxed);
                    instrument::record_empty_poll();
                    false
                }
                Some(msg) => {
                    self.handle_msg(st, vci.ctx_index, msg);
                    true
                }
            }
        })
    }

    /// Poll one stream-owned VCI's context lock-free — the single-writer
    /// twin of [`MpiProc::progress_vci`], entered only by the lane's
    /// owning thread (any other caller trips the SimSan owner check in
    /// `with_state_stream`). Same poll, same dispatch, zero lock
    /// acquisitions: this is where the streamed arm's wait loop spins.
    pub(super) fn progress_stream(&self, vci_idx: usize) -> bool {
        let vci = self.vcis().get(vci_idx).clone();
        if self.chaos && self.fabric.ctx_killed(vci.ctx_index) {
            // The deterministic rebind trap: a stream pins its lane 1:1,
            // so transparent failover would break the single-writer
            // contract — tell the owner instead of silently stalling.
            panic!(
                "stream-owned VCI lane {vci_idx} (ctx {}) hard-failed at t={}ns: a serial \
                 execution stream pins its lane 1:1, so it cannot fail over transparently — \
                 rebind (stream_unbind + stream_bind on a surviving lane) to recover",
                vci.ctx_index,
                crate::platform::pnow(self.backend),
            );
        }
        vci.with_state_stream(|st| {
            match self.fabric.poll_ctx(vci.ctx_index) {
                None => {
                    self.empty_polls.fetch_add(1, Ordering::Relaxed);
                    instrument::record_empty_poll();
                    false
                }
                Some(msg) => {
                    self.handle_msg(st, vci.ctx_index, msg);
                    true
                }
            }
        })
    }

    /// One global round: poll every open VCI (locking each in FG mode —
    /// the contention cost the paper attributes to shared progress).
    /// Stream-owned lanes are exempt from the sweep: a single-writer VCI
    /// is polled only by its owner (lock-free when the round runs on the
    /// owning thread, skipped everywhere else — the owner's own wait loop
    /// and the eventual unbind keep it live).
    pub fn progress_global_round(&self) {
        let me = super::proc::thread_token();
        for i in 0..self.vcis().len() {
            let v = self.vcis().get(i);
            if v.is_stream_owned() {
                if v.stream_owned_by(me) {
                    self.progress_stream(i);
                }
                continue;
            }
            self.progress_vci(i);
        }
    }

    /// Check the two MPICH-style progress hooks (paper §4.1: "one
    /// iteration of the progress engine takes three locks": the portal
    /// poll plus these two). The activeness check itself is a cheap atomic
    /// load; each hook's own lock is taken only when the hook is *active*
    /// (a registered nonblocking-collective schedule) — otherwise every
    /// thread's progress loop would serialize on two process-wide locks.
    pub(super) fn check_hooks(&self) {
        use super::vci::Guard;
        for (i, hook) in self.hooks.iter().enumerate() {
            padvance(self.backend, self.costs.progress_hook_check);
            if hook.active.load(Ordering::Relaxed) && self.guard() == Guard::VciLock {
                let _g = hook.lock.lock_class(LockClass::Hook);
                // Hook 0 carries the nonblocking-collective schedules
                // (`mpi::coll_nb`): any thread's progress call advances
                // every outstanding schedule — consuming completed
                // segment receives, reducing, and issuing the next
                // pipeline step — so a collective keeps moving while the
                // initiator computes. Hook 1 has no workload; its lock
                // models the second MPICH hook's cost for Table 1.
                // Ordering is legal: Hook (20) < CollSched (25) < Vci
                // (30), and schedule advancement never re-enters
                // progress.
                if i == 0 {
                    self.advance_registered_colls();
                }
            }
        }
    }

    /// Record one dropped stale/duplicate/malformed wire message.
    fn drop_stale(&self) {
        self.stale_ctrl_drops.fetch_add(1, Ordering::Relaxed);
        instrument::record_stale_ctrl_drop();
        padvance(self.backend, self.costs.completion_process);
    }

    /// A striped envelope arrived on whichever VCI polled it: admit it
    /// through the owning matching shard (reorder stage + match) and
    /// consume whatever matched. The shard lock is a leaf: it is released
    /// before consumption, and the epoch state machine is ticked after —
    /// matched pairs are already bound, so consumption order across
    /// requests is not MPI-visible.
    fn sharded_arrival(&self, st: &mut VciState, my_ctx_index: usize, um: UnexpectedMsg) {
        let mut um = um;
        let (cm, matched) = loop {
            let cm = self.cached_comm_match(st, um.comm_id);
            match cm.striped_arrival(um) {
                Ok(matched) => break (cm, matched),
                Err(back) => {
                    // The engine was retired by a policy adoption
                    // mid-flight: the table was swapped to the successor
                    // before the drain, so refresh this VCI's stale
                    // handle and retry there.
                    st.match_cache.remove(&back.comm_id);
                    um = back;
                }
            }
        };
        let mut wildcards = 0u64;
        for (p, um) in matched {
            if p.src == Src::Any {
                wildcards += 1;
            }
            self.consume_matched(my_ctx_index, p.req, um);
        }
        cm.note_arrival(wildcards);
    }

    /// Dispatch one arrived message. Runs with the polled VCI's state
    /// held; striped two-sided envelopes additionally take their matching
    /// shard's (leaf) lock inside [`MpiProc::sharded_arrival`].
    pub(super) fn handle_msg(&self, st: &mut VciState, my_ctx_index: usize, msg: WireMsg) {
        let sender = SenderInfo { src_proc: msg.src_proc, src_ctx: msg.src_ctx, send_handle: 0 };
        match msg.payload {
            Payload::TwoSided {
                comm_id,
                src_rank,
                tag,
                seq,
                stripe_home,
                protocol,
                needs_ack,
                data,
                ..
            } => {
                match protocol {
                    P2pProtocol::Eager { send_handle } => {
                        padvance(self.backend, self.costs.match_cost);
                        let um = UnexpectedMsg {
                            comm_id,
                            src_rank,
                            tag,
                            seq,
                            sender: SenderInfo { send_handle, ..sender },
                            arrival: Arrival::Eager { data, needs_ack },
                        };
                        if stripe_home.is_some() {
                            self.sharded_arrival(st, my_ctx_index, um);
                        } else if let Some((p, um)) = st.matching.on_arrival(um) {
                            self.consume_matched(my_ctx_index, p.req, um);
                        }
                    }
                    P2pProtocol::Rts { send_handle } => {
                        padvance(self.backend, self.costs.match_cost);
                        let um = UnexpectedMsg {
                            comm_id,
                            src_rank,
                            tag,
                            seq,
                            sender: SenderInfo { send_handle, ..sender },
                            arrival: Arrival::Rts,
                        };
                        if stripe_home.is_some() {
                            self.sharded_arrival(st, my_ctx_index, um);
                        } else if let Some((p, um)) = st.matching.on_arrival(um) {
                            self.consume_matched(my_ctx_index, p.req, um);
                        }
                    }
                    P2pProtocol::Cts { send_handle, recv_handle } => {
                        // We are the sender: ship the parked payload. A
                        // duplicate or stale CTS (no pending rendezvous for
                        // the handle) is dropped with a counted diagnostic
                        // — never a process abort.
                        let Some(ps) = st.pending_sends.remove(&send_handle) else {
                            self.drop_stale();
                            return;
                        };
                        padvance(self.backend, self.costs.completion_process);
                        self.reply(my_ctx_index, &sender, Payload::TwoSided {
                            comm_id: ps.comm_id,
                            src_rank: 0,
                            dst_rank: ps.dst_rank,
                            tag: ps.tag,
                            seq: 0,
                            stripe_home: None,
                            protocol: P2pProtocol::Data { recv_handle },
                            needs_ack: false,
                            data: ps.data,
                        });
                        // Sender-side completion once the DMA drains.
                        let done = crate::platform::pnow(self.backend);
                        self.slab.slot(ps.req).complete_at.store(done, Ordering::Release);
                    }
                    P2pProtocol::Data { recv_handle } => {
                        let Some((_id, slot)) = self.slab.try_slot(recv_handle) else {
                            self.drop_stale();
                            return;
                        };
                        padvance(
                            self.backend,
                            self.costs.memcpy_cost(data.len()) + self.costs.completion_process,
                        );
                        *slot.data.lock(LockClass::HostSlotData) = Some(data);
                        slot.completed.store(1, self.charged_atomics());
                    }
                }
            }
            Payload::SendAck { send_handle } => {
                let Some((_, slot)) = self.slab.try_slot(send_handle) else {
                    self.drop_stale();
                    return;
                };
                padvance(self.backend, self.costs.completion_process);
                slot.completed.store(1, self.charged_atomics());
            }
            // ---- software-emulated RMA (target side) ----
            Payload::RmaPut { win, offset, data, flush_handle, lane } => {
                let Some(mem) = self.fabric.find_window(self.rank(), win) else {
                    self.drop_stale();
                    return;
                };
                if span_out_of_bounds(offset, data.len(), mem.len()) {
                    self.drop_stale();
                    return;
                }
                padvance(
                    self.backend,
                    self.costs.rma_am_handle + self.costs.memcpy_cost(data.len()),
                );
                mem.write(offset, &data);
                // Striped ops (lane marked) complete by counted ack on the
                // issuing lane; ordered ops keep the flush-handle ack.
                let ack = match lane {
                    Some(l) => Payload::RmaAckCount { win, lane: l },
                    None => Payload::RmaAck { flush_handle },
                };
                self.reply(my_ctx_index, &sender, ack);
            }
            Payload::RmaGetReq { win, offset, len, get_handle, lane } => {
                let Some(mem) = self.fabric.find_window(self.rank(), win) else {
                    self.drop_stale();
                    return;
                };
                if span_out_of_bounds(offset, len, mem.len()) {
                    self.drop_stale();
                    return;
                }
                padvance(self.backend, self.costs.rma_am_handle + self.costs.memcpy_cost(len));
                let data = mem.read(offset, len);
                self.reply(
                    my_ctx_index,
                    &sender,
                    Payload::RmaGetReply { win, get_handle, data, lane },
                );
            }
            Payload::RmaGetReply { win, get_handle, data, lane } => {
                padvance(self.backend, self.costs.completion_process);
                st.get_done.insert(get_handle, data);
                if lane.is_some() {
                    // Counted striped-get completion: the reply returned
                    // to the issuing lane's context (like RmaAckCount), so
                    // this VCI's per-(window, target) ack counter is the
                    // one `win_flush` is watching — one thread's gets fan
                    // out across lanes exactly like its puts.
                    *st.rma_acked.entry((win, sender.src_proc)).or_insert(0) += 1;
                }
            }
            Payload::RmaAcc { win, offset, data, op, flush_handle, lane } => {
                let Some(mem) = self.fabric.find_window(self.rank(), win) else {
                    self.drop_stale();
                    return;
                };
                let bad_len = span_out_of_bounds(offset, data.len(), mem.len())
                    || (op != crate::fabric::AccOp::Replace && data.len() % 8 != 0);
                if bad_len {
                    self.drop_stale();
                    return;
                }
                padvance(
                    self.backend,
                    self.costs.rma_am_handle + 2 * self.costs.memcpy_cost(data.len()),
                );
                super::rma::apply_accumulate(&mem, offset, &data, op);
                let ack = match lane {
                    Some(l) => Payload::RmaAckCount { win, lane: l },
                    None => Payload::RmaAck { flush_handle },
                };
                self.reply(my_ctx_index, &sender, ack);
            }
            Payload::RmaFetchOp { win, offset, operand, op, fetch_handle } => {
                let Some(mem) = self.fabric.find_window(self.rank(), win) else {
                    self.drop_stale();
                    return;
                };
                // Fetch-ops read a fixed 8-byte cell for Sum*, and exactly
                // the operand span for Replace — reject anything that
                // would index out of bounds in the apply step.
                let span = match op {
                    crate::fabric::AccOp::Replace => operand.len(),
                    _ => operand.len().max(8),
                };
                if operand.is_empty()
                    || span_out_of_bounds(offset, span, mem.len())
                    || (op != crate::fabric::AccOp::Replace && operand.len() < 8)
                {
                    self.drop_stale();
                    return;
                }
                padvance(self.backend, self.costs.rma_am_handle);
                let prev = super::rma::apply_fetch_op(&mem, offset, &operand, op);
                self.reply(my_ctx_index, &sender, Payload::RmaFetchOpReply {
                    fetch_handle,
                    data: prev,
                });
            }
            Payload::RmaFetchOpReply { fetch_handle, data } => {
                padvance(self.backend, self.costs.completion_process);
                st.fetch_done.insert(fetch_handle, data);
            }
            Payload::RmaAck { flush_handle } => {
                padvance(self.backend, self.costs.completion_process);
                st.acked.insert(flush_handle);
            }
            // ---- passive-target lock protocol (OPA software path) ----
            Payload::RmaLockReq { win, kind, handle } => {
                // We are the target: admit through this window's FIFO lock
                // table (see `mpi::rma::WinLockTable`). The table lock is a
                // leaf — grant decided inside, grant *message* sent after
                // the guard drops. A request for an unknown window is a
                // stale/rogue origin: drop counted, never grant.
                if self.fabric.find_window(self.rank(), win).is_none() {
                    self.drop_stale();
                    return;
                }
                padvance(self.backend, self.costs.rma_am_handle);
                let granted = {
                    let mut t = self.win_locks.lock(LockClass::HostWinLocks);
                    t.entry(win).or_default().admit(super::rma::QueuedLock {
                        kind,
                        src_proc: sender.src_proc,
                        src_ctx: sender.src_ctx,
                        handle,
                    })
                };
                if granted {
                    self.reply(my_ctx_index, &sender, Payload::RmaLockGrant { win, handle });
                }
            }
            Payload::RmaLockGrant { win: _, handle } => {
                // We are the origin: the grant lands in the issuing VCI's
                // wait set (`wait_grant` is spinning on it).
                padvance(self.backend, self.costs.completion_process);
                st.lock_granted.insert(handle);
            }
            Payload::RmaUnlock { win, kind, handle } => {
                // We are the target: release, ack the unlocker (via the
                // ordinary RmaAck path — the unlock handle behaves like a
                // flush handle), then grant the now-runnable FIFO prefix.
                if self.fabric.find_window(self.rank(), win).is_none() {
                    self.drop_stale();
                    return;
                }
                padvance(self.backend, self.costs.rma_am_handle);
                let grants = {
                    let mut t = self.win_locks.lock(LockClass::HostWinLocks);
                    t.entry(win).or_default().release(kind)
                };
                self.reply(my_ctx_index, &sender, Payload::RmaAck { flush_handle: handle });
                for q in grants {
                    let to =
                        SenderInfo { src_proc: q.src_proc, src_ctx: q.src_ctx, send_handle: 0 };
                    self.reply(my_ctx_index, &to, Payload::RmaLockGrant { win, handle: q.handle });
                }
            }
            Payload::RelAck { .. } => {
                // Reliability-layer cumulative acks are NIC-level traffic
                // consumed inside `ProcFabric::poll_ctx` and never
                // surfaced to the MPI dispatch; one arriving here means a
                // forged or misrouted frame (the fuzz suite injects
                // exactly these) — drop counted, like any stale control.
                self.drop_stale();
            }
            Payload::RmaAckCount { win, lane } => {
                // Counted striped-RMA completion: the ack returned to the
                // issuing stripe lane's context (the target replies toward
                // `src_ctx`), so this VCI's per-(window, target) counter is
                // the one `win_flush` is watching; `lane` rides along as
                // the wire-contract record of that routing. A straggler
                // for a freed window just bumps a counter nobody waits on
                // (purged again if the id is ever resurrected — win ids
                // are never recycled).
                debug_assert!(
                    (lane as usize) >= self.vcis().len()
                        || self
                            .vcis()
                            .get(self.vcis().resolve(lane as usize))
                            .ctx_index
                            == my_ctx_index,
                    "counted RMA ack landed off its issuing lane {lane}"
                );
                padvance(self.backend, self.costs.completion_process);
                *st.rma_acked.entry((win, sender.src_proc)).or_insert(0) += 1;
            }
        }
    }

    /// Service-thread entry: drain every context this process owns once.
    /// Used by the OPA personality's low-frequency PSM2-style progress
    /// thread; runs the global round irrespective of the progress model.
    pub fn service_progress_round(&self) {
        if !self.initialized.load(Ordering::Acquire) {
            return;
        }
        let _cs = self.enter_cs();
        if self.chaos {
            self.fabric.drive_retransmits();
        }
        self.progress_global_round();
    }
}
