//! Nonblocking collectives: `MPI_Iallreduce` / `MPI_Ibcast` as
//! request-shaped handles riding the segmented collective engine.
//!
//! The blocking segmented ring/binomial tree in `mpi::collectives`
//! already pre-posts every step's receives; the only thing its step loop
//! added was a thread parked in `wait`. This module factors that loop
//! into a resumable state machine — [`CollSched`] — so the schedule can
//! be driven incrementally by *any* thread's progress call, and the
//! issuing thread is free to compute while the collective is in flight.
//!
//! # State machine
//!
//! A [`CollSched`] holds the working buffer, the full receive schedule
//! (every phase/step/segment receive is posted at initiation — legal
//! because the internal tag space is distinct per (phase, step,
//! segment)), a cursor over it, and the outstanding child send requests.
//! Advancing the machine consumes completed receives **strictly in
//! schedule order** (so the reduction order — and therefore the floating
//! point result — is bit-identical to the blocking path), applies each
//! segment (reduce for the reduce-scatter phase, copy for the allgather
//! phase, append for bcast), and forwards the freshly updated segment
//! downstream exactly as the blocking loop did. Once the receive
//! schedule is exhausted the machine drains its sends, then parks the
//! result in the buffer.
//!
//! # Progress-hook contract
//!
//! Initiating a nonblocking collective registers its schedule in
//! `MpiProc::coll_scheds` and arms progress hook 0. Every
//! `progress_with` iteration ends in `check_hooks` (`mpi::progress`),
//! which — in FG mode, under the hook's own lock — snapshots the
//! registry and advances each schedule. That gives the MPICH-style
//! asynchronous-progress property: *any* thread waiting on *any*
//! request (a p2p storm, an RMA flush, another collective) drives every
//! outstanding collective forward. `coll_wait` additionally drives
//! progress itself (polling the lane of the head blocked child, per its
//! recorded striping flags), so completion never depends on other
//! threads existing. Under the Global critical section the hooks do not
//! run (`guard() != VciLock`) and the waiter alone drives the schedule —
//! same liveness, serialized like every other Global-CS path.
//!
//! Lock discipline (see `mpi::instrument`): the hook path nests
//! `Hook (20) → CollSched (25) → Vci (30)`, strictly ascending. The
//! advancement step itself takes **no** sim lock other than `CollSched`:
//! child sends are issued with the schedule lock *released* (the cursor
//! already moved, so a racing advancer cannot double-issue), and
//! completed children are retired after the lock is dropped. Child
//! completion is observed only via the lock-free `is_complete` — the
//! machine never calls `progress` while holding any lock, which is what
//! makes the hook re-entrancy-free.
//!
//! # Tag-space constraint
//!
//! The internal collective tag space admits ONE outstanding nonblocking
//! collective per communicator (tags are reused across invocations —
//! `mpi::collectives` module doc). Initiating a second one on the same
//! comm while the first is outstanding is erroneous and panics;
//! overlapping collectives (the trainer's gradient buckets, the
//! deadlock suite) use distinct communicators, which is also what gives
//! them independent lanes.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::platform::{pnow, PMutex};

use super::collectives::{allreduce_tag, bcast_tag, part_bounds};
use super::instrument::{self, LockClass};
use super::policy::MAX_COLL_SEGMENTS;
use super::proc::MpiProc;
use super::request::{Request, REQ_FLAG_DOORBELL, REQ_FLAG_STRIPED};
use super::Comm;

/// Reduction operator of a nonblocking allreduce (closures cannot ride a
/// handle that outlives the initiating call, so the op is data).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RedOp {
    /// Element-wise f32 sum (little-endian 4-byte elements).
    SumF32,
    /// Element-wise f64 sum (little-endian 8-byte elements).
    SumF64,
}

impl RedOp {
    pub(super) fn elem(self) -> usize {
        match self {
            RedOp::SumF32 => 4,
            RedOp::SumF64 => 8,
        }
    }

    /// `acc ⊕= inc`, element-aligned. Accumulation order is fixed by the
    /// schedule cursor, so results are bit-identical to the blocking ring.
    fn apply(self, acc: &mut [u8], inc: &[u8]) {
        match self {
            RedOp::SumF32 => {
                for (a, b) in acc.chunks_exact_mut(4).zip(inc.chunks_exact(4)) {
                    let v = f32::from_le_bytes((&a[..]).try_into().unwrap())
                        + f32::from_le_bytes(b.try_into().unwrap());
                    a.copy_from_slice(&v.to_le_bytes());
                }
            }
            RedOp::SumF64 => {
                for (a, b) in acc.chunks_exact_mut(8).zip(inc.chunks_exact(8)) {
                    let v = f64::from_le_bytes((&a[..]).try_into().unwrap())
                        + f64::from_le_bytes(b.try_into().unwrap());
                    a.copy_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
}

/// What consuming a received segment does to the working buffer.
#[derive(Clone, Copy)]
enum Combine {
    /// Reduce-scatter phase: `buf[lo..hi] ⊕= segment`.
    Reduce,
    /// Allgather phase: `buf[lo..hi] = segment`.
    Copy,
    /// Bcast: segments arrive in order and are appended (non-roots never
    /// know the payload length up front).
    Append,
}

/// Sends to issue the moment a segment is consumed (the pipelining step
/// of the blocking loop, made explicit).
#[derive(Clone)]
struct ForwardSpec {
    tag: i32,
    dsts: Vec<usize>,
}

/// One pre-posted segment receive plus its downstream forwarding.
struct SegRecv {
    req: Request,
    /// Byte bounds in the working buffer (unused for `Combine::Append`).
    lo: usize,
    hi: usize,
    forward: Option<ForwardSpec>,
}

/// One ring/tree step: its segment receives, consumed in order.
struct RecvStep {
    combine: Combine,
    segs: Vec<SegRecv>,
}

/// A send the advancer must issue once the schedule lock is released.
struct SendAction {
    dst: usize,
    tag: i32,
    data: Vec<u8>,
}

/// Outcome of one locked advancement pass.
enum Locked {
    /// The head child request is incomplete: progress its lane (routing
    /// flags read from the live slot, under the schedule lock).
    Blocked { vci: usize, striped: bool, doorbell: bool },
    /// Issue these sends (lock released), deposit the requests, re-enter.
    Issue(Vec<SendAction>),
    Done,
}

/// Outcome of a full advancement drive ([`MpiProc::coll_advance`]).
pub(super) enum CollStatus {
    Blocked { vci: usize, striped: bool, doorbell: bool },
    Done,
}

/// Mutable schedule state, serialized by the `CollSched` lock.
struct SchedState {
    buf: Vec<u8>,
    op: Option<RedOp>,
    steps: Vec<RecvStep>,
    cursor_step: usize,
    cursor_seg: usize,
    sends: Vec<Request>,
    /// `sends[..send_drained]` are retired.
    send_drained: usize,
    /// Completed children awaiting retirement — drained by the driver
    /// *after* the schedule lock is dropped (retirement takes VCI /
    /// Global locks the advancer must not nest under `CollSched`).
    to_free: Vec<Request>,
    done: bool,
    /// Virtual time the schedule reached `done` (clamps the overlap
    /// metric: compute after completion is not "hidden" communication).
    completed_at: u64,
}

/// A resumable nonblocking-collective schedule (see the module doc).
pub struct CollSched {
    pub(super) comm: Comm,
    issued_at: u64,
    registered: bool,
    state: PMutex<SchedState>,
}

/// The user-visible handle of a nonblocking collective. Complete it with
/// [`MpiProc::coll_wait`] (which yields the result buffer); poll it with
/// [`MpiProc::coll_test`].
pub struct CollReq {
    sched: Arc<CollSched>,
}

impl MpiProc {
    /// Per-chunk segment count: static `vcmpi_coll_segments`, or the
    /// topology-aware [`MpiProc::auto_coll_segments`] when the policy
    /// says `auto` — either way bounded by the chunk's element count.
    /// Pure function of shared inputs (policy, cost model, payload
    /// length): part of the wire contract like the tag layout.
    pub(super) fn coll_segs(&self, comm: &Comm, chunk_elems: usize, elem: usize) -> usize {
        let base = if comm.policy.coll_segments_auto {
            self.auto_coll_segments(chunk_elems * elem)
        } else {
            comm.policy.coll_segments.clamp(1, MAX_COLL_SEGMENTS)
        };
        base.min(chunk_elems.max(1))
    }

    /// MPI_Iallreduce over an element-aligned byte buffer: initiates the
    /// segmented ring (posting EVERY phase's receives and the first
    /// step's sends) and returns a handle the progress hooks advance.
    pub fn iallreduce(&self, comm: &Comm, data: &[u8], op: RedOp) -> CollReq {
        let elem = op.elem();
        assert_eq!(data.len() % elem, 0, "payload must be element-aligned");
        let buf = data.to_vec();
        let n = comm.size;
        if n <= 1 {
            return self.coll_trivial(comm, buf);
        }
        let me = comm.rank;
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let elems = buf.len() / elem;
        let chunk_segs = |c: usize| -> usize {
            let (clo, chi) = part_bounds(elems, n, c);
            self.coll_segs(comm, chi - clo, elem)
        };
        // Byte bounds of segment g of chunk c (identical on every rank).
        let seg_bounds = |c: usize, g: usize| -> (usize, usize) {
            let (clo, chi) = part_bounds(elems, n, c);
            let (slo, shi) = part_bounds(chi - clo, chunk_segs(c), g);
            ((clo + slo) * elem, (clo + shi) * elem)
        };
        // Full receive schedule, both phases pre-posted (tags are unique
        // per (phase, step, segment)). Phase 0 (reduce-scatter) step s
        // receives chunk (me-s-1); phase 1 (allgather) receives chunk
        // (me-s). A consumed segment forwards to the right neighbor as
        // the next step's send — the last reduce-scatter step's segments
        // (chunk me+1, fully reduced here) forward as allgather step 0,
        // which is exactly what the blocking loop sent there.
        let mut steps = Vec::with_capacity(2 * (n - 1));
        for phase in 0..2usize {
            for s in 0..n - 1 {
                let chunk =
                    if phase == 0 { (me + n - s - 1) % n } else { (me + n - s) % n };
                let combine = if phase == 0 { Combine::Reduce } else { Combine::Copy };
                let segs = (0..chunk_segs(chunk))
                    .map(|g| {
                        let (lo, hi) = seg_bounds(chunk, g);
                        let forward = if s + 1 < n - 1 {
                            Some(ForwardSpec {
                                tag: allreduce_tag(n, phase, s + 1, g),
                                dsts: vec![right],
                            })
                        } else if phase == 0 {
                            Some(ForwardSpec { tag: allreduce_tag(n, 1, 0, g), dsts: vec![right] })
                        } else {
                            None
                        };
                        SegRecv {
                            req: self.coll_irecv(comm, left, allreduce_tag(n, phase, s, g)),
                            lo,
                            hi,
                            forward,
                        }
                    })
                    .collect();
                steps.push(RecvStep { combine, segs });
            }
        }
        // Reduce-scatter step 0 sends my own chunk.
        let mut sends = Vec::with_capacity(chunk_segs(me));
        for g in 0..chunk_segs(me) {
            let (lo, hi) = seg_bounds(me, g);
            sends.push(self.coll_isend(comm, right, allreduce_tag(n, 0, 0, g), &buf[lo..hi]));
        }
        self.coll_activate(comm, SchedState {
            buf,
            op: Some(op),
            steps,
            cursor_step: 0,
            cursor_seg: 0,
            sends,
            send_drained: 0,
            to_free: Vec::new(),
            done: false,
            completed_at: 0,
        })
    }

    /// MPI_Iallreduce (sum) over an f32 buffer — the gradient-exchange
    /// entry point. Pair with [`MpiProc::coll_wait_f32`].
    pub fn iallreduce_f32(&self, comm: &Comm, data: &[f32]) -> CollReq {
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        self.iallreduce(comm, &bytes, RedOp::SumF32)
    }

    /// MPI_Ibcast (binomial tree, segment-pipelined) from `root`; only
    /// the root supplies `data`. `coll_wait` yields the full buffer on
    /// every rank. Non-roots size their receive posts from the policy's
    /// STATIC segment count — `vcmpi_coll_segments=auto` cannot apply
    /// here because they do not know the payload length (see
    /// `mpi::policy`).
    pub fn ibcast(&self, comm: &Comm, root: usize, data: Option<Vec<u8>>) -> CollReq {
        let n = comm.size;
        if n <= 1 {
            return self.coll_trivial(comm, data.expect("root must supply data"));
        }
        let me = (comm.rank + n - root) % n; // virtual rank with root at 0
        let segs = comm.policy.coll_segments.clamp(1, MAX_COLL_SEGMENTS);
        let max_j = if me == 0 { usize::BITS } else { me.trailing_zeros() };
        let mut children = Vec::new();
        for j in 0..max_j {
            let child_virt = me + (1usize << j);
            if child_virt >= n {
                break;
            }
            children.push((child_virt + root) % n); // actual rank
        }
        let st = if me == 0 {
            let buf = data.expect("root must supply data");
            let mut sends = Vec::with_capacity(children.len() * segs);
            for g in 0..segs {
                let (lo, hi) = part_bounds(buf.len(), segs, g);
                for &child in &children {
                    sends.push(self.coll_isend(comm, child, bcast_tag(g), &buf[lo..hi]));
                }
            }
            SchedState {
                buf,
                op: None,
                steps: Vec::new(),
                cursor_step: 0,
                cursor_seg: 0,
                sends,
                send_drained: 0,
                to_free: Vec::new(),
                done: false,
                completed_at: 0,
            }
        } else {
            let parent = ((me & (me - 1)) + root) % n;
            let forward_dsts = children;
            let segs = (0..segs)
                .map(|g| SegRecv {
                    req: self.coll_irecv(comm, parent, bcast_tag(g)),
                    lo: 0,
                    hi: 0,
                    forward: if forward_dsts.is_empty() {
                        None
                    } else {
                        Some(ForwardSpec { tag: bcast_tag(g), dsts: forward_dsts.clone() })
                    },
                })
                .collect();
            SchedState {
                buf: Vec::new(),
                op: None,
                steps: vec![RecvStep { combine: Combine::Append, segs }],
                cursor_step: 0,
                cursor_seg: 0,
                sends: Vec::new(),
                send_drained: 0,
                to_free: Vec::new(),
                done: false,
                completed_at: 0,
            }
        };
        self.coll_activate(comm, st)
    }

    /// Complete a nonblocking collective: drive its schedule (progressing
    /// the head blocked child's lane between passes) until done, retire
    /// it from the hook registry, and return the result buffer. Credits
    /// the issue-to-wait gap — clamped at the schedule's completion time
    /// — to the Table-1 `coll_overlap_ms` column: the compute this thread
    /// did while the collective was genuinely in flight.
    pub fn coll_wait(&self, req: CollReq) -> Vec<u8> {
        let sched = req.sched;
        let wait_entry = pnow(self.backend);
        let deadline = super::proc::SpinDeadline::new(self.backend);
        loop {
            match self.coll_advance(&sched) {
                CollStatus::Done => break,
                CollStatus::Blocked { vci, striped, doorbell } => {
                    deadline.check(|| {
                        format!(
                            "coll_wait (nonblocking collective on comm {}, blocked on \
                             lane {vci})",
                            sched.comm.id
                        )
                    });
                    self.progress_with(vci, striped, doorbell);
                }
            }
        }
        if sched.registered {
            self.coll_unregister(&sched);
        }
        let (buf, completed_at) = {
            let mut st = sched.state.lock_class(LockClass::CollSched);
            (std::mem::take(&mut st.buf), st.completed_at)
        };
        instrument::count_coll_overlap_ns(
            completed_at.min(wait_entry).saturating_sub(sched.issued_at),
        );
        buf
    }

    /// [`MpiProc::coll_wait`] into an f32 slice.
    pub fn coll_wait_f32(&self, req: CollReq, out: &mut [f32]) {
        let bytes = self.coll_wait(req);
        for (d, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes(c.try_into().unwrap());
        }
    }

    /// MPI_Test for a collective handle: one advancement drive, one
    /// progress pass if blocked, then a re-check. `true` means the
    /// schedule is complete — the handle must still be passed to
    /// [`MpiProc::coll_wait`] to fetch the result and retire it (which
    /// then returns without progressing, like `wait` on a completed
    /// request).
    pub fn coll_test(&self, req: &CollReq) -> bool {
        match self.coll_advance(&req.sched) {
            CollStatus::Done => true,
            CollStatus::Blocked { vci, striped, doorbell } => {
                self.progress_with(vci, striped, doorbell);
                matches!(self.coll_advance(&req.sched), CollStatus::Done)
            }
        }
    }

    /// Hook-0 workload (called from `check_hooks` under the Hook lock,
    /// FG mode only): snapshot the registry, then advance every
    /// outstanding schedule as far as its completed children allow. The
    /// host registry lock is dropped before any schedule lock is taken.
    pub(super) fn advance_registered_colls(&self) {
        let scheds: Vec<Arc<CollSched>> = {
            let t = self.coll_scheds.lock(LockClass::HostCollScheds);
            t.clone()
        };
        for sched in scheds {
            // Blocked is fine — the snapshot pass is opportunistic.
            let _ = self.coll_advance(&sched);
        }
    }

    /// Drive one schedule as far as it can go without progressing:
    /// consume completed receives in order (issuing the forwards with the
    /// schedule lock released), then drain sends. Retires completed
    /// children after every locked pass.
    pub(super) fn coll_advance(&self, sched: &Arc<CollSched>) -> CollStatus {
        loop {
            let outcome = {
                let mut st = sched.state.lock_class(LockClass::CollSched);
                self.advance_locked(&mut st)
            };
            match outcome {
                Locked::Issue(actions) => {
                    let reqs: Vec<Request> = actions
                        .into_iter()
                        .map(|a| self.coll_isend(&sched.comm, a.dst, a.tag, &a.data))
                        .collect();
                    let mut st = sched.state.lock_class(LockClass::CollSched);
                    st.sends.extend(reqs);
                }
                Locked::Blocked { vci, striped, doorbell } => {
                    self.coll_drain_free(sched);
                    return CollStatus::Blocked { vci, striped, doorbell };
                }
                Locked::Done => {
                    self.coll_drain_free(sched);
                    return CollStatus::Done;
                }
            }
        }
    }

    /// One pass under the schedule lock. Never blocks, never progresses,
    /// takes no sim lock below `CollSched` (slot data locks are host
    /// leaves): completion is observed via the lock-free `is_complete`.
    fn advance_locked(&self, st: &mut SchedState) -> Locked {
        loop {
            if st.cursor_step < st.steps.len() {
                let combine = st.steps[st.cursor_step].combine;
                let (req, lo, hi, forward) = {
                    let seg = &st.steps[st.cursor_step].segs[st.cursor_seg];
                    (seg.req, seg.lo, seg.hi, seg.forward.clone())
                };
                let Request::Real { id, vci } = req else {
                    unreachable!("collective segment receives are slab-backed")
                };
                if !self.is_complete(id) {
                    let flags = self.slab.slot(id).flags.load(Ordering::Relaxed);
                    return Locked::Blocked {
                        vci,
                        striped: flags & REQ_FLAG_STRIPED != 0,
                        doorbell: flags & REQ_FLAG_DOORBELL != 0,
                    };
                }
                let data = self
                    .slab
                    .slot(id)
                    .data
                    .lock(LockClass::HostSlotData)
                    .take()
                    .expect("collective segment payload");
                let (flo, fhi) = match combine {
                    Combine::Reduce => {
                        debug_assert_eq!(data.len(), hi - lo, "segment length mismatch");
                        st.op.expect("reduce op").apply(&mut st.buf[lo..hi], &data);
                        (lo, hi)
                    }
                    Combine::Copy => {
                        debug_assert_eq!(data.len(), hi - lo, "segment length mismatch");
                        st.buf[lo..hi].copy_from_slice(&data);
                        (lo, hi)
                    }
                    Combine::Append => {
                        let lo = st.buf.len();
                        st.buf.extend_from_slice(&data);
                        (lo, st.buf.len())
                    }
                };
                st.to_free.push(req);
                st.cursor_seg += 1;
                if st.cursor_seg == st.steps[st.cursor_step].segs.len() {
                    st.cursor_seg = 0;
                    st.cursor_step += 1;
                }
                if let Some(f) = forward {
                    let payload = st.buf[flo..fhi].to_vec();
                    let actions = f
                        .dsts
                        .iter()
                        .map(|&dst| SendAction { dst, tag: f.tag, data: payload.clone() })
                        .collect();
                    return Locked::Issue(actions);
                }
                continue;
            }
            while st.send_drained < st.sends.len() {
                let r = st.sends[st.send_drained];
                if let Request::Real { id, vci } = r {
                    if !self.is_complete(id) {
                        let flags = self.slab.slot(id).flags.load(Ordering::Relaxed);
                        return Locked::Blocked {
                            vci,
                            striped: flags & REQ_FLAG_STRIPED != 0,
                            doorbell: flags & REQ_FLAG_DOORBELL != 0,
                        };
                    }
                }
                st.to_free.push(r);
                st.send_drained += 1;
            }
            if !st.done {
                st.done = true;
                st.completed_at = pnow(self.backend);
            }
            return Locked::Done;
        }
    }

    /// Retire completed children parked by the advancer. Runs with the
    /// schedule lock released (a retire takes VCI — or, under the Global
    /// CS, the Global — lock, which must not nest under `CollSched`).
    /// Every parked request is complete, so `wait` returns without a
    /// single progress call.
    fn coll_drain_free(&self, sched: &Arc<CollSched>) {
        let to_free: Vec<Request> = {
            let mut st = sched.state.lock_class(LockClass::CollSched);
            std::mem::take(&mut st.to_free)
        };
        for r in to_free {
            self.wait(r);
        }
    }

    /// Build, register, and stamp a live schedule (children already
    /// posted/issued by the initiator — single-threaded until this
    /// registers it).
    fn coll_activate(&self, comm: &Comm, st: SchedState) -> CollReq {
        let sched = Arc::new(CollSched {
            comm: comm.clone(),
            issued_at: pnow(self.backend),
            registered: true,
            state: PMutex::new(self.backend, st),
        });
        self.coll_register(&sched);
        CollReq { sched }
    }

    /// A pre-completed schedule (single-member comm): never registered.
    fn coll_trivial(&self, comm: &Comm, buf: Vec<u8>) -> CollReq {
        let now = pnow(self.backend);
        let sched = Arc::new(CollSched {
            comm: comm.clone(),
            issued_at: now,
            registered: false,
            state: PMutex::new(self.backend, SchedState {
                buf,
                op: None,
                steps: Vec::new(),
                cursor_step: 0,
                cursor_seg: 0,
                sends: Vec::new(),
                send_drained: 0,
                to_free: Vec::new(),
                done: true,
                completed_at: now,
            }),
        });
        CollReq { sched }
    }

    /// Register a schedule and arm progress hook 0. One outstanding
    /// nonblocking collective per communicator (the tag-space contract —
    /// module doc); a second initiation on the same comm is erroneous.
    fn coll_register(&self, sched: &Arc<CollSched>) {
        let mut t = self.coll_scheds.lock(LockClass::HostCollScheds);
        assert!(
            !t.iter().any(|s| s.comm.id == sched.comm.id),
            "a nonblocking collective is already outstanding on comm {} — the internal \
             collective tag space admits one per communicator; overlap across distinct \
             comms instead (erroneous program)",
            sched.comm.id
        );
        t.push(sched.clone());
        self.hooks[0].active.store(true, Ordering::Release);
    }

    /// Remove a completed schedule; disarm hook 0 when the registry
    /// empties (so idle progress loops go back to one atomic load).
    fn coll_unregister(&self, sched: &Arc<CollSched>) {
        let mut t = self.coll_scheds.lock(LockClass::HostCollScheds);
        t.retain(|s| !Arc::ptr_eq(s, sched));
        if t.is_empty() {
            self.hooks[0].active.store(false, Ordering::Release);
        }
    }
}
