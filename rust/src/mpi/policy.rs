//! Per-communicator and per-window policy: the info-key-driven resolution
//! of the striping / sharding / wildcard knobs that used to be
//! process-global.
//!
//! The paper's position (§7) is that users should expose parallelism
//! through *existing* MPI mechanisms — communicators and per-object info
//! hints — and let the library map that parallelism onto VCIs. After the
//! striping and sharded-matching work, our knobs (`vci_striping`,
//! `match_shards`, `wildcard_epoch_linger`, `rx_doorbell`, the wildcard
//! assertions) lived on [`MpiConfig`], so one process could not host a
//! hot halo-exchange communicator *and* a latency-sensitive ordered
//! communicator with different policies. This module lifts them into a
//! per-communicator [`CommPolicy`], resolved at communicator creation
//! from MPI-4-style [`Info`] keys; the `MpiConfig` values are demoted to
//! process-wide **defaults** (the policy every communicator starts from,
//! including `MPI_COMM_WORLD`).
//!
//! # Info-key vocabulary
//!
//! | key                        | values            | effect |
//! |----------------------------|-------------------|--------|
//! | `vcmpi_striping`           | `off`\|`rr`\|`hash` | per-message VCI striping mode for this communicator |
//! | `vcmpi_match_shards`       | integer ≥ 1       | matching shards for striped traffic (rounded up to a power of two) |
//! | `vcmpi_wildcard_linger`    | integer ≥ 0       | wildcard-epoch hysteresis, in operations |
//! | `vcmpi_rx_doorbell`        | `true`\|`false`   | participate in doorbell-gated striped sweeps |
//! | `mpi_assert_no_any_source` | `true`\|`false`   | receives on this comm never use `MPI_ANY_SOURCE` |
//! | `mpi_assert_no_any_tag`    | `true`\|`false`   | receives on this comm never use `MPI_ANY_TAG` |
//! | `vcmpi_collectives`        | `inherit`\|`dedicated`\|`striped` | how this comm's collectives map onto the VCI pool (see [`CollectivesMode`]) |
//! | `vcmpi_coll_segments`      | integer ≥ 1 \| `auto` | segments per collective payload (pipelined; clamped to [`MAX_COLL_SEGMENTS`]). `auto` sizes topology-aware from the fabric cost model: per-chunk DMA time balanced against per-segment latency (see `MpiProc::auto_coll_segments`) |
//! | `vcmpi_stream`             | `local`           | serial execution stream (MPIX-Stream style): the first thread to touch the comm binds it to a dedicated single-writer VCI — no VCI lock, no shared request cache on that path. Mutually exclusive with striping; see the decision table below |
//!
//! # Stream vs striping: the policy decision table
//!
//! | traffic shape | policy |
//! |---------------|--------|
//! | many threads, one hot comm, bulk | `vcmpi_striping=rr`/`hash` (+ shards + doorbell) |
//! | one thread, one comm, latency/rate-critical | `vcmpi_stream=local` — single-writer lane, zero locks per op |
//! | one thread per comm, several comms | default ordered comms (pinned lanes), or a stream per comm |
//! | mixed / unknown | default ordered; measure before opting in |
//!
//! Windows resolve a [`WinPolicy`] from the same [`Info`] machinery at
//! `MpiProc::win_create_with_info` (MPI_Win_create's info argument):
//!
//! | key                     | values             | effect |
//! |-------------------------|--------------------|--------|
//! | `accumulate_ordering`   | `none` \| `rar,raw,war,waw` list | `none` relaxes accumulate program order (MPI-3.1 §11.7.2), enabling accumulate striping |
//! | `vcmpi_striping`        | `off`\|`rr`\|`hash`  | per-message VCI striping of this window's puts/accumulates |
//! | `vcmpi_rx_doorbell`     | `true`\|`false`    | flush sweeps are doorbell-gated for this window |
//! | `mpi_assert_no_locks`   | `true`\|`false`    | promises lock epochs need no mutual exclusion: the lock protocol is elided to a local no-op grant (see `mpi::rma`) |
//!
//! Unknown keys are ignored (MPI info semantics); a malformed value for a
//! known key panics — it is a programming error, like posting a wildcard
//! under an asserted hint.
//!
//! The consolidated reference — every key with its legal values, default,
//! and the bench lane that proves it — lives in `docs/ARCHITECTURE.md`
//! (§ "Info-key reference"), kept in sync with these tables by
//! `scripts/lint_doc_links.py` (it checks the `[[bench gate: …]]` names
//! against the bench sources).
//!
//! # Wire-contract symmetry
//!
//! Like `num_vcis` and the striping wire format, a communicator's policy
//! is part of the job-wide contract: every member must pass the same info
//! keys to the same creation call, so the policy is derived
//! deterministically from `(comm id, info)` and all members agree on
//! whether envelopes are striped and how streams shard. This is asserted
//! the same way `num_vcis` symmetry is — by construction plus a counted
//! diagnostic (`MpiProc::policy_mismatch_count`) when a striped envelope
//! arrives for a communicator whose registered policy says `off`.

use super::config::{MpiConfig, VciStriping};

/// Hard cap on `vcmpi_coll_segments`: the collective internal-tag space
/// reserves this many tags per (collective op, ring step), so the cap is
/// part of the wire contract (see `mpi::collectives` for the tag layout).
pub const MAX_COLL_SEGMENTS: usize = 64;

/// Default `vcmpi_coll_segments` when no info key overrides it: enough
/// pipeline depth to overlap injection, wire time, and target-side
/// handling for bulk payloads, while tiny payloads degenerate gracefully
/// (segment counts never exceed the element count — empty trailing
/// segments are elided by the collectives engine).
pub const DEFAULT_COLL_SEGMENTS: usize = 4;

/// How a communicator's collectives map onto the VCI pool
/// (`vcmpi_collectives`). Collective internal traffic never uses
/// wildcards, so its envelopes are always fully specified — that is what
/// makes the `Striped` spread legal without the §7 hint assertions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectivesMode {
    /// Collective segments ride the communicator's regular two-sided
    /// path: striped comms stripe them per message (seq reorder, shard
    /// engine), ordered comms funnel them through the home VCI.
    Inherit,
    /// Reserve (pin) one lane for this communicator's collective traffic:
    /// the lane is derived deterministically from the comm id (wire
    /// symmetry) and pinned out of the stripe-lane set, so a hot striped
    /// comm's p2p storm sharing the pool can never head-of-line-block
    /// this comm's allreduce. Released at `comm_free`.
    Dedicated,
    /// Spread collective segments over the pool by the pure
    /// (comm, sender rank, tag) envelope hash — per-segment tags fan one
    /// collective's segments across many lanes, matched per VCI with no
    /// reorder stage (the envelope selects the lane on both sides).
    Striped,
}

/// An MPI-4.0-style info object: an ordered list of `(key, value)`
/// string pairs. Later `set`s of the same key win.
#[derive(Clone, Debug, Default)]
pub struct Info {
    entries: Vec<(String, String)>,
}

impl Info {
    pub fn new() -> Self {
        Info { entries: Vec::new() }
    }

    /// MPI_Info_set.
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.entries.push((key.into(), value.into()));
    }

    /// Builder-style `set` for test/bench ergonomics.
    pub fn with(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.set(key, value);
        self
    }

    /// MPI_Info_get: the latest value set for `key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The per-communicator resolution of the striping/sharding knobs.
///
/// Built once at communicator creation ([`from_config`] for the process
/// defaults, then [`with_info`] per creation call) and carried by every
/// [`super::comm::Comm`] handle as an `Arc`; the process also keeps a
/// `comm id -> policy` table so the receive side (which only sees comm
/// ids on the wire) can build matching engines with the right shape.
///
/// [`from_config`]: CommPolicy::from_config
/// [`with_info`]: CommPolicy::with_info
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CommPolicy {
    /// Per-message VCI striping mode for this communicator's two-sided
    /// traffic (`vcmpi_striping`). `Off` pins the communicator to its
    /// assigned VCI — and *pins that VCI out of the stripe-lane set*, so
    /// striped communicators' bulk traffic never queues behind it.
    pub striping: VciStriping,
    /// Matching shards for striped traffic (`vcmpi_match_shards`,
    /// rounded up to a power of two by the engine; `1` = the single
    /// home-engine arm).
    pub match_shards: usize,
    /// Wildcard-epoch hysteresis in operations (`vcmpi_wildcard_linger`).
    pub wildcard_linger: u32,
    /// Does this communicator's striped traffic participate in
    /// doorbell-gated progress sweeps (`vcmpi_rx_doorbell`)?
    pub rx_doorbell: bool,
    /// `mpi_assert_no_any_source`: receives never use `MPI_ANY_SOURCE`,
    /// so (with `no_any_tag`) unstriped traffic may spread by envelope.
    pub no_any_source: bool,
    /// `mpi_assert_no_any_tag`: receives never use `MPI_ANY_TAG`.
    pub no_any_tag: bool,
    /// How this communicator's collectives map onto the VCI pool
    /// (`vcmpi_collectives`) — see [`CollectivesMode`].
    pub collectives: CollectivesMode,
    /// Segments per collective payload (`vcmpi_coll_segments`): allreduce
    /// splits each ring-step chunk — and bcast each tree hop — into this
    /// many independently tagged nonblocking transfers, pipelined as they
    /// complete. Clamped to `1..=`[`MAX_COLL_SEGMENTS`].
    pub coll_segments: usize,
    /// `vcmpi_coll_segments=auto`: derive the allreduce segment count from
    /// the fabric cost model (chunk DMA time vs per-segment wire+inject
    /// latency) instead of the static [`coll_segments`] value. Pure
    /// function of shared state (cost model + payload length), so all
    /// members derive the same count — wire-contract symmetric. Bcast
    /// cannot use it (non-roots don't know the payload length before the
    /// first segment arrives) and falls back to the static count.
    ///
    /// [`coll_segments`]: CommPolicy::coll_segments
    pub coll_segments_auto: bool,
    /// `vcmpi_stream=local`: this communicator is a *serial execution
    /// stream* (MPIX-Stream style). The first thread to drive it binds
    /// itself to the comm's VCI (`MpiProc::stream_bind`), which switches
    /// the lane into single-writer mode: ops on the bound thread skip the
    /// VCI lock and the shared request cache entirely. Implies ordered
    /// (non-striped) traffic; combining with `vcmpi_striping` other than
    /// `off` is erroneous.
    pub stream: bool,
}

impl Default for CommPolicy {
    fn default() -> Self {
        CommPolicy {
            striping: VciStriping::Off,
            match_shards: 1,
            wildcard_linger: 0,
            rx_doorbell: false,
            no_any_source: false,
            no_any_tag: false,
            collectives: CollectivesMode::Inherit,
            coll_segments: DEFAULT_COLL_SEGMENTS,
            coll_segments_auto: false,
            stream: false,
        }
    }
}

impl CommPolicy {
    /// The process-default policy: the demoted `MpiConfig` knobs. Every
    /// preset builds exactly its pre-policy behavior through this path.
    pub fn from_config(cfg: &MpiConfig) -> Self {
        CommPolicy {
            striping: cfg.vci_striping,
            match_shards: cfg.match_shards,
            wildcard_linger: cfg.wildcard_epoch_linger,
            rx_doorbell: cfg.rx_doorbell,
            no_any_source: cfg.hints.no_any_source,
            no_any_tag: cfg.hints.no_any_tag,
            // No process-wide knobs exist for the collectives mapping:
            // it is inherently per-communicator (info keys only).
            collectives: CollectivesMode::Inherit,
            coll_segments: DEFAULT_COLL_SEGMENTS,
            coll_segments_auto: false,
            // Streams are inherently per-communicator too: a process-wide
            // "every comm is a stream" default would be self-contradictory
            // (one thread can only own one lane at a time per comm).
            stream: false,
        }
    }

    /// Resolve a derived policy: this policy (the parent communicator's)
    /// overridden by `info`'s keys. An empty info inherits the parent
    /// policy unchanged — `comm_dup` is `comm_dup_with_info(.., &Info::new())`.
    pub fn with_info(&self, info: &Info) -> Self {
        let mut p = self.clone();
        if let Some(v) = info.get("vcmpi_striping") {
            p.striping = parse_striping(v);
        }
        if let Some(v) = info.get("vcmpi_match_shards") {
            p.match_shards = v
                .parse::<usize>()
                .unwrap_or_else(|_| {
                    panic!(
                        "info key vcmpi_match_shards: expected an integer, got {v:?} (erroneous program)"
                    )
                })
                .max(1);
        }
        if let Some(v) = info.get("vcmpi_wildcard_linger") {
            p.wildcard_linger = v.parse::<u32>().unwrap_or_else(|_| {
                panic!(
                    "info key vcmpi_wildcard_linger: expected an integer, got {v:?} (erroneous program)"
                )
            });
        }
        if let Some(v) = info.get("vcmpi_rx_doorbell") {
            p.rx_doorbell = parse_bool("vcmpi_rx_doorbell", v);
        }
        if let Some(v) = info.get("mpi_assert_no_any_source") {
            p.no_any_source = parse_bool("mpi_assert_no_any_source", v);
        }
        if let Some(v) = info.get("mpi_assert_no_any_tag") {
            p.no_any_tag = parse_bool("mpi_assert_no_any_tag", v);
        }
        if let Some(v) = info.get("vcmpi_collectives") {
            p.collectives = parse_collectives(v);
        }
        if let Some(v) = info.get("vcmpi_coll_segments") {
            if v == "auto" {
                p.coll_segments_auto = true;
            } else {
                p.coll_segments = v
                    .parse::<usize>()
                    .unwrap_or_else(|_| {
                        panic!(
                            "info key vcmpi_coll_segments: expected an integer or auto, got {v:?} (erroneous program)"
                        )
                    })
                    .clamp(1, MAX_COLL_SEGMENTS);
                p.coll_segments_auto = false;
            }
        }
        if let Some(v) = info.get("vcmpi_stream") {
            p.stream = match v {
                "local" => true,
                other => panic!(
                    "info key vcmpi_stream: expected local, got {other:?} (erroneous program)"
                ),
            };
        }
        if p.stream && p.striped() {
            panic!(
                "vcmpi_stream=local is mutually exclusive with vcmpi_striping={:?}: a stream is a \
                 single-writer ordered lane (erroneous program)",
                p.striping
            );
        }
        p
    }

    /// Does this policy stripe two-sided traffic across the pool?
    pub fn striped(&self) -> bool {
        self.striping != VciStriping::Off
    }

    /// Shard-index mask of this policy's matching engine: shard count
    /// rounded up to a power of two, minus one (mirrors `CommMatch`).
    pub fn shard_mask(&self) -> usize {
        self.match_shards.max(1).next_power_of_two() - 1
    }

    /// This policy with striping forced off (endpoints communicators:
    /// each endpoint IS a dedicated VCI, so striping would defeat them).
    pub fn ordered(&self) -> Self {
        CommPolicy { striping: VciStriping::Off, ..self.clone() }
    }
}

/// The per-window resolution of the RMA knobs: which completion/ordering
/// model a window's one-sided traffic uses.
///
/// Built at window creation (`MpiProc::win_create_with_info`) from the
/// process-default policy — the demoted `accumulate_ordering_none` hint on
/// [`MpiConfig`] — overridden by the creation call's [`Info`] keys, and
/// carried by every `Window` as an `Arc`. Like a communicator's policy it
/// is part of the wire contract: windows are created collectively and all
/// members must pass the same info keys (the striped-ack wire format
/// differs from the flush-handle format).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WinPolicy {
    /// `accumulate_ordering=none` (MPI-3.1 §11.7.2): accumulates from one
    /// origin need not apply in program order, so they may fan out across
    /// VCIs — thread-spread without striping, per-message with it.
    pub relaxed_accumulate: bool,
    /// Per-message VCI striping of this window's one-sided traffic
    /// (`vcmpi_striping`). `Off` funnels through the window's home VCI
    /// — and *pins that VCI out of the stripe-lane set*, like an ordered
    /// communicator. Puts stripe whenever this is on (MPI imposes no
    /// inter-put ordering); accumulates additionally require
    /// [`relaxed_accumulate`](WinPolicy::relaxed_accumulate).
    pub striping: VciStriping,
    /// Are this window's flush sweeps doorbell-gated (`vcmpi_rx_doorbell`)?
    pub rx_doorbell: bool,
    /// `mpi_assert_no_locks`: the program promises its lock epochs need
    /// no mutual exclusion, so `win_lock`/`win_unlock` **elide the whole
    /// lock protocol** — a local no-op grant instead of the OPA
    /// request/grant round trip or IB NIC atomics (the unlock's
    /// flush-completion semantics are kept). Load-bearing: the
    /// `no_locks_over_locked` bench gate measures the saved round trips,
    /// and `MpiProc::lock_elision_count` /
    /// `MpiProc::lock_wire_req_count` prove which path fired. See the
    /// decision table in `mpi::rma`.
    pub no_locks: bool,
}

impl Default for WinPolicy {
    fn default() -> Self {
        WinPolicy {
            relaxed_accumulate: false,
            striping: VciStriping::Off,
            rx_doorbell: false,
            no_locks: false,
        }
    }
}

impl WinPolicy {
    /// The process-default window policy: the demoted `MpiConfig` RMA
    /// hint. Every window starts from it; info keys at creation override.
    pub fn from_config(cfg: &MpiConfig) -> Self {
        WinPolicy {
            relaxed_accumulate: cfg.hints.accumulate_ordering_none,
            striping: VciStriping::Off,
            rx_doorbell: cfg.rx_doorbell,
            no_locks: false,
        }
    }

    /// Resolve a derived policy: this policy overridden by `info`'s keys.
    /// An empty info inherits unchanged — `win_create` is
    /// `win_create_with_info(.., &Info::new())`.
    pub fn with_info(&self, info: &Info) -> Self {
        let mut p = self.clone();
        if let Some(v) = info.get("accumulate_ordering") {
            p.relaxed_accumulate = parse_accumulate_ordering(v);
        }
        if let Some(v) = info.get("vcmpi_striping") {
            p.striping = parse_striping(v);
        }
        if let Some(v) = info.get("vcmpi_rx_doorbell") {
            p.rx_doorbell = parse_bool("vcmpi_rx_doorbell", v);
        }
        if let Some(v) = info.get("mpi_assert_no_locks") {
            p.no_locks = parse_bool("mpi_assert_no_locks", v);
        }
        p
    }

    /// Does this policy stripe any one-sided traffic across the pool?
    pub fn striped(&self) -> bool {
        self.striping != VciStriping::Off
    }

    /// Puts stripe whenever striping is on: MPI guarantees no ordering
    /// between puts (overlapping unsynchronized puts are already
    /// undefined), so fanning them out is always legal.
    pub fn stripes_puts(&self) -> bool {
        self.striped()
    }

    /// Accumulates stripe only when program order was relaxed
    /// (`accumulate_ordering=none`): the default ordering guarantees
    /// same-origin same-target accumulates apply in program order, which
    /// per-message fan-out would break.
    pub fn stripes_accumulates(&self) -> bool {
        self.striped() && self.relaxed_accumulate
    }

    /// Gets stripe whenever striping is on, like puts: MPI imposes no
    /// ordering between gets (or between gets and puts) within a passive
    /// epoch, and completion is counted per (window, target, lane) — the
    /// reply echoes the issuing lane exactly like `RmaAckCount`.
    pub fn stripes_gets(&self) -> bool {
        self.striped()
    }
}

/// `accumulate_ordering` value: `none` relaxes ordering; a comma list
/// drawn from `rar,raw,war,waw` (MPI-3.1's ordering vocabulary) keeps the
/// ordered path. Anything else is erroneous.
fn parse_accumulate_ordering(v: &str) -> bool {
    if v == "none" {
        return true;
    }
    let all_known = !v.is_empty()
        && v.split(',').all(|t| matches!(t.trim(), "rar" | "raw" | "war" | "waw"));
    if !all_known {
        panic!(
            "info key accumulate_ordering: expected none or a rar/raw/war/waw list, got {v:?} (erroneous program)"
        );
    }
    false
}

fn parse_collectives(v: &str) -> CollectivesMode {
    match v {
        "inherit" => CollectivesMode::Inherit,
        "dedicated" => CollectivesMode::Dedicated,
        "striped" => CollectivesMode::Striped,
        other => panic!(
            "info key vcmpi_collectives: expected inherit|dedicated|striped, got {other:?} (erroneous program)"
        ),
    }
}

fn parse_striping(v: &str) -> VciStriping {
    match v {
        "off" => VciStriping::Off,
        "rr" => VciStriping::RoundRobin,
        "hash" => VciStriping::HashedByRequest,
        other => panic!(
            "info key vcmpi_striping: expected off|rr|hash, got {other:?} (erroneous program)"
        ),
    }
}

fn parse_bool(key: &str, v: &str) -> bool {
    match v {
        "true" | "1" => true,
        "false" | "0" => false,
        other => panic!("info key {key}: expected true|false, got {other:?} (erroneous program)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_last_set_wins_and_unknown_keys_are_ignored() {
        let info = Info::new()
            .with("vcmpi_striping", "off")
            .with("vcmpi_striping", "rr")
            .with("some_vendor_key", "whatever");
        assert_eq!(info.get("vcmpi_striping"), Some("rr"));
        assert_eq!(info.get("missing"), None);
        let p = CommPolicy::default().with_info(&info);
        assert_eq!(p.striping, VciStriping::RoundRobin);
    }

    #[test]
    fn defaults_mirror_the_config_presets() {
        let p = CommPolicy::from_config(&MpiConfig::striped_sharded(8));
        assert_eq!(p.striping, VciStriping::RoundRobin);
        assert_eq!(p.match_shards, 8);
        assert!(p.rx_doorbell);
        let q = CommPolicy::from_config(&MpiConfig::optimized(8));
        assert!(!q.striped());
        assert_eq!(q.match_shards, 1);
    }

    #[test]
    fn with_info_overrides_only_named_keys() {
        let base = CommPolicy::from_config(&MpiConfig::striped_sharded(8));
        let p = base.with_info(
            &Info::new().with("vcmpi_match_shards", "3").with("vcmpi_wildcard_linger", "5"),
        );
        assert_eq!(p.match_shards, 3);
        assert_eq!(p.shard_mask(), 3, "rounded up to 4 shards");
        assert_eq!(p.wildcard_linger, 5);
        assert_eq!(p.striping, base.striping, "unnamed keys inherit");
        assert!(p.rx_doorbell);
    }

    #[test]
    fn wildcard_assertions_parse() {
        let p = CommPolicy::default().with_info(
            &Info::new()
                .with("mpi_assert_no_any_source", "true")
                .with("mpi_assert_no_any_tag", "1"),
        );
        assert!(p.no_any_source && p.no_any_tag);
        assert!(!p.ordered().striped());
    }

    #[test]
    fn collectives_keys_parse_and_default_to_inherit() {
        let base = CommPolicy::default();
        assert_eq!(base.collectives, CollectivesMode::Inherit);
        assert_eq!(base.coll_segments, DEFAULT_COLL_SEGMENTS);
        let p = base.with_info(
            &Info::new()
                .with("vcmpi_collectives", "dedicated")
                .with("vcmpi_coll_segments", "12"),
        );
        assert_eq!(p.collectives, CollectivesMode::Dedicated);
        assert_eq!(p.coll_segments, 12);
        let q = p.with_info(&Info::new().with("vcmpi_collectives", "striped"));
        assert_eq!(q.collectives, CollectivesMode::Striped);
        assert_eq!(q.coll_segments, 12, "unnamed keys inherit");
        // Segment counts clamp into the wire-contract tag budget.
        let r = base.with_info(&Info::new().with("vcmpi_coll_segments", "100000"));
        assert_eq!(r.coll_segments, MAX_COLL_SEGMENTS);
        let z = base.with_info(&Info::new().with("vcmpi_coll_segments", "0"));
        assert_eq!(z.coll_segments, 1);
    }

    #[test]
    fn coll_segments_auto_parses_and_explicit_count_clears_it() {
        let base = CommPolicy::default();
        assert!(!base.coll_segments_auto);
        let auto = base.with_info(&Info::new().with("vcmpi_coll_segments", "auto"));
        assert!(auto.coll_segments_auto);
        assert_eq!(
            auto.coll_segments, DEFAULT_COLL_SEGMENTS,
            "the static count survives as the bcast fallback"
        );
        let back = auto.with_info(&Info::new().with("vcmpi_coll_segments", "6"));
        assert!(!back.coll_segments_auto, "an explicit count overrides auto");
        assert_eq!(back.coll_segments, 6);
    }

    #[test]
    fn stream_key_parses_and_defaults_off() {
        let base = CommPolicy::default();
        assert!(!base.stream);
        let p = base.with_info(&Info::new().with("vcmpi_stream", "local"));
        assert!(p.stream);
        assert!(!p.striped(), "a stream is an ordered lane");
        // A striped process default needs striping explicitly disabled.
        let striped_base = CommPolicy::from_config(&MpiConfig::striped(8));
        let q = striped_base.with_info(
            &Info::new().with("vcmpi_striping", "off").with("vcmpi_stream", "local"),
        );
        assert!(q.stream && !q.striped());
    }

    #[test]
    #[should_panic(expected = "vcmpi_stream")]
    fn malformed_stream_value_is_erroneous() {
        let _ = CommPolicy::default().with_info(&Info::new().with("vcmpi_stream", "global"));
    }

    #[test]
    #[should_panic(expected = "mutually exclusive")]
    fn stream_plus_striping_is_erroneous() {
        let _ = CommPolicy::default().with_info(
            &Info::new().with("vcmpi_striping", "rr").with("vcmpi_stream", "local"),
        );
    }

    #[test]
    #[should_panic(expected = "vcmpi_collectives")]
    fn malformed_collectives_mode_is_erroneous() {
        let _ =
            CommPolicy::default().with_info(&Info::new().with("vcmpi_collectives", "sideways"));
    }

    #[test]
    #[should_panic(expected = "vcmpi_coll_segments")]
    fn malformed_coll_segments_is_erroneous() {
        let _ =
            CommPolicy::default().with_info(&Info::new().with("vcmpi_coll_segments", "several"));
    }

    #[test]
    #[should_panic(expected = "vcmpi_striping")]
    fn malformed_striping_value_is_erroneous() {
        let _ = CommPolicy::default().with_info(&Info::new().with("vcmpi_striping", "sideways"));
    }

    #[test]
    #[should_panic(expected = "vcmpi_match_shards")]
    fn malformed_shard_count_is_erroneous() {
        let _ = CommPolicy::default().with_info(&Info::new().with("vcmpi_match_shards", "many"));
    }

    #[test]
    fn win_policy_resolves_from_config_and_info() {
        let mut cfg = MpiConfig::optimized(8);
        cfg.hints.accumulate_ordering_none = true;
        let base = WinPolicy::from_config(&cfg);
        assert!(base.relaxed_accumulate, "process hint seeds the default");
        assert!(!base.striped());
        let p = base.with_info(
            &Info::new()
                .with("vcmpi_striping", "rr")
                .with("vcmpi_rx_doorbell", "true")
                .with("mpi_assert_no_locks", "1"),
        );
        assert_eq!(p.striping, VciStriping::RoundRobin);
        assert!(p.rx_doorbell && p.no_locks);
        assert!(p.stripes_puts() && p.stripes_accumulates());
    }

    #[test]
    fn win_policy_decision_table() {
        // Ordered window: nothing stripes.
        let ordered = WinPolicy::default();
        assert!(!ordered.stripes_puts() && !ordered.stripes_accumulates());
        // Striped but accumulate ordering kept: puts stripe, accs do not.
        let puts_only =
            WinPolicy::default().with_info(&Info::new().with("vcmpi_striping", "hash"));
        assert!(puts_only.stripes_puts());
        assert!(!puts_only.stripes_accumulates(), "ordered accs keep program order");
        // Relaxed + striped: both stripe.
        let both = WinPolicy::default().with_info(
            &Info::new().with("accumulate_ordering", "none").with("vcmpi_striping", "rr"),
        );
        assert!(both.stripes_puts() && both.stripes_accumulates());
        // An explicit MPI-3.1 ordering list keeps the ordered path.
        let listed = both.with_info(&Info::new().with("accumulate_ordering", "rar,raw,war,waw"));
        assert!(!listed.relaxed_accumulate && !listed.stripes_accumulates());
    }

    #[test]
    #[should_panic(expected = "accumulate_ordering")]
    fn malformed_accumulate_ordering_is_erroneous() {
        let _ =
            WinPolicy::default().with_info(&Info::new().with("accumulate_ordering", "sometimes"));
    }
}
