//! Per-communicator policy: the info-key-driven resolution of the
//! striping / sharding / wildcard knobs that used to be process-global.
//!
//! The paper's position (§7) is that users should expose parallelism
//! through *existing* MPI mechanisms — communicators and per-object info
//! hints — and let the library map that parallelism onto VCIs. After the
//! striping and sharded-matching work, our knobs (`vci_striping`,
//! `match_shards`, `wildcard_epoch_linger`, `rx_doorbell`, the wildcard
//! assertions) lived on [`MpiConfig`], so one process could not host a
//! hot halo-exchange communicator *and* a latency-sensitive ordered
//! communicator with different policies. This module lifts them into a
//! per-communicator [`CommPolicy`], resolved at communicator creation
//! from MPI-4-style [`Info`] keys; the `MpiConfig` values are demoted to
//! process-wide **defaults** (the policy every communicator starts from,
//! including `MPI_COMM_WORLD`).
//!
//! # Info-key vocabulary
//!
//! | key                        | values            | effect |
//! |----------------------------|-------------------|--------|
//! | `vcmpi_striping`           | `off`\|`rr`\|`hash` | per-message VCI striping mode for this communicator |
//! | `vcmpi_match_shards`       | integer ≥ 1       | matching shards for striped traffic (rounded up to a power of two) |
//! | `vcmpi_wildcard_linger`    | integer ≥ 0       | wildcard-epoch hysteresis, in operations |
//! | `vcmpi_rx_doorbell`        | `true`\|`false`   | participate in doorbell-gated striped sweeps |
//! | `mpi_assert_no_any_source` | `true`\|`false`   | receives on this comm never use `MPI_ANY_SOURCE` |
//! | `mpi_assert_no_any_tag`    | `true`\|`false`   | receives on this comm never use `MPI_ANY_TAG` |
//!
//! Unknown keys are ignored (MPI info semantics); a malformed value for a
//! known key panics — it is a programming error, like posting a wildcard
//! under an asserted hint.
//!
//! # Wire-contract symmetry
//!
//! Like `num_vcis` and the striping wire format, a communicator's policy
//! is part of the job-wide contract: every member must pass the same info
//! keys to the same creation call, so the policy is derived
//! deterministically from `(comm id, info)` and all members agree on
//! whether envelopes are striped and how streams shard. This is asserted
//! the same way `num_vcis` symmetry is — by construction plus a counted
//! diagnostic (`MpiProc::policy_mismatch_count`) when a striped envelope
//! arrives for a communicator whose registered policy says `off`.

use super::config::{MpiConfig, VciStriping};

/// An MPI-4.0-style info object: an ordered list of `(key, value)`
/// string pairs. Later `set`s of the same key win.
#[derive(Clone, Debug, Default)]
pub struct Info {
    entries: Vec<(String, String)>,
}

impl Info {
    pub fn new() -> Self {
        Info { entries: Vec::new() }
    }

    /// MPI_Info_set.
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.entries.push((key.into(), value.into()));
    }

    /// Builder-style `set` for test/bench ergonomics.
    pub fn with(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.set(key, value);
        self
    }

    /// MPI_Info_get: the latest value set for `key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The per-communicator resolution of the striping/sharding knobs.
///
/// Built once at communicator creation ([`from_config`] for the process
/// defaults, then [`with_info`] per creation call) and carried by every
/// [`super::comm::Comm`] handle as an `Arc`; the process also keeps a
/// `comm id -> policy` table so the receive side (which only sees comm
/// ids on the wire) can build matching engines with the right shape.
///
/// [`from_config`]: CommPolicy::from_config
/// [`with_info`]: CommPolicy::with_info
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CommPolicy {
    /// Per-message VCI striping mode for this communicator's two-sided
    /// traffic (`vcmpi_striping`). `Off` pins the communicator to its
    /// assigned VCI — and *pins that VCI out of the stripe-lane set*, so
    /// striped communicators' bulk traffic never queues behind it.
    pub striping: VciStriping,
    /// Matching shards for striped traffic (`vcmpi_match_shards`,
    /// rounded up to a power of two by the engine; `1` = the single
    /// home-engine arm).
    pub match_shards: usize,
    /// Wildcard-epoch hysteresis in operations (`vcmpi_wildcard_linger`).
    pub wildcard_linger: u32,
    /// Does this communicator's striped traffic participate in
    /// doorbell-gated progress sweeps (`vcmpi_rx_doorbell`)?
    pub rx_doorbell: bool,
    /// `mpi_assert_no_any_source`: receives never use `MPI_ANY_SOURCE`,
    /// so (with `no_any_tag`) unstriped traffic may spread by envelope.
    pub no_any_source: bool,
    /// `mpi_assert_no_any_tag`: receives never use `MPI_ANY_TAG`.
    pub no_any_tag: bool,
}

impl Default for CommPolicy {
    fn default() -> Self {
        CommPolicy {
            striping: VciStriping::Off,
            match_shards: 1,
            wildcard_linger: 0,
            rx_doorbell: false,
            no_any_source: false,
            no_any_tag: false,
        }
    }
}

impl CommPolicy {
    /// The process-default policy: the demoted `MpiConfig` knobs. Every
    /// preset builds exactly its pre-policy behavior through this path.
    pub fn from_config(cfg: &MpiConfig) -> Self {
        CommPolicy {
            striping: cfg.vci_striping,
            match_shards: cfg.match_shards,
            wildcard_linger: cfg.wildcard_epoch_linger,
            rx_doorbell: cfg.rx_doorbell,
            no_any_source: cfg.hints.no_any_source,
            no_any_tag: cfg.hints.no_any_tag,
        }
    }

    /// Resolve a derived policy: this policy (the parent communicator's)
    /// overridden by `info`'s keys. An empty info inherits the parent
    /// policy unchanged — `comm_dup` is `comm_dup_with_info(.., &Info::new())`.
    pub fn with_info(&self, info: &Info) -> Self {
        let mut p = self.clone();
        if let Some(v) = info.get("vcmpi_striping") {
            p.striping = match v {
                "off" => VciStriping::Off,
                "rr" => VciStriping::RoundRobin,
                "hash" => VciStriping::HashedByRequest,
                other => panic!(
                    "info key vcmpi_striping: expected off|rr|hash, got {other:?} (erroneous program)"
                ),
            };
        }
        if let Some(v) = info.get("vcmpi_match_shards") {
            p.match_shards = v
                .parse::<usize>()
                .unwrap_or_else(|_| {
                    panic!(
                        "info key vcmpi_match_shards: expected an integer, got {v:?} (erroneous program)"
                    )
                })
                .max(1);
        }
        if let Some(v) = info.get("vcmpi_wildcard_linger") {
            p.wildcard_linger = v.parse::<u32>().unwrap_or_else(|_| {
                panic!(
                    "info key vcmpi_wildcard_linger: expected an integer, got {v:?} (erroneous program)"
                )
            });
        }
        if let Some(v) = info.get("vcmpi_rx_doorbell") {
            p.rx_doorbell = parse_bool("vcmpi_rx_doorbell", v);
        }
        if let Some(v) = info.get("mpi_assert_no_any_source") {
            p.no_any_source = parse_bool("mpi_assert_no_any_source", v);
        }
        if let Some(v) = info.get("mpi_assert_no_any_tag") {
            p.no_any_tag = parse_bool("mpi_assert_no_any_tag", v);
        }
        p
    }

    /// Does this policy stripe two-sided traffic across the pool?
    pub fn striped(&self) -> bool {
        self.striping != VciStriping::Off
    }

    /// Shard-index mask of this policy's matching engine: shard count
    /// rounded up to a power of two, minus one (mirrors `CommMatch`).
    pub fn shard_mask(&self) -> usize {
        self.match_shards.max(1).next_power_of_two() - 1
    }

    /// This policy with striping forced off (endpoints communicators:
    /// each endpoint IS a dedicated VCI, so striping would defeat them).
    pub fn ordered(&self) -> Self {
        CommPolicy { striping: VciStriping::Off, ..self.clone() }
    }
}

fn parse_bool(key: &str, v: &str) -> bool {
    match v {
        "true" | "1" => true,
        "false" | "0" => false,
        other => panic!("info key {key}: expected true|false, got {other:?} (erroneous program)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_last_set_wins_and_unknown_keys_are_ignored() {
        let info = Info::new()
            .with("vcmpi_striping", "off")
            .with("vcmpi_striping", "rr")
            .with("some_vendor_key", "whatever");
        assert_eq!(info.get("vcmpi_striping"), Some("rr"));
        assert_eq!(info.get("missing"), None);
        let p = CommPolicy::default().with_info(&info);
        assert_eq!(p.striping, VciStriping::RoundRobin);
    }

    #[test]
    fn defaults_mirror_the_config_presets() {
        let p = CommPolicy::from_config(&MpiConfig::striped_sharded(8));
        assert_eq!(p.striping, VciStriping::RoundRobin);
        assert_eq!(p.match_shards, 8);
        assert!(p.rx_doorbell);
        let q = CommPolicy::from_config(&MpiConfig::optimized(8));
        assert!(!q.striped());
        assert_eq!(q.match_shards, 1);
    }

    #[test]
    fn with_info_overrides_only_named_keys() {
        let base = CommPolicy::from_config(&MpiConfig::striped_sharded(8));
        let p = base.with_info(
            &Info::new().with("vcmpi_match_shards", "3").with("vcmpi_wildcard_linger", "5"),
        );
        assert_eq!(p.match_shards, 3);
        assert_eq!(p.shard_mask(), 3, "rounded up to 4 shards");
        assert_eq!(p.wildcard_linger, 5);
        assert_eq!(p.striping, base.striping, "unnamed keys inherit");
        assert!(p.rx_doorbell);
    }

    #[test]
    fn wildcard_assertions_parse() {
        let p = CommPolicy::default().with_info(
            &Info::new()
                .with("mpi_assert_no_any_source", "true")
                .with("mpi_assert_no_any_tag", "1"),
        );
        assert!(p.no_any_source && p.no_any_tag);
        assert!(!p.ordered().striped());
    }

    #[test]
    #[should_panic(expected = "vcmpi_striping")]
    fn malformed_striping_value_is_erroneous() {
        let _ = CommPolicy::default().with_info(&Info::new().with("vcmpi_striping", "sideways"));
    }

    #[test]
    #[should_panic(expected = "vcmpi_match_shards")]
    fn malformed_shard_count_is_erroneous() {
        let _ = CommPolicy::default().with_info(&Info::new().with("vcmpi_match_shards", "many"));
    }
}
