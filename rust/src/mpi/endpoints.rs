//! User-visible MPI Endpoints — the proposed-standard extension the paper
//! argues against (Dinan et al.), implemented on top of the same VCI
//! infrastructure ("each endpoint is a VCI", paper §5) so the two
//! approaches can be compared per-experiment.
//!
//! `create_endpoints(parent, n)` is collective: every process derives an
//! endpoints communicator whose rank space is `nprocs * n`, with endpoint
//! `e` of process `p` at rank `p*n + e`, pinned to its own VCI. Threads
//! then communicate *through* a specific endpoint, giving them explicit,
//! direct control over the underlying hardware context — exactly what
//! MPI-3.1 abstracts away.

use std::sync::Arc;

use super::comm::{Comm, CommKind};
use super::proc::MpiProc;

impl MpiProc {
    /// Collective: create `n` endpoints per process on a new communicator.
    ///
    /// Panics if the VCI pool cannot supply `n` distinct VCIs (endpoints
    /// expose hardware limits to the user — that is the point of them).
    pub fn create_endpoints(&self, parent: &Comm, n: usize) -> Comm {
        assert!(n >= 1);
        let mut vcis = Vec::with_capacity(n);
        for k in 0..n {
            let idx = self.vcis().assign(0xEE00_0000_0000_0000 | k as u64);
            vcis.push(idx);
        }
        // Endpoints demand dedicated channels; if the pool collapsed onto
        // the fallback for any endpoint beyond the first, the hardware is
        // oversubscribed — surface it rather than silently serializing.
        let distinct: std::collections::HashSet<usize> = vcis.iter().copied().collect();
        assert_eq!(
            distinct.len(),
            n,
            "endpoint creation needs {n} distinct VCIs; pool exhausted (hardware limit)"
        );
        // Communicator ids must agree across processes: derive from the
        // per-process creation counter (creation is collective and ordered).
        let id = self.alloc_comm_id();
        let c = Comm {
            id,
            vci: vcis[0],
            size: parent.size * n,
            rank: parent.rank,
            kind: CommKind::Endpoints { per_proc: n, vcis: Arc::new(vcis) },
            // Endpoints never stripe (each endpoint IS a dedicated VCI);
            // registering the ordered policy also pins every endpoint VCI
            // out of the stripe-lane set, so a coexisting striped comm's
            // bulk traffic never queues on an endpoint's context.
            policy: Arc::new(parent.policy.ordered()),
        };
        self.register_comm(&c);
        c
    }

    /// Free the endpoints communicator, returning its VCIs to the pool
    /// and dropping its policy registration (and lane pins).
    pub fn free_endpoints(&self, comm: Comm) {
        if let CommKind::Endpoints { vcis, .. } = &comm.kind {
            for &v in vcis.iter() {
                self.vcis().release(v);
            }
        }
        self.unregister_comm(&comm);
    }

    /// Endpoint rank of endpoint `e` on process `p` within `comm`.
    pub fn endpoint_rank(&self, comm: &Comm, p: usize, e: usize) -> usize {
        p * comm.ranks_per_proc() + e
    }
}
