//! Critical-path instrumentation: lock/atomic counting (reproduces Table 1)
//! and modeled atomic counters.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::platform::{padvance, Backend};
use crate::sim;
use crate::sim::sanitizer::{self, LockTag};

thread_local! {
    static LOCKS_VCI: Cell<u64> = const { Cell::new(0) };
    static LOCKS_REQUEST: Cell<u64> = const { Cell::new(0) };
    static LOCKS_GLOBAL: Cell<u64> = const { Cell::new(0) };
    static LOCKS_HOOK: Cell<u64> = const { Cell::new(0) };
    static LOCKS_SHARD: Cell<u64> = const { Cell::new(0) };
    static ATOMIC_OPS: Cell<u64> = const { Cell::new(0) };
    static ANCHORED_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static COLL_SEGMENTS: Cell<u64> = const { Cell::new(0) };
    static COLL_LANE_SPREAD: Cell<u64> = const { Cell::new(0) };
    static COLL_OVERLAP_NS: Cell<u64> = const { Cell::new(0) };
    static STREAM_OPS: Cell<u64> = const { Cell::new(0) };
    static STREAM_FREELIST_HITS: Cell<u64> = const { Cell::new(0) };
    static FAILOVERS: Cell<u64> = const { Cell::new(0) };
}

/// Which class of lock was taken.
///
/// The first five are the paper Table 1 columns (plus the matching-shard
/// locks introduced by per-source sharded matching) and are counted per
/// thread. The remainder exist for SimSan's lock-order checking: they name
/// every host (`std::sync`) mutex in `mpi/` plus the wildcard-epoch
/// control lock, and are *not* counted (they are not Table-1 critical-path
/// locks — EpochCtl was never counted, and host mutexes are bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockClass {
    Global,
    Vci,
    Request,
    Hook,
    /// A per-communicator matching shard (see `mpi::shard`).
    Shard,
    /// Wildcard-epoch / engine-retirement control (`mpi::shard::EpochCtl`).
    EpochCtl,
    /// A nonblocking-collective schedule (`mpi::coll_nb::CollSched`):
    /// serializes the waiter and the progress hook advancing one handle.
    CollSched,
    // --- host mutex classes (leaf-only; see sim::sanitizer) ---
    /// `MpiProc::comms`.
    HostComms,
    /// `MpiProc::windows`.
    HostWindows,
    /// `MpiProc::stripe_seq`.
    HostStripeSeq,
    /// `MpiProc::split_seqs`.
    HostSplitSeqs,
    /// `MpiProc::freed_comms` (tripwire; may nest into the engine table).
    HostFreedComms,
    /// `MpiProc::match_engines` (the host engine table).
    HostMatchEngines,
    /// `MpiProc::policies` (nested inside the engine table on misses).
    HostPolicies,
    /// `MpiProc::coll_lanes` (may nest into the pin table).
    HostCollLanes,
    /// `MpiProc::coll_scheds` (outstanding nonblocking-collective registry).
    HostCollScheds,
    /// `MpiProc::ordered_pins`.
    HostOrderedPins,
    /// `MpiProc::streams` (serial-execution-stream bind table).
    HostStreams,
    /// `MpiProc::failed_lanes` (lane-failover dead→survivor table). Held
    /// only for the idempotence check — never across a state migration
    /// (VCI locks park, and host mutexes must not be held across one).
    HostFailover,
    /// `Window::outstanding` (RMA completion records).
    HostRmaOutstanding,
    /// `Window::epochs` (origin-side passive-target lock epochs). Never
    /// held together with `HostRmaOutstanding`: unlock copies the epoch
    /// out, drops this lock, and only then drains the thread's records.
    HostRmaEpochs,
    /// `MpiProc::win_locks` (target-side passive-target lock tables: the
    /// FIFO reader/writer queue the OPA lock-protocol handlers serve).
    HostWinLocks,
    /// `Window::get_results` (parked MPI_Get payloads).
    HostRmaResults,
    /// `ReqSlot::data` (received payload parking).
    HostSlotData,
    /// `Vci::deferred_frees` (striped-flagged request frees).
    HostDeferredFrees,
    /// `VciPool::free` (VCI allocation free list).
    HostPoolFree,
    /// `world::NATIVE_MEASUREMENTS` (native-backend bench recording).
    HostMeasurements,
}

pub fn count_lock(class: LockClass) {
    let cell = match class {
        LockClass::Global => &LOCKS_GLOBAL,
        LockClass::Vci => &LOCKS_VCI,
        LockClass::Request => &LOCKS_REQUEST,
        LockClass::Hook => &LOCKS_HOOK,
        LockClass::Shard => &LOCKS_SHARD,
        // Not Table-1 critical-path locks: uncounted.
        _ => return,
    };
    cell.with(|c| c.set(c.get() + 1));
}

// ---------------------------------------------------------------------------
// SimSan lock tags (see sim::sanitizer for the checking machinery)
// ---------------------------------------------------------------------------
//
// Rank layout — strictly increasing along every legal nesting chain:
//
//   sim locks:   Global 10 < Hook 20 < CollSched 25 < Vci 30 < Request 40
//                < EpochCtl 50 < Shard 60 (multi, ascending shard index)
//                (CollSched sits between Hook and Vci: the progress hook
//                advances a nonblocking-collective schedule, and advancing
//                one issues sends that take VCI locks.)
//   host locks:  rank >= 100, leaf-only relative to sim locks, ordered
//                among themselves to permit the legal host-host
//                nestings: freed_comms -> match_engines -> policies
//                (finalize / comm_match) and coll_lanes -> ordered_pins
//                (dedicated_coll_lane).

macro_rules! tags {
    ($($cls:ident => $name:ident { $lit:literal, $rank:literal, $multi:literal, $host:literal }),+ $(,)?) => {
        $(static $name: LockTag = LockTag {
            name: $lit,
            rank: $rank,
            ordered: true,
            multi: $multi,
            host: $host,
        };)+
        /// The SimSan tag for a lock class (static identity; ranks above).
        pub fn tag_of(class: LockClass) -> &'static LockTag {
            match class {
                $(LockClass::$cls => &$name,)+
            }
        }
    };
}

tags! {
    Global => TAG_GLOBAL { "cs.global", 10, false, false },
    Hook => TAG_HOOK { "progress.hook", 20, false, false },
    Vci => TAG_VCI { "vci.state", 30, false, false },
    Request => TAG_REQUEST { "request.free", 40, false, false },
    CollSched => TAG_COLL_SCHED { "coll.sched", 25, false, false },
    EpochCtl => TAG_EPOCH_CTL { "shard.epoch_ctl", 50, false, false },
    Shard => TAG_SHARD { "shard.leaf", 60, true, false },
    HostComms => TAG_HOST_COMMS { "host.comms", 100, false, true },
    HostWindows => TAG_HOST_WINDOWS { "host.windows", 105, false, true },
    HostStripeSeq => TAG_HOST_STRIPE_SEQ { "host.stripe_seq", 110, false, true },
    HostSplitSeqs => TAG_HOST_SPLIT_SEQS { "host.split_seqs", 115, false, true },
    HostFreedComms => TAG_HOST_FREED_COMMS { "host.freed_comms", 120, false, true },
    HostMatchEngines => TAG_HOST_MATCH_ENGINES { "host.match_engines", 125, false, true },
    HostPolicies => TAG_HOST_POLICIES { "host.policies", 130, false, true },
    HostCollLanes => TAG_HOST_COLL_LANES { "host.coll_lanes", 135, false, true },
    HostCollScheds => TAG_HOST_COLL_SCHEDS { "host.coll_scheds", 137, false, true },
    HostOrderedPins => TAG_HOST_ORDERED_PINS { "host.ordered_pins", 140, false, true },
    HostStreams => TAG_HOST_STREAMS { "host.streams", 142, false, true },
    HostFailover => TAG_HOST_FAILOVER { "host.failover", 143, false, true },
    HostRmaOutstanding => TAG_HOST_RMA_OUTSTANDING { "host.rma_outstanding", 145, false, true },
    HostRmaEpochs => TAG_HOST_RMA_EPOCHS { "host.rma_epochs", 147, false, true },
    HostWinLocks => TAG_HOST_WIN_LOCKS { "host.win_locks", 148, false, true },
    HostRmaResults => TAG_HOST_RMA_RESULTS { "host.rma_results", 150, false, true },
    HostSlotData => TAG_HOST_SLOT_DATA { "host.slot_data", 155, false, true },
    HostDeferredFrees => TAG_HOST_DEFERRED_FREES { "host.deferred_frees", 160, false, true },
    HostPoolFree => TAG_HOST_POOL_FREE { "host.pool_free", 165, false, true },
    HostMeasurements => TAG_HOST_MEASUREMENTS { "host.measurements", 170, false, true },
}

/// An instrumented host mutex: the only sanctioned way to use a
/// `std::sync::Mutex` inside `mpi/` (enforced by
/// `scripts/lint_lock_discipline.py`). Acquisition requires a
/// [`LockClass`], participates in SimSan's held-lock stack (so holding one
/// across a scheduler yield/park is reported), and recovers from poison
/// like the rest of the crate.
pub struct HostMutex<T> {
    inner: std::sync::Mutex<T>, // lint:allow-host-mutex (the wrapper itself)
}

impl<T> HostMutex<T> {
    pub fn new(value: T) -> Self {
        HostMutex { inner: std::sync::Mutex::new(value) } // lint:allow-host-mutex
    }

    #[track_caller]
    pub fn lock(&self, class: LockClass) -> HostMutexGuard<'_, T> {
        let id = &self.inner as *const _ as *const u8 as usize;
        sanitizer::lock_attempt(tag_of(class), id, 0);
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner()); // lint:allow-host-mutex
        HostMutexGuard { guard: g, id }
    }
}

pub struct HostMutexGuard<'a, T> {
    guard: std::sync::MutexGuard<'a, T>,
    id: usize,
}

impl<T> std::ops::Deref for HostMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> std::ops::DerefMut for HostMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

impl<T> Drop for HostMutexGuard<'_, T> {
    fn drop(&mut self) {
        sanitizer::lock_released(self.id);
    }
}

pub fn count_atomic() {
    ATOMIC_OPS.with(|c| c.set(c.get() + 1));
}

/// A striped receive post allocated its request from a shard-anchored VCI
/// cache instead of the communicator's home VCI (the Table-1 proof that
/// the receive-post path no longer funnels through one shared lock).
pub fn count_anchored_alloc() {
    ANCHORED_ALLOCS.with(|c| c.set(c.get() + 1));
}

/// One collective internal segment issued (a barrier round, a bcast or
/// allreduce segment): the Table-1 proof that collectives are segmented
/// rather than whole-payload lockstep.
pub fn count_coll_segment() {
    COLL_SEGMENTS.with(|c| c.set(c.get() + 1));
}

/// A collective segment issued on an explicit lane other than the
/// communicator's home VCI (dedicated-lane or envelope-spread collective
/// policies): the Table-1 proof that collective traffic leaves the home
/// lane. (Inherit-mode segments on a striped comm spread too, but via the
/// per-message striping path — counted there, not here.)
pub fn count_coll_lane_spread() {
    COLL_LANE_SPREAD.with(|c| c.set(c.get() + 1));
}

/// Virtual nanoseconds of compute the calling thread performed while a
/// nonblocking collective it had issued was still in flight (issue-to-wait
/// gap, clamped at completion): the Table-1 proof that `Iallreduce` hides
/// communication behind compute instead of blocking per bucket.
pub fn count_coll_overlap_ns(ns: u64) {
    COLL_OVERLAP_NS.with(|c| c.set(c.get() + ns));
}

/// One single-writer (stream) state entry — `Vci::with_state_stream`: the
/// Table-1 proof that the streamed arm's ops bypass the VCI lock (a
/// streamed run shows `stream_ops > 0` with `vci_locks == 0`).
pub fn count_stream_op() {
    STREAM_OPS.with(|c| c.set(c.get() + 1));
}

/// A stream request allocation satisfied from the thread-local freelist
/// (no shared request cache, no Request lock — Table 1's streamed
/// request-path column).
pub fn count_stream_freelist_hit() {
    STREAM_FREELIST_HITS.with(|c| c.set(c.get() + 1));
}

/// One VCI lane failover completed by the calling thread (a hard-failed
/// hardware context was quarantined and its matching state migrated to a
/// survivor lane — see `MpiProc::failover_vci`).
pub fn count_failover() {
    FAILOVERS.with(|c| c.set(c.get() + 1));
}

/// Snapshot of the calling thread's critical-path counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounters {
    pub global_locks: u64,
    pub vci_locks: u64,
    pub request_locks: u64,
    pub hook_locks: u64,
    pub shard_locks: u64,
    pub atomics: u64,
    /// Striped receive posts whose request came from a shard-anchored
    /// VCI's cache rather than the communicator's home VCI.
    pub anchored_allocs: u64,
    /// Collective internal segments issued (segmented pipelined
    /// collectives — see `mpi::collectives`).
    pub coll_segments: u64,
    /// Collective segments issued on an explicit non-home lane
    /// (dedicated / envelope-spread collective policies).
    pub coll_lane_spread: u64,
    /// Virtual ns of compute overlapped with in-flight nonblocking
    /// collectives (issue-to-wait gap; see `mpi::coll_nb`).
    pub coll_overlap_ns: u64,
    /// Single-writer stream state entries (`Vci::with_state_stream`) —
    /// lock-free ops on a stream-bound lane.
    pub stream_ops: u64,
    /// Stream request allocations served by the thread-local freelist
    /// (no Request lock, no shared cache).
    pub stream_freelist_hits: u64,
    /// VCI lane failovers completed by this thread (dead hardware context
    /// quarantined, state migrated to a survivor lane).
    pub failovers: u64,
}

impl OpCounters {
    pub fn total_locks(&self) -> u64 {
        self.global_locks + self.vci_locks + self.request_locks + self.hook_locks
            + self.shard_locks
    }
}

impl std::ops::Sub for OpCounters {
    type Output = OpCounters;
    fn sub(self, rhs: OpCounters) -> OpCounters {
        OpCounters {
            global_locks: self.global_locks - rhs.global_locks,
            vci_locks: self.vci_locks - rhs.vci_locks,
            request_locks: self.request_locks - rhs.request_locks,
            hook_locks: self.hook_locks - rhs.hook_locks,
            shard_locks: self.shard_locks - rhs.shard_locks,
            atomics: self.atomics - rhs.atomics,
            anchored_allocs: self.anchored_allocs - rhs.anchored_allocs,
            coll_segments: self.coll_segments - rhs.coll_segments,
            coll_lane_spread: self.coll_lane_spread - rhs.coll_lane_spread,
            coll_overlap_ns: self.coll_overlap_ns - rhs.coll_overlap_ns,
            stream_ops: self.stream_ops - rhs.stream_ops,
            stream_freelist_hits: self.stream_freelist_hits - rhs.stream_freelist_hits,
            failovers: self.failovers - rhs.failovers,
        }
    }
}

/// Read the calling thread's counters (monotonic; diff two snapshots to
/// count one operation, as `repro figures table1` does).
pub fn snapshot() -> OpCounters {
    OpCounters {
        global_locks: LOCKS_GLOBAL.with(|c| c.get()),
        vci_locks: LOCKS_VCI.with(|c| c.get()),
        request_locks: LOCKS_REQUEST.with(|c| c.get()),
        hook_locks: LOCKS_HOOK.with(|c| c.get()),
        shard_locks: LOCKS_SHARD.with(|c| c.get()),
        atomics: ATOMIC_OPS.with(|c| c.get()),
        anchored_allocs: ANCHORED_ALLOCS.with(|c| c.get()),
        coll_segments: COLL_SEGMENTS.with(|c| c.get()),
        coll_lane_spread: COLL_LANE_SPREAD.with(|c| c.get()),
        coll_overlap_ns: COLL_OVERLAP_NS.with(|c| c.get()),
        stream_ops: STREAM_OPS.with(|c| c.get()),
        stream_freelist_hits: STREAM_FREELIST_HITS.with(|c| c.get()),
        failovers: FAILOVERS.with(|c| c.get()),
    }
}

// ---------------------------------------------------------------------------
// Process-wide diagnostic counters
// ---------------------------------------------------------------------------
//
// Unlike the per-thread critical-path counters above, these aggregate over
// every thread (and, in a simulated cluster, every rank) of the host
// process: they exist so a bench run can snapshot "what did the engine do"
// — dropped control messages, wildcard-epoch flips, empty polls — into its
// JSON report without plumbing every `MpiProc` out of the workload closure.

static STALE_CTRL_DROPS: AtomicU64 = AtomicU64::new(0);
static DUP_SEQ_DROPS: AtomicU64 = AtomicU64::new(0);
static EPOCH_FLIPS: AtomicU64 = AtomicU64::new(0);
static EPOCH_UNFLIPS: AtomicU64 = AtomicU64::new(0);
static WILDCARD_POSTS: AtomicU64 = AtomicU64::new(0);
static EMPTY_POLLS: AtomicU64 = AtomicU64::new(0);
static DOORBELL_SKIPS: AtomicU64 = AtomicU64::new(0);
static LANE_FAILOVERS: AtomicU64 = AtomicU64::new(0);

pub fn record_stale_ctrl_drop() {
    STALE_CTRL_DROPS.fetch_add(1, Ordering::Relaxed);
}

pub fn record_dup_seq_drop() {
    DUP_SEQ_DROPS.fetch_add(1, Ordering::Relaxed);
}

/// One flip INTO the serialized wildcard epoch.
pub fn record_epoch_flip() {
    EPOCH_FLIPS.fetch_add(1, Ordering::Relaxed);
}

/// One flip back OUT of the serialized wildcard epoch.
pub fn record_epoch_unflip() {
    EPOCH_UNFLIPS.fetch_add(1, Ordering::Relaxed);
}

pub fn record_wildcard_post() {
    WILDCARD_POSTS.fetch_add(1, Ordering::Relaxed);
}

/// A hardware-context poll that found nothing ready.
pub fn record_empty_poll() {
    EMPTY_POLLS.fetch_add(1, Ordering::Relaxed);
}

/// A striped-progress sweep skipped outright because no rx doorbell was
/// rung (the poll that never happened).
pub fn record_doorbell_skip() {
    DOORBELL_SKIPS.fetch_add(1, Ordering::Relaxed);
}

/// One VCI lane failover completed anywhere in the process (chaos runs
/// assert this is nonzero after a context hard-fail).
pub fn record_failover() {
    LANE_FAILOVERS.fetch_add(1, Ordering::Relaxed);
}

/// Aggregate engine diagnostics since the last [`reset_proc_counters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProcCounters {
    /// Stale/duplicate/malformed wire control messages dropped.
    pub stale_ctrl_drops: u64,
    /// Striped arrivals dropped for a duplicate sequence number.
    pub dup_seq_drops: u64,
    /// Wildcard-epoch entries (flips into serialized matching).
    pub epoch_flips: u64,
    /// Wildcard-epoch exits (flips back to sharded matching).
    pub epoch_unflips: u64,
    /// `MPI_ANY_SOURCE` receives posted on sharded communicators.
    pub wildcard_posts: u64,
    /// Context polls that found nothing ready.
    pub empty_polls: u64,
    /// Striped sweeps skipped because no doorbell bit was set.
    pub doorbell_skips: u64,
    /// VCI lane failovers (dead context quarantined, state migrated).
    pub failovers: u64,
}

pub fn proc_counters() -> ProcCounters {
    ProcCounters {
        stale_ctrl_drops: STALE_CTRL_DROPS.load(Ordering::Relaxed),
        dup_seq_drops: DUP_SEQ_DROPS.load(Ordering::Relaxed),
        epoch_flips: EPOCH_FLIPS.load(Ordering::Relaxed),
        epoch_unflips: EPOCH_UNFLIPS.load(Ordering::Relaxed),
        wildcard_posts: WILDCARD_POSTS.load(Ordering::Relaxed),
        empty_polls: EMPTY_POLLS.load(Ordering::Relaxed),
        doorbell_skips: DOORBELL_SKIPS.load(Ordering::Relaxed),
        failovers: LANE_FAILOVERS.load(Ordering::Relaxed),
    }
}

/// Zero the process-wide counters (bench harnesses call this between runs;
/// racing workloads only smear counts between adjacent runs, never panic).
pub fn reset_proc_counters() {
    STALE_CTRL_DROPS.store(0, Ordering::Relaxed);
    DUP_SEQ_DROPS.store(0, Ordering::Relaxed);
    EPOCH_FLIPS.store(0, Ordering::Relaxed);
    EPOCH_UNFLIPS.store(0, Ordering::Relaxed);
    WILDCARD_POSTS.store(0, Ordering::Relaxed);
    EMPTY_POLLS.store(0, Ordering::Relaxed);
    DOORBELL_SKIPS.store(0, Ordering::Relaxed);
    LANE_FAILOVERS.store(0, Ordering::Relaxed);
}

/// A completion/reference counter whose *data* is always a host atomic
/// (correct on both backends) and whose *cost* is modeled explicitly:
/// in FG mode the paper's implementation pays an atomic RMW plus a
/// cache-line transfer when the previous toucher was another thread; under
/// the Global critical section (or Fig. 12's no-thread-safety mode) the
/// counter is a plain field and costs nothing extra.
pub struct ModeledCounter {
    v: AtomicU64,
    last_toucher: AtomicUsize,
    backend: Backend,
}

const NO_TOUCHER: usize = usize::MAX;

impl ModeledCounter {
    pub fn new(backend: Backend, v: u64) -> Self {
        ModeledCounter {
            v: AtomicU64::new(v),
            last_toucher: AtomicUsize::new(NO_TOUCHER),
            backend,
        }
    }

    fn charge(&self, charged: bool) {
        if !charged {
            return;
        }
        count_atomic();
        if self.backend == Backend::Sim {
            let me = sim::current_tid();
            let prev = self.last_toucher.swap(me, Ordering::Relaxed);
            let costs = crate::mpi::proc::active_costs();
            if prev != me {
                padvance(self.backend, costs.cacheline_transfer);
            }
            padvance(self.backend, costs.atomic_rmw);
        }
        // Native: the host atomic op below *is* the cost.
    }

    pub fn load(&self) -> u64 {
        self.v.load(Ordering::Acquire)
    }

    /// `charged`: whether this access models an atomic RMW (FG mode).
    pub fn fetch_add(&self, d: u64, charged: bool) -> u64 {
        self.charge(charged);
        self.v.fetch_add(d, Ordering::AcqRel)
    }

    pub fn fetch_sub(&self, d: u64, charged: bool) -> u64 {
        self.charge(charged);
        self.v.fetch_sub(d, Ordering::AcqRel)
    }

    pub fn store(&self, v: u64, charged: bool) {
        self.charge(charged);
        self.v.store(v, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_thread() {
        let base = snapshot();
        count_lock(LockClass::Vci);
        count_lock(LockClass::Vci);
        count_lock(LockClass::Request);
        count_lock(LockClass::Shard);
        count_atomic();
        count_anchored_alloc();
        count_coll_segment();
        count_coll_segment();
        count_coll_lane_spread();
        count_coll_overlap_ns(1500);
        count_stream_op();
        count_stream_op();
        count_stream_op();
        count_stream_freelist_hit();
        count_failover();
        let d = snapshot() - base;
        assert_eq!(d.vci_locks, 2);
        assert_eq!(d.request_locks, 1);
        assert_eq!(d.shard_locks, 1);
        assert_eq!(d.atomics, 1);
        assert_eq!(d.anchored_allocs, 1);
        assert_eq!(d.coll_segments, 2);
        assert_eq!(d.coll_lane_spread, 1);
        assert_eq!(d.coll_overlap_ns, 1500);
        assert_eq!(d.stream_ops, 3);
        assert_eq!(d.stream_freelist_hits, 1);
        assert_eq!(d.failovers, 1);
        assert_eq!(d.total_locks(), 4, "anchored allocs / coll segments / stream ops are not locks");
    }

    #[test]
    fn proc_counters_are_monotonic_across_records() {
        // Global counters shared with concurrently running tests: assert
        // deltas, not absolutes.
        let before = proc_counters();
        record_stale_ctrl_drop();
        record_dup_seq_drop();
        record_epoch_flip();
        record_epoch_unflip();
        record_wildcard_post();
        record_empty_poll();
        record_doorbell_skip();
        record_failover();
        let after = proc_counters();
        assert!(after.stale_ctrl_drops >= before.stale_ctrl_drops + 1);
        assert!(after.dup_seq_drops >= before.dup_seq_drops + 1);
        assert!(after.epoch_flips >= before.epoch_flips + 1);
        assert!(after.epoch_unflips >= before.epoch_unflips + 1);
        assert!(after.wildcard_posts >= before.wildcard_posts + 1);
        assert!(after.empty_polls >= before.empty_polls + 1);
        assert!(after.doorbell_skips >= before.doorbell_skips + 1);
        assert!(after.failovers >= before.failovers + 1);
    }

    #[test]
    fn modeled_counter_native_is_plain_atomic() {
        let c = ModeledCounter::new(Backend::Native, 5);
        assert_eq!(c.fetch_add(2, true), 5);
        assert_eq!(c.load(), 7);
        c.store(0, false);
        assert_eq!(c.load(), 0);
    }
}
