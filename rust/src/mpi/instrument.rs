//! Critical-path instrumentation: lock/atomic counting (reproduces Table 1)
//! and modeled atomic counters.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::platform::{padvance, Backend};
use crate::sim;

thread_local! {
    static LOCKS_VCI: Cell<u64> = const { Cell::new(0) };
    static LOCKS_REQUEST: Cell<u64> = const { Cell::new(0) };
    static LOCKS_GLOBAL: Cell<u64> = const { Cell::new(0) };
    static LOCKS_HOOK: Cell<u64> = const { Cell::new(0) };
    static ATOMIC_OPS: Cell<u64> = const { Cell::new(0) };
}

/// Which class of lock was taken (paper Table 1's columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockClass {
    Global,
    Vci,
    Request,
    Hook,
}

pub fn count_lock(class: LockClass) {
    let cell = match class {
        LockClass::Global => &LOCKS_GLOBAL,
        LockClass::Vci => &LOCKS_VCI,
        LockClass::Request => &LOCKS_REQUEST,
        LockClass::Hook => &LOCKS_HOOK,
    };
    cell.with(|c| c.set(c.get() + 1));
}

pub fn count_atomic() {
    ATOMIC_OPS.with(|c| c.set(c.get() + 1));
}

/// Snapshot of the calling thread's critical-path counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounters {
    pub global_locks: u64,
    pub vci_locks: u64,
    pub request_locks: u64,
    pub hook_locks: u64,
    pub atomics: u64,
}

impl OpCounters {
    pub fn total_locks(&self) -> u64 {
        self.global_locks + self.vci_locks + self.request_locks + self.hook_locks
    }
}

impl std::ops::Sub for OpCounters {
    type Output = OpCounters;
    fn sub(self, rhs: OpCounters) -> OpCounters {
        OpCounters {
            global_locks: self.global_locks - rhs.global_locks,
            vci_locks: self.vci_locks - rhs.vci_locks,
            request_locks: self.request_locks - rhs.request_locks,
            hook_locks: self.hook_locks - rhs.hook_locks,
            atomics: self.atomics - rhs.atomics,
        }
    }
}

/// Read the calling thread's counters (monotonic; diff two snapshots to
/// count one operation, as `repro figures table1` does).
pub fn snapshot() -> OpCounters {
    OpCounters {
        global_locks: LOCKS_GLOBAL.with(|c| c.get()),
        vci_locks: LOCKS_VCI.with(|c| c.get()),
        request_locks: LOCKS_REQUEST.with(|c| c.get()),
        hook_locks: LOCKS_HOOK.with(|c| c.get()),
        atomics: ATOMIC_OPS.with(|c| c.get()),
    }
}

/// A completion/reference counter whose *data* is always a host atomic
/// (correct on both backends) and whose *cost* is modeled explicitly:
/// in FG mode the paper's implementation pays an atomic RMW plus a
/// cache-line transfer when the previous toucher was another thread; under
/// the Global critical section (or Fig. 12's no-thread-safety mode) the
/// counter is a plain field and costs nothing extra.
pub struct ModeledCounter {
    v: AtomicU64,
    last_toucher: AtomicUsize,
    backend: Backend,
}

const NO_TOUCHER: usize = usize::MAX;

impl ModeledCounter {
    pub fn new(backend: Backend, v: u64) -> Self {
        ModeledCounter {
            v: AtomicU64::new(v),
            last_toucher: AtomicUsize::new(NO_TOUCHER),
            backend,
        }
    }

    fn charge(&self, charged: bool) {
        if !charged {
            return;
        }
        count_atomic();
        if self.backend == Backend::Sim {
            let me = sim::current_tid();
            let prev = self.last_toucher.swap(me, Ordering::Relaxed);
            let costs = crate::mpi::proc::active_costs();
            if prev != me {
                padvance(self.backend, costs.cacheline_transfer);
            }
            padvance(self.backend, costs.atomic_rmw);
        }
        // Native: the host atomic op below *is* the cost.
    }

    pub fn load(&self) -> u64 {
        self.v.load(Ordering::Acquire)
    }

    /// `charged`: whether this access models an atomic RMW (FG mode).
    pub fn fetch_add(&self, d: u64, charged: bool) -> u64 {
        self.charge(charged);
        self.v.fetch_add(d, Ordering::AcqRel)
    }

    pub fn fetch_sub(&self, d: u64, charged: bool) -> u64 {
        self.charge(charged);
        self.v.fetch_sub(d, Ordering::AcqRel)
    }

    pub fn store(&self, v: u64, charged: bool) {
        self.charge(charged);
        self.v.store(v, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_thread() {
        let base = snapshot();
        count_lock(LockClass::Vci);
        count_lock(LockClass::Vci);
        count_lock(LockClass::Request);
        count_atomic();
        let d = snapshot() - base;
        assert_eq!(d.vci_locks, 2);
        assert_eq!(d.request_locks, 1);
        assert_eq!(d.atomics, 1);
        assert_eq!(d.total_locks(), 3);
    }

    #[test]
    fn modeled_counter_native_is_plain_atomic() {
        let c = ModeledCounter::new(Backend::Native, 5);
        assert_eq!(c.fetch_add(2, true), 5);
        assert_eq!(c.load(), 7);
        c.store(0, false);
        assert_eq!(c.load(), 0);
    }
}
