//! Cluster runner: builds the network, spawns `nprocs x threads_per_proc`
//! workers (plus OPA-style service progress threads), runs MPI_Init /
//! user body / MPI_Finalize per process, on either backend.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

use crate::fabric::{FabricConfig, Interconnect, Network};
use crate::platform::{padvance, pnow, Backend, PBarrier};
use crate::sim::{CostModel, Sim, SimOutcome};

use super::config::MpiConfig;
use super::instrument::{HostMutex, LockClass};
use super::proc::{set_active_costs, MpiProc};

/// Everything needed to stand up a cluster run.
#[derive(Clone)]
pub struct ClusterSpec {
    pub fabric: FabricConfig,
    pub costs: CostModel,
    pub backend: Backend,
    pub mpi: MpiConfig,
    pub threads_per_proc: usize,
    /// Virtual-time cap for the DES (detects livelock; None = 300s).
    pub time_limit: Option<u64>,
    /// Run a low-frequency service progress thread per process (defaults
    /// to `interconnect == Opa` via [`ClusterSpec::default_services`]).
    pub service_threads: bool,
}

impl ClusterSpec {
    pub fn new(fabric: FabricConfig, mpi: MpiConfig, threads_per_proc: usize) -> Self {
        let service_threads = fabric.interconnect == Interconnect::Opa;
        ClusterSpec {
            fabric,
            costs: CostModel::default(),
            backend: Backend::Sim,
            mpi,
            threads_per_proc,
            time_limit: None,
            service_threads,
        }
    }
}

/// Result of a cluster run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub outcome: SimOutcome,
    /// Virtual end time (sim) or elapsed wallclock ns (native).
    pub time_ns: u64,
    pub measurements: HashMap<String, f64>,
    pub wall_ms: f64,
}

static NATIVE_MEASUREMENTS: OnceLock<HostMutex<HashMap<String, f64>>> = OnceLock::new();

/// Fold the fault plan's counters into the run's measurement map so chaos
/// tests can assert on them (and replay tests compare them bit-for-bit)
/// without reaching into the network.
fn record_fault_stats(m: &mut HashMap<String, f64>, s: &crate::fabric::FaultStats) {
    m.insert("fault_drops".into(), s.drops as f64);
    m.insert("fault_dups".into(), s.dups as f64);
    m.insert("fault_corrupts".into(), s.corrupts as f64);
    m.insert("fault_delays".into(), s.delays as f64);
    m.insert("fault_kill_drops".into(), s.kill_drops as f64);
    m.insert("fault_retransmits".into(), s.retransmits as f64);
    m.insert("fault_rel_dup_drops".into(), s.rel_dup_drops as f64);
    m.insert("fault_rel_corrupt_drops".into(), s.rel_corrupt_drops as f64);
    m.insert("fault_rel_reorders".into(), s.rel_reorders as f64);
}

/// Record a named measurement from inside a workload body (both backends).
pub fn record(name: impl Into<String>, value: f64) {
    if crate::sim::in_sim() {
        crate::sim::record(name, value);
    } else {
        NATIVE_MEASUREMENTS
            .get_or_init(|| HostMutex::new(HashMap::new()))
            .lock(LockClass::HostMeasurements)
            .insert(name.into(), value);
    }
}

/// Run `body(proc, thread_idx)` on every thread of every process.
pub fn run_cluster<F>(spec: ClusterSpec, body: F) -> RunReport
where
    F: Fn(&Arc<MpiProc>, usize) + Send + Sync + 'static,
{
    let wall_start = std::time::Instant::now();
    let costs = Arc::new(spec.costs.clone());
    let net = Network::new(spec.fabric.clone(), spec.backend, costs.clone());
    if let Some(spec_str) = &spec.mpi.fault_plan {
        let plan = crate::fabric::FaultPlan::parse(spec_str).unwrap_or_else(|e| {
            panic!("invalid vcmpi_fault_plan {spec_str:?}: {e}");
        });
        net.install_fault_plan(Arc::new(plan));
    }
    let nprocs = spec.fabric.nprocs();
    let procs: Vec<Arc<MpiProc>> =
        (0..nprocs).map(|p| MpiProc::new(net.proc_fabric(p), spec.mpi.clone())).collect();
    let body: Arc<F> = Arc::new(body);
    let tpp = spec.threads_per_proc;

    // One thread barrier per process (the "#pragma omp barrier" around the
    // parallel region).
    let barriers: Vec<Arc<PBarrier>> =
        (0..nprocs).map(|_| Arc::new(PBarrier::new(spec.backend, tpp))).collect();

    let worker = |proc: Arc<MpiProc>, bar: Arc<PBarrier>, t: usize, body: Arc<F>,
                  costs: Arc<CostModel>| {
        move || {
            set_active_costs(costs.clone());
            if t == 0 {
                let t0 = pnow(proc.backend);
                proc.init();
                record(format!("init_ns_p{}", proc.rank()), (pnow(proc.backend) - t0) as f64);
            }
            bar.wait();
            body(&proc, t);
            bar.wait();
            if t == 0 {
                let t0 = pnow(proc.backend);
                proc.finalize();
                record(
                    format!("finalize_ns_p{}", proc.rank()),
                    (pnow(proc.backend) - t0) as f64,
                );
            }
        }
    };

    let service = |proc: Arc<MpiProc>, costs: Arc<CostModel>| {
        move || {
            set_active_costs(costs.clone());
            loop {
                if proc.finalized.load(std::sync::atomic::Ordering::Acquire) {
                    break;
                }
                match proc.backend {
                    Backend::Sim => padvance(Backend::Sim, costs.psm2_progress_interval),
                    Backend::Native => std::thread::sleep(std::time::Duration::from_micros(
                        costs.psm2_progress_interval / 1000,
                    )),
                }
                proc.service_progress_round();
                crate::platform::pyield(proc.backend);
            }
        }
    };

    match spec.backend {
        Backend::Sim => {
            let mut sim = Sim::new(spec.costs.clone());
            sim.set_time_limit(spec.time_limit.unwrap_or(300_000_000_000));
            for (p, proc) in procs.iter().enumerate() {
                for t in 0..tpp {
                    sim.spawn_setup(
                        format!("p{p}t{t}"),
                        worker(proc.clone(), barriers[p].clone(), t, body.clone(), costs.clone()),
                    );
                }
                if spec.service_threads {
                    sim.spawn_setup(format!("p{p}-svc"), service(proc.clone(), costs.clone()));
                }
            }
            let r = sim.run();
            let mut measurements = r.measurements;
            if let Some(plan) = net.fault_plan() {
                record_fault_stats(&mut measurements, &plan.counters.snapshot());
            }
            RunReport {
                outcome: r.outcome,
                time_ns: r.end_time,
                measurements,
                wall_ms: wall_start.elapsed().as_secs_f64() * 1e3,
            }
        }
        Backend::Native => {
            if let Some(m) = NATIVE_MEASUREMENTS.get() {
                m.lock(LockClass::HostMeasurements).clear();
            }
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for (p, proc) in procs.iter().enumerate() {
                for t in 0..tpp {
                    let f =
                        worker(proc.clone(), barriers[p].clone(), t, body.clone(), costs.clone());
                    handles.push(std::thread::Builder::new()
                        .name(format!("p{p}t{t}"))
                        .spawn(f)
                        .expect("spawn"));
                }
                if spec.service_threads {
                    let f = service(proc.clone(), costs.clone());
                    handles.push(std::thread::Builder::new()
                        .name(format!("p{p}-svc"))
                        .spawn(f)
                        .expect("spawn"));
                }
            }
            let mut panicked = None;
            for h in handles {
                if let Err(e) = h.join() {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "worker panicked".into());
                    panicked = Some(msg);
                }
            }
            let mut measurements = NATIVE_MEASUREMENTS
                .get_or_init(|| HostMutex::new(HashMap::new()))
                .lock(LockClass::HostMeasurements)
                .clone();
            if let Some(plan) = net.fault_plan() {
                record_fault_stats(&mut measurements, &plan.counters.snapshot());
            }
            RunReport {
                outcome: match panicked {
                    Some(m) => SimOutcome::Panicked(m),
                    None => SimOutcome::Completed,
                },
                time_ns: t0.elapsed().as_nanos() as u64,
                measurements,
                wall_ms: wall_start.elapsed().as_secs_f64() * 1e3,
            }
        }
    }
}
