//! Interior mutability for simulation-owned state.

use std::cell::UnsafeCell;

/// A cell whose contents may be freely mutated by simulated threads.
///
/// # Safety invariant
/// The conservative scheduler guarantees that **exactly one** simulated
/// thread executes at any host instant, and baton handoffs go through a host
/// `Mutex`+`Condvar` pair, which establishes happens-before edges between
/// consecutive accessors. Under that regime, `&self` access to the interior
/// is data-race-free even though multiple OS threads hold references.
///
/// `SimCell` must therefore only be touched from *running* simulated threads
/// (i.e. between scheduler grants). All users in this crate follow the
/// pattern `sync-point -> mutate -> continue`, where the sync point is a
/// scheduler interaction ([`super::sched::advance`]/lock/queue ops).
pub struct SimCell<T> {
    inner: UnsafeCell<T>,
}

// SAFETY: see type-level invariant above — mutual exclusion and ordering are
// provided externally by the scheduler.
unsafe impl<T: Send> Send for SimCell<T> {}
unsafe impl<T: Send> Sync for SimCell<T> {}

impl<T> SimCell<T> {
    pub fn new(value: T) -> Self {
        SimCell { inner: UnsafeCell::new(value) }
    }

    /// Shared view. Caller must be the running simulated thread.
    #[allow(clippy::mut_from_ref)]
    pub fn get(&self) -> &mut T {
        // SAFETY: scheduler-enforced mutual exclusion (see type docs).
        unsafe { &mut *self.inner.get() }
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner()
    }
}

impl<T: Default> Default for SimCell<T> {
    fn default() -> Self {
        SimCell::new(T::default())
    }
}
