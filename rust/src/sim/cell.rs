//! Interior mutability for simulation-owned state.

use std::cell::UnsafeCell;

use super::sanitizer::{self, CellMeta};

/// A cell whose contents may be freely mutated by simulated threads.
///
/// # Safety invariant
/// The conservative scheduler guarantees that **exactly one** simulated
/// thread executes at any host instant, and baton handoffs go through a host
/// `Mutex`+`Condvar` pair, which establishes happens-before edges between
/// consecutive accessors. Under that regime, `&self` access to the interior
/// is data-race-free even though multiple OS threads hold references.
///
/// `SimCell` must therefore only be touched from *running* simulated threads
/// (i.e. between scheduler grants). All users in this crate follow the
/// pattern `sync-point -> mutate -> continue`, where the sync point is a
/// scheduler interaction ([`super::sched::advance`]/lock/queue ops).
///
/// # SimSan
/// Baton order makes a cross-thread plain access *memory-safe*, but not
/// *meaningful*: without a simulated sync edge the interleaving is an
/// artifact of the min-clock rule, i.e. the modeled program has a data
/// race. With the `simsan` feature, [`SimCell::get`] therefore records a
/// last-writer epoch and panics when an access is not ordered after the
/// previous writer by a vector-clock edge (see [`super::sanitizer`]). The
/// simulation primitives themselves (`SimMutex` lock words, event/barrier
/// wait lists) are the *sources* of those edges and use the untracked
/// [`SimCell::get_raw`] instead.
pub struct SimCell<T> {
    inner: UnsafeCell<T>,
    meta: CellMeta,
}

// SAFETY: see type-level invariant above — mutual exclusion and ordering are
// provided externally by the scheduler.
unsafe impl<T: Send> Send for SimCell<T> {}
unsafe impl<T: Send> Sync for SimCell<T> {}

impl<T> SimCell<T> {
    pub fn new(value: T) -> Self {
        SimCell { inner: UnsafeCell::new(value), meta: CellMeta::new() }
    }

    /// Shared view. Caller must be the running simulated thread, and the
    /// access must be ordered after the previous writer by a simulated
    /// sync edge (checked under `simsan`).
    #[allow(clippy::mut_from_ref)]
    #[track_caller]
    pub fn get(&self) -> &mut T {
        sanitizer::cell_access(&self.meta);
        // SAFETY: scheduler-enforced mutual exclusion (see type docs).
        unsafe { &mut *self.inner.get() }
    }

    /// Untracked view for the synchronization primitives' own state, which
    /// is by construction touched only at scheduler interaction points and
    /// *provides* (rather than consumes) happens-before edges.
    #[allow(clippy::mut_from_ref)]
    pub(crate) fn get_raw(&self) -> &mut T {
        // SAFETY: scheduler-enforced mutual exclusion (see type docs).
        unsafe { &mut *self.inner.get() }
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner()
    }
}

impl<T: Default> Default for SimCell<T> {
    fn default() -> Self {
        SimCell::new(T::default())
    }
}
