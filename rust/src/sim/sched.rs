//! The conservative min-clock scheduler ("baton passing").
//!
//! Each simulated thread is an OS thread. A thread may execute simulation
//! code only while its slot is `Running`; exactly one slot is `Running` at a
//! time. Threads accumulate virtual time locally via [`advance`] and
//! synchronize with the scheduler at *interaction points* (lock/queue/event
//! operations, explicit [`yield_now`]): if any other runnable thread has a
//! smaller virtual clock, the baton is handed to the minimum-clock thread.
//! This conservative rule totally orders all shared-state interactions by
//! virtual time (ties broken by thread id), making runs deterministic.

use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

use super::clock::Nanos;
use super::costs::CostModel;

/// Why a simulation run ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimOutcome {
    /// All threads ran to completion.
    Completed,
    /// Every unfinished thread was blocked on a primitive — a true deadlock
    /// (used to demonstrate the paper's Fig. 9 scenarios).
    Deadlock,
    /// Virtual time exceeded the configured limit — a livelock/unbounded
    /// wait (e.g. pure per-VCI progress spinning forever).
    TimeLimit,
    /// A simulated thread panicked with an application error.
    Panicked(String),
}

/// Result of [`Sim::run`].
#[derive(Clone, Debug)]
pub struct SimReport {
    pub outcome: SimOutcome,
    /// Maximum virtual clock reached by any thread.
    pub end_time: Nanos,
    /// Final virtual clock per thread, in spawn order.
    pub thread_clocks: Vec<Nanos>,
    /// Named measurements recorded by threads via [`Sim::record`].
    pub measurements: HashMap<String, f64>,
}

/// Internal abort signal, delivered by unwinding simulated threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimAbort {
    Deadlock,
    TimeLimit,
    Cascade, // another thread aborted first; unwind quietly
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RunState {
    /// Waiting for the baton.
    Runnable,
    /// Holds the baton; executing simulation code.
    Running,
    /// Parked on a primitive (mutex/event); not schedulable until unparked.
    Blocked,
    Finished,
}

struct Slot {
    state: RunState,
    clock: Nanos,
    cv: Arc<Condvar>,
    #[allow(dead_code)]
    name: String,
}

struct Sched {
    slots: Vec<Slot>,
    /// Set when the run must be torn down (deadlock/time limit/panic).
    abort: Option<SimAbort>,
    panic_msg: Option<String>,
    time_limit: Nanos,
    unfinished: usize,
    measurements: HashMap<String, f64>,
}

pub(crate) struct SimCore {
    sched: Mutex<Sched>,
    pub costs: CostModel,
    /// SimSan per-run state (zero-sized unless the `simsan` feature is on).
    pub(crate) san: super::sanitizer::SanCore,
}

thread_local! {
    static CURRENT: std::cell::RefCell<Option<ThreadCtx>> = const { std::cell::RefCell::new(None) };
}

#[derive(Clone)]
struct ThreadCtx {
    core: Arc<SimCore>,
    tid: usize,
    /// Locally accumulated clock; authoritative while Running. Flushed to
    /// the slot at every scheduler interaction.
    clock: std::rc::Rc<std::cell::Cell<Nanos>>,
}

fn with_ctx<R>(f: impl FnOnce(&ThreadCtx) -> R) -> R {
    CURRENT.with(|c| {
        let b = c.borrow();
        let ctx = b
            .as_ref()
            .expect("sim primitive used outside a simulated thread (native backend code path?)");
        f(ctx)
    })
}

/// True when called from inside a simulated thread.
pub fn in_sim() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// Current virtual time of the calling simulated thread.
pub fn now() -> Nanos {
    with_ctx(|ctx| ctx.clock.get())
}

/// Id of the calling simulated thread (spawn order).
pub fn current_tid() -> usize {
    with_ctx(|ctx| ctx.tid)
}

/// Charge `ns` of virtual compute time to the calling thread. Purely local —
/// the scheduler is consulted at the next interaction point.
pub fn advance(ns: Nanos) {
    with_ctx(|ctx| ctx.clock.set(ctx.clock.get() + ns));
}

/// Charge time and release the baton if another thread is now behind us.
/// Poll loops must call this (directly or via primitive ops) to let virtual
/// time interleave.
pub fn yield_now() {
    with_ctx(|ctx| ctx.core.clone().interaction(ctx));
}

impl SimCore {
    /// Interaction point: flush the local clock and run the min-clock rule.
    /// On return the calling thread is `Running` again (possibly after
    /// having lost and regained the baton) and its local clock is valid.
    fn interaction(self: &Arc<Self>, ctx: &ThreadCtx) {
        // A host mutex held here could deadlock the host process the moment
        // the baton moves; SimSan reports it at the yield, deterministically.
        self.san.check_yield(ctx.tid);
        let mut s = self.sched.lock().unwrap_or_else(|e| e.into_inner());
        s.slots[ctx.tid].clock = ctx.clock.get();
        self.check_abort(&s);
        if ctx.clock.get() > s.time_limit {
            self.raise_abort(&mut s, SimAbort::TimeLimit, None);
        }
        // Find the minimum-clock runnable slot (Running counts as runnable).
        if let Some(j) = min_runnable(&s) {
            if j != ctx.tid {
                // Hand the baton over.
                s.slots[ctx.tid].state = RunState::Runnable;
                grant(&mut s, j);
                s = self.wait_for_baton(s, ctx.tid);
            }
        }
        drop(s);
        // Reload clock: an unparker may have advanced it while we waited.
        with_slot_clock(self, ctx);
    }

    /// Park the calling thread (state -> Blocked) after `register` has
    /// queued it on some primitive's wait list. Returns when unparked.
    pub(crate) fn park(self: &Arc<Self>, register: impl FnOnce()) {
        with_ctx(|ctx| {
            debug_assert!(Arc::ptr_eq(&ctx.core, self), "cross-sim primitive use");
            self.san.check_yield(ctx.tid);
            // We still hold the baton: safe to touch primitive state.
            register();
            let mut s = self.sched.lock().unwrap_or_else(|e| e.into_inner());
            s.slots[ctx.tid].clock = ctx.clock.get();
            self.check_abort(&s);
            s.slots[ctx.tid].state = RunState::Blocked;
            match min_runnable(&s) {
                Some(j) => grant(&mut s, j),
                None => {
                    // Everyone is blocked or finished: deadlock.
                    self.raise_abort(&mut s, SimAbort::Deadlock, None);
                }
            }
            let s = self.wait_for_baton(s, ctx.tid);
            drop(s);
            with_slot_clock(self, ctx);
        });
    }

    /// Unpark thread `tid`, advancing its clock to at least `wake_clock`.
    /// Caller keeps the baton; the woken thread becomes Runnable and will be
    /// scheduled by the min-clock rule at the next interaction.
    pub(crate) fn unpark(self: &Arc<Self>, tid: usize, wake_clock: Nanos) {
        // Happens-before: the waker's history is visible to the woken
        // thread (direct mutex handoff, event signal, barrier release).
        self.san.unpark_edge(current_tid(), tid);
        let mut s = self.sched.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert_eq!(s.slots[tid].state, RunState::Blocked, "unpark of non-blocked thread");
        s.slots[tid].clock = s.slots[tid].clock.max(wake_clock);
        s.slots[tid].state = RunState::Runnable;
    }

    fn wait_for_baton<'a>(
        &'a self,
        mut s: std::sync::MutexGuard<'a, Sched>,
        tid: usize,
    ) -> std::sync::MutexGuard<'a, Sched> {
        let cv = s.slots[tid].cv.clone();
        while s.slots[tid].state != RunState::Running {
            if s.abort.is_some() {
                drop(s);
                panic::panic_any(SimAbort::Cascade);
            }
            s = cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        self.check_abort(&s);
        s
    }

    fn check_abort(&self, s: &Sched) {
        if let Some(a) = s.abort {
            panic::panic_any(a);
        }
    }

    /// Mark the run aborted, wake every parked/waiting thread so it can
    /// unwind, and unwind the caller.
    fn raise_abort(&self, s: &mut Sched, abort: SimAbort, msg: Option<String>) -> ! {
        if s.abort.is_none() {
            s.abort = Some(abort);
            s.panic_msg = msg;
        }
        for slot in s.slots.iter_mut() {
            if slot.state != RunState::Finished {
                slot.state = RunState::Running; // let them observe abort
                slot.cv.notify_all();
            }
        }
        panic::panic_any(abort);
    }

    /// Thread termination: release the baton permanently.
    fn finish(self: &Arc<Self>, tid: usize, clock: Nanos, app_panic: Option<String>) {
        let mut s = self.sched.lock().unwrap_or_else(|e| e.into_inner());
        if s.slots[tid].state == RunState::Finished {
            return;
        }
        s.slots[tid].clock = s.slots[tid].clock.max(clock);
        s.slots[tid].state = RunState::Finished;
        s.unfinished -= 1;
        if let Some(msg) = app_panic {
            if s.abort.is_none() {
                s.abort = Some(SimAbort::Cascade);
                s.panic_msg = Some(msg);
            }
            for slot in s.slots.iter_mut() {
                if slot.state != RunState::Finished {
                    slot.state = RunState::Running;
                    slot.cv.notify_all();
                }
            }
            return;
        }
        if s.abort.is_some() {
            return;
        }
        if s.unfinished > 0 {
            match min_runnable(&s) {
                Some(j) => grant(&mut s, j),
                None => {
                    // Remaining threads all blocked -> deadlock.
                    s.abort = Some(SimAbort::Deadlock);
                    for slot in s.slots.iter_mut() {
                        if slot.state != RunState::Finished {
                            slot.state = RunState::Running;
                            slot.cv.notify_all();
                        }
                    }
                }
            }
        }
    }
}

fn min_runnable(s: &Sched) -> Option<usize> {
    s.slots
        .iter()
        .enumerate()
        .filter(|(_, sl)| matches!(sl.state, RunState::Runnable | RunState::Running))
        .min_by_key(|(i, sl)| (sl.clock, *i))
        .map(|(i, _)| i)
}

fn grant(s: &mut Sched, j: usize) {
    if s.slots[j].state != RunState::Running {
        s.slots[j].state = RunState::Running;
        s.slots[j].cv.notify_all();
    }
}

fn with_slot_clock(core: &Arc<SimCore>, ctx: &ThreadCtx) {
    let s = core.sched.lock().unwrap_or_else(|e| e.into_inner());
    ctx.clock.set(s.slots[ctx.tid].clock);
}

/// A simulation instance: build with [`Sim::new`], add threads with
/// [`Sim::spawn_setup`], then [`Sim::run`].
pub struct Sim {
    core: Arc<SimCore>,
    threads: Vec<(String, Box<dyn FnOnce() + Send>)>,
    time_limit: Nanos,
}

impl Sim {
    pub fn new(costs: CostModel) -> Self {
        Sim {
            core: Arc::new(SimCore {
                sched: Mutex::new(Sched {
                    slots: Vec::new(),
                    abort: None,
                    panic_msg: None,
                    time_limit: Nanos::MAX,
                    unfinished: 0,
                    measurements: HashMap::new(),
                }),
                costs,
                san: super::sanitizer::SanCore::new(),
            }),
            threads: Vec::new(),
            time_limit: Nanos::MAX,
        }
    }

    /// Abort the run (outcome `TimeLimit`) if virtual time passes `ns`.
    pub fn set_time_limit(&mut self, ns: Nanos) {
        self.time_limit = ns;
    }

    pub fn costs(&self) -> &CostModel {
        &self.core.costs
    }

    /// Register a simulated thread started at virtual time 0.
    pub fn spawn_setup(&mut self, name: impl Into<String>, f: impl FnOnce() + Send + 'static) {
        self.threads.push((name.into(), Box::new(f)));
    }

    /// Execute the simulation to completion. Consumes the builder.
    pub fn run(self) -> SimReport {
        let Sim { core, threads, time_limit } = self;
        {
            let mut s = core.sched.lock().unwrap_or_else(|e| e.into_inner());
            s.time_limit = time_limit;
            for (name, _) in &threads {
                s.slots.push(Slot {
                    state: RunState::Runnable,
                    clock: 0,
                    cv: Arc::new(Condvar::new()),
                    name: name.clone(),
                });
            }
            s.unfinished = threads.len();
            if !threads.is_empty() {
                s.slots[0].state = RunState::Running;
            }
        }
        core.san.init(core.sched.lock().unwrap_or_else(|e| e.into_inner()).slots.len());
        let mut joins = Vec::new();
        for (tid, (name, f)) in threads.into_iter().enumerate() {
            let core = core.clone();
            let jh = std::thread::Builder::new()
                .name(format!("sim-{name}"))
                .stack_size(1 << 21)
                .spawn(move || {
                    let ctx = ThreadCtx {
                        core: core.clone(),
                        tid,
                        clock: std::rc::Rc::new(std::cell::Cell::new(0)),
                    };
                    CURRENT.with(|c| *c.borrow_mut() = Some(ctx.clone()));
                    // Wait for the initial baton grant.
                    {
                        let s = core.sched.lock().unwrap_or_else(|e| e.into_inner());
                        let s = core.wait_for_baton_entry(s, tid);
                        drop(s);
                        ctx.clock.set({
                            let s = core.sched.lock().unwrap_or_else(|e| e.into_inner());
                            s.slots[tid].clock
                        });
                    }
                    let result = panic::catch_unwind(AssertUnwindSafe(f));
                    let app_panic = match result {
                        Ok(()) => None,
                        Err(e) => {
                            if e.downcast_ref::<SimAbort>().is_some() {
                                None // scheduler-initiated unwind
                            } else if let Some(s) = e.downcast_ref::<&str>() {
                                Some((*s).to_string())
                            } else if let Some(s) = e.downcast_ref::<String>() {
                                Some(s.clone())
                            } else {
                                Some("simulated thread panicked".to_string())
                            }
                        }
                    };
                    let clock = ctx.clock.get();
                    CURRENT.with(|c| *c.borrow_mut() = None);
                    core.finish(tid, clock, app_panic);
                })
                .expect("spawn sim thread");
            joins.push(jh);
        }
        for jh in joins {
            let _ = jh.join();
        }
        let s = core.sched.lock().unwrap_or_else(|e| e.into_inner());
        let outcome = match (&s.abort, &s.panic_msg) {
            (Some(SimAbort::Deadlock), _) => SimOutcome::Deadlock,
            (Some(SimAbort::TimeLimit), _) => SimOutcome::TimeLimit,
            (Some(SimAbort::Cascade), Some(m)) => SimOutcome::Panicked(m.clone()),
            (Some(SimAbort::Cascade), None) => SimOutcome::Panicked("aborted".into()),
            (None, Some(m)) => SimOutcome::Panicked(m.clone()),
            (None, None) => SimOutcome::Completed,
        };
        SimReport {
            outcome,
            end_time: s.slots.iter().map(|sl| sl.clock).max().unwrap_or(0),
            thread_clocks: s.slots.iter().map(|sl| sl.clock).collect(),
            measurements: s.measurements.clone(),
        }
    }
}

impl SimCore {
    fn wait_for_baton_entry<'a>(
        &'a self,
        mut s: std::sync::MutexGuard<'a, Sched>,
        tid: usize,
    ) -> std::sync::MutexGuard<'a, Sched> {
        let cv = s.slots[tid].cv.clone();
        while s.slots[tid].state != RunState::Running {
            if s.abort.is_some() {
                drop(s);
                panic::panic_any(SimAbort::Cascade);
            }
            s = cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        s
    }
}

/// Record a named scalar measurement, retrievable from the [`SimReport`].
pub fn record(name: impl Into<String>, value: f64) {
    with_ctx(|ctx| {
        let mut s = ctx.core.sched.lock().unwrap_or_else(|e| e.into_inner());
        s.measurements.insert(name.into(), value);
    });
}

pub(crate) fn current_core() -> Arc<SimCore> {
    with_ctx(|ctx| ctx.core.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_advances_clock() {
        let mut sim = Sim::new(CostModel::default());
        sim.spawn_setup("t0", || {
            advance(100);
            yield_now();
            advance(50);
            assert_eq!(now(), 150);
        });
        let r = sim.run();
        assert_eq!(r.outcome, SimOutcome::Completed);
        assert_eq!(r.end_time, 150);
    }

    #[test]
    fn min_clock_interleaving_is_deterministic() {
        // Two threads advancing by different steps must interleave by
        // virtual time: the trace of (tid, time) pairs is fixed.
        use std::sync::atomic::{AtomicU64, Ordering};
        let order = Arc::new(AtomicU64::new(0));
        let run = |order: Arc<AtomicU64>| {
            let mut sim = Sim::new(CostModel::default());
            let o1 = order.clone();
            sim.spawn_setup("fast", move || {
                for _ in 0..3 {
                    advance(10);
                    yield_now();
                    o1.fetch_add(1, Ordering::SeqCst);
                }
            });
            let o2 = order;
            sim.spawn_setup("slow", move || {
                advance(25);
                yield_now();
                o2.fetch_add(100, Ordering::SeqCst);
            });
            sim.run()
        };
        let r = run(order.clone());
        assert_eq!(r.outcome, SimOutcome::Completed);
        assert_eq!(r.end_time, 30);
        assert_eq!(order.load(Ordering::SeqCst), 103);
    }

    #[test]
    fn time_limit_reports_livelock() {
        let mut sim = Sim::new(CostModel::default());
        sim.set_time_limit(1_000);
        sim.spawn_setup("spinner", || loop {
            advance(100);
            yield_now();
        });
        let r = sim.run();
        assert_eq!(r.outcome, SimOutcome::TimeLimit);
    }

    #[test]
    fn app_panic_propagates() {
        let mut sim = Sim::new(CostModel::default());
        sim.spawn_setup("bad", || panic!("boom"));
        sim.spawn_setup("other", || {
            for _ in 0..1000 {
                advance(1);
                yield_now();
            }
        });
        let r = sim.run();
        assert!(matches!(r.outcome, SimOutcome::Panicked(ref m) if m.contains("boom")));
    }

    #[test]
    fn measurements_are_returned() {
        let mut sim = Sim::new(CostModel::default());
        sim.spawn_setup("m", || {
            advance(5);
            record("rate", 42.5);
        });
        let r = sim.run();
        assert_eq!(r.measurements.get("rate"), Some(&42.5));
    }
}
