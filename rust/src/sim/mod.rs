//! Deterministic discrete-event simulation (DES) of a multicore node.
//!
//! The paper's testbed is a 16-core socket driving a multi-context NIC; this
//! host exposes a single CPU core, so thread-*scaling* results cannot be
//! reproduced with wallclock threads. Instead, this module provides a
//! conservative virtual-time executor: every simulated hardware thread is a
//! real OS thread running *real* library code (the matching engine, request
//! pools, VCI mapping, ... all execute for real), but
//!
//!   * time is virtual — code charges cycles via [`advance`],
//!   * synchronization primitives ([`SimMutex`], [`SimAtomicU64`]) charge a
//!     calibrated cost model and model contention in virtual time, and
//!   * only the thread with the minimum virtual clock runs at any instant
//!     (a baton-passing conservative scheduler), which makes every run
//!     bit-for-bit deterministic regardless of host parallelism.
//!
//! Throughput results are then `messages / virtual time`, reproducing the
//! *shape* of the paper's figures deterministically.
//!
//! # Memory model
//! Because exactly one simulated thread executes at a time and batons are
//! handed through a host `Mutex`/`Condvar`, all simulated-shared state is
//! totally ordered with proper happens-before edges; [`SimCell`] exploits
//! this to provide zero-cost interior mutability for simulation state.

mod cell;
mod clock;
mod costs;
mod sched;
mod sync;

pub use cell::SimCell;
pub use clock::{Nanos, fmt_ns};
pub use costs::CostModel;
pub use sched::{
    advance, current_tid, in_sim, now, record, yield_now, Sim, SimAbort, SimOutcome, SimReport,
};
pub use sync::{CacheLine, SimAtomicU64, SimBarrier, SimEvent, SimMutex, SimMutexGuard};
