//! Deterministic discrete-event simulation (DES) of a multicore node.
//!
//! The paper's testbed is a 16-core socket driving a multi-context NIC; this
//! host exposes a single CPU core, so thread-*scaling* results cannot be
//! reproduced with wallclock threads. Instead, this module provides a
//! conservative virtual-time executor: every simulated hardware thread is a
//! real OS thread running *real* library code (the matching engine, request
//! pools, VCI mapping, ... all execute for real), but
//!
//!   * time is virtual — code charges cycles via [`advance`],
//!   * synchronization primitives ([`SimMutex`], [`SimAtomicU64`]) charge a
//!     calibrated cost model and model contention in virtual time, and
//!   * only the thread with the minimum virtual clock runs at any instant
//!     (a baton-passing conservative scheduler), which makes every run
//!     bit-for-bit deterministic regardless of host parallelism.
//!
//! Throughput results are then `messages / virtual time`, reproducing the
//! *shape* of the paper's figures deterministically.
//!
//! # Memory model
//!
//! Two layers of ordering exist, and conflating them is the bug class
//! SimSan ([`sanitizer`]) was built to catch:
//!
//! * **Host-level (memory safety).** Exactly one simulated thread executes
//!   at a time and batons are handed through a host `Mutex`/`Condvar`, so
//!   all simulated-shared state is totally ordered with proper host
//!   happens-before edges; [`SimCell`] exploits this to provide zero-cost
//!   interior mutability for simulation state.
//! * **Simulation-level (program meaning).** Baton order is an artifact of
//!   the min-clock rule, *not* a synchronization edge of the modeled
//!   program. Only the simulated primitives create simulated
//!   happens-before: `SimMutex` release → next acquire, `SimEvent` signal
//!   → wait-return, `SimBarrier` arrival → release, `SimAtomicU64`
//!   operations, and scheduler unpark (direct lock handoff). A plain
//!   [`SimCell`] access that is not ordered after the previous writer by
//!   one of those edges is a data race in the modeled program, even though
//!   it is memory-safe on the host.
//!
//! ## Lock hierarchy (enforced by SimSan under `--features simsan`)
//!
//! ```text
//!   rank  10  Global     process-wide critical section (CsMode::Global)
//!   rank  20  Hook       progress-hook registration lock
//!   rank  30  Vci        per-VCI state lock (THE per-lane lock)
//!   rank  40  Request    request-slab free list
//!   rank  50  EpochCtl   wildcard-epoch / engine-retirement control
//!   rank  60  Shard      per-communicator matching shard (multi: may hold
//!                        several, ascending shard index — epoch pattern)
//!   rank 100+ Host*      host std::sync mutexes (instrument::HostMutex):
//!                        leaf-only, never held across a yield/park
//! ```
//!
//! Acquisitions must strictly increase in rank along any nesting chain;
//! host mutexes must be released before any sim lock, yield, or park.
//! SimSan additionally learns the dynamic class-order graph and reports
//! any cycle-closing acquisition with both first-acquisition sites.
//!
//! ## What SimSan does and does not catch
//!
//! It catches: rank/hierarchy inversions and class-order cycles (at the
//! acquisition attempt, before the deadlock manifests), host mutexes held
//! across scheduler interactions, and unsynchronized cross-thread
//! [`SimCell`] access (last-writer epoch vs. vector clock). It does not
//! catch: races on host atomics (`AtomicU64` with relaxed ordering is
//! assumed intentional), ABBA orders that never share a class pair in one
//! run, lost updates through `ModeledCounter` (host-atomic by design), or
//! anything in `Backend::Native` runs — the checker only observes
//! simulated threads.

mod cell;
mod clock;
mod costs;
mod sched;
pub mod sanitizer;
mod sync;

pub use cell::SimCell;
pub use clock::{Nanos, fmt_ns};
pub use costs::CostModel;
pub use sched::{
    advance, current_tid, in_sim, now, record, yield_now, Sim, SimAbort, SimOutcome, SimReport,
};
pub use sync::{CacheLine, SimAtomicU64, SimBarrier, SimEvent, SimMutex, SimMutexGuard};
