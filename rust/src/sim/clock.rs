//! Virtual time. One unit = one nanosecond of simulated wallclock.

/// Virtual nanoseconds.
pub type Nanos = u64;

/// Human-readable formatting of a virtual duration.
pub fn fmt_ns(ns: Nanos) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3}us", ns as f64 / 1e3)
    } else {
        format!("{}ns", ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_scales() {
        assert_eq!(fmt_ns(17), "17ns");
        assert_eq!(fmt_ns(1_500), "1.500us");
        assert_eq!(fmt_ns(2_500_000), "2.500ms");
        assert_eq!(fmt_ns(3_200_000_000), "3.200s");
    }
}
