//! Virtual-time synchronization primitives.
//!
//! These model the *cost* of real primitives (lock fast paths, contended
//! handoffs, atomic RMWs, cache-line migration) while providing real mutual
//! exclusion semantics in virtual time. They are the levers behind the
//! paper's Figures 2, 3, 7, 8 and 12: critical-section granularity, atomic
//! counting overhead, and false sharing all surface through them.

use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

use super::cell::SimCell;
use super::sanitizer::{self, LockTag, SyncClock, TAG_ANON};
use super::sched::{advance, current_core, current_tid, now, yield_now};

/// Models one 64-byte cache line's ownership for false-sharing accounting.
///
/// Whenever a thread touches a line last owned by a different thread, a
/// line-transfer cost is charged. Placing two hot locks on the *same*
/// `CacheLine` reproduces the paper's Fig. 8 false-sharing penalty; giving
/// each its own line models `__attribute__((aligned(64)))`.
pub struct CacheLine {
    last_owner: SimCell<Option<usize>>,
}

impl CacheLine {
    pub fn new() -> Arc<Self> {
        Arc::new(CacheLine { last_owner: SimCell::new(None) })
    }

    /// Charge the calling thread for touching this line.
    pub fn touch(&self) {
        let me = current_tid();
        let owner = self.last_owner.get_raw();
        if *owner != Some(me) {
            let c = current_core();
            advance(c.costs.cacheline_transfer);
            *owner = Some(me);
        }
    }
}

struct MutexState {
    held_by: Option<usize>,
    waiters: VecDeque<usize>,
}

/// A virtual-time mutex.
///
/// Uncontended acquire/release charge the fast-path cost; a contended
/// acquire parks the thread until the holder releases, then charges the
/// handoff cost (futex wake + lock-word migration) — the term that builds
/// the paper's "lock convoy" under a global critical section.
pub struct SimMutex<T> {
    state: SimCell<MutexState>,
    data: SimCell<T>,
    line: Option<Arc<CacheLine>>,
    /// SimSan: vector clock carrying release -> acquire happens-before.
    clock: SyncClock,
}

impl<T: Send> SimMutex<T> {
    pub fn new(data: T) -> Self {
        SimMutex {
            state: SimCell::new(MutexState { held_by: None, waiters: VecDeque::new() }),
            data: SimCell::new(data),
            line: None,
            clock: SyncClock::new(),
        }
    }

    /// Stable identity for SimSan's held-lock bookkeeping.
    fn san_id(&self) -> usize {
        &self.state as *const _ as usize
    }

    /// Place this mutex's lock word on an explicit cache line (for
    /// false-sharing experiments). Without this, the lock word is assumed
    /// exclusively-owned (perfectly aligned).
    pub fn on_line(mut self, line: Arc<CacheLine>) -> Self {
        self.line = Some(line);
        self
    }

    pub fn lock(&self) -> SimMutexGuard<'_, T> {
        self.lock_tagged(&TAG_ANON, 0)
    }

    /// Classed acquisition: SimSan checks `tag` against the held-lock
    /// stack and the lock-order graph *before* any park (so a latent
    /// deadlock is reported at the acquisition attempt, deterministically).
    #[track_caller]
    pub fn lock_tagged(&self, tag: &'static LockTag, ordinal: u32) -> SimMutexGuard<'_, T> {
        let core = current_core();
        let me = current_tid();
        sanitizer::lock_attempt(tag, self.san_id(), ordinal);
        yield_now(); // ordering point for this interaction
        if let Some(line) = &self.line {
            line.touch();
        }
        advance(core.costs.lock_acquire);
        // Convoy semantics: once a lock has waiters, ownership is handed
        // through the queue (each transfer pays FUTEX_WAKE on the releaser
        // and wake-up latency on the waiter). This is the regime a
        // contended global critical section degrades into — the 10-100x
        // collapse of paper Figs. 3/10.
        let st = self.state.get_raw();
        debug_assert_ne!(st.held_by, Some(me), "recursive SimMutex lock");
        if st.held_by.is_none() && st.waiters.is_empty() {
            st.held_by = Some(me);
        } else {
            st.waiters.push_back(me);
            core.park(|| {});
            // Woken by the releaser, which transferred ownership to us.
            debug_assert_eq!(self.state.get_raw().held_by, Some(me));
        }
        sanitizer::vc_acquire(&self.clock);
        SimMutexGuard { mutex: self }
    }

    /// Non-blocking acquire.
    pub fn try_lock(&self) -> Option<SimMutexGuard<'_, T>> {
        self.try_lock_tagged(&TAG_ANON)
    }

    /// Non-blocking classed acquire. Cannot deadlock, so it is exempt from
    /// SimSan's ordering checks, but the hold is still tracked.
    #[track_caller]
    pub fn try_lock_tagged(&self, tag: &'static LockTag) -> Option<SimMutexGuard<'_, T>> {
        let core = current_core();
        let me = current_tid();
        yield_now();
        if let Some(line) = &self.line {
            line.touch();
        }
        advance(core.costs.lock_acquire);
        let st = self.state.get_raw();
        if st.held_by.is_none() {
            st.held_by = Some(me);
            sanitizer::lock_attempt_try(tag, self.san_id());
            sanitizer::vc_acquire(&self.clock);
            Some(SimMutexGuard { mutex: self })
        } else {
            None
        }
    }

    fn unlock(&self) {
        let core = current_core();
        advance(core.costs.lock_release);
        // Release edge before ownership can move to a waiter.
        sanitizer::vc_release(&self.clock);
        yield_now();
        let st = self.state.get_raw();
        debug_assert_eq!(st.held_by, Some(current_tid()));
        if let Some(next) = st.waiters.pop_front() {
            // FUTEX_WAKE: the releaser pays the syscall + line migration;
            // the waiter additionally pays its wake-up latency. Ownership
            // transfers directly (queue fairness — the convoy regime).
            st.held_by = Some(next);
            advance(core.costs.lock_wake);
            core.unpark(next, now() + core.costs.lock_handoff);
        } else {
            st.held_by = None;
        }
        sanitizer::lock_released(self.san_id());
    }
}

pub struct SimMutexGuard<'a, T: Send> {
    mutex: &'a SimMutex<T>,
}

impl<T: Send> Deref for SimMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.mutex.data.get()
    }
}

impl<T: Send> DerefMut for SimMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.mutex.data.get()
    }
}

impl<T: Send> Drop for SimMutexGuard<'_, T> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // Unwinding (possibly a scheduler-initiated abort): the run is
            // being torn down; skip scheduler interaction entirely — a
            // panic inside drop would abort the whole process.
            return;
        }
        self.mutex.unlock();
    }
}

/// A virtual-time atomic counter. Every RMW charges the atomic cost plus a
/// cache-line transfer when the previous toucher was a different thread —
/// the "atomics for reference and completion counters" overhead of the
/// paper's fine-grained mode (§4.1, Fig. 12).
pub struct SimAtomicU64 {
    v: SimCell<u64>,
    owner: SimCell<Option<usize>>,
    /// SimSan per-op vector-clock tracking: loads are acquire edges, RMWs
    /// are full fences (they read *and* publish), but plain stores are
    /// **release-only**. A store used to be a full fence too, which let
    /// an unrelated atomic launder app-level races: thread A's
    /// store(flag) would *acquire* B's entire history through the shared
    /// clock, manufacturing happens-before edges no real release store
    /// provides. Message-passing (store-release → load-acquire → read
    /// payload) still synchronizes; two racing store+read-plain-cell
    /// threads no longer do — the checker now sees that race.
    clock: SyncClock,
}

impl SimAtomicU64 {
    pub fn new(v: u64) -> Self {
        SimAtomicU64 {
            v: SimCell::new(v),
            owner: SimCell::new(None),
            clock: SyncClock::new(),
        }
    }

    fn charge(&self, rmw: bool) {
        let core = current_core();
        let me = current_tid();
        let owner = self.owner.get_raw();
        if *owner != Some(me) {
            advance(core.costs.cacheline_transfer);
            *owner = Some(me);
        }
        if rmw {
            advance(core.costs.atomic_rmw);
        }
    }

    pub fn load(&self) -> u64 {
        yield_now();
        self.charge(false);
        sanitizer::vc_acquire(&self.clock);
        *self.v.get_raw()
    }

    pub fn store(&self, v: u64) {
        yield_now();
        self.charge(true);
        // Release-only: the storer publishes its history but must NOT
        // acquire prior touchers' histories (see the `clock` field doc).
        sanitizer::vc_release(&self.clock);
        *self.v.get_raw() = v;
    }

    pub fn fetch_add(&self, d: u64) -> u64 {
        yield_now();
        self.charge(true);
        sanitizer::vc_fence(&self.clock);
        let p = self.v.get_raw();
        let old = *p;
        *p = old.wrapping_add(d);
        old
    }

    pub fn fetch_sub(&self, d: u64) -> u64 {
        yield_now();
        self.charge(true);
        sanitizer::vc_fence(&self.clock);
        let p = self.v.get_raw();
        let old = *p;
        *p = old.wrapping_sub(d);
        old
    }
}

/// A one-shot / resettable event: threads park until signaled.
pub struct SimEvent {
    state: SimCell<EventState>,
    /// SimSan: signal -> wait-return happens-before.
    clock: SyncClock,
}

struct EventState {
    signaled: bool,
    waiters: Vec<usize>,
}

impl SimEvent {
    pub fn new() -> Self {
        SimEvent {
            state: SimCell::new(EventState { signaled: false, waiters: Vec::new() }),
            clock: SyncClock::new(),
        }
    }

    pub fn wait(&self) {
        let core = current_core();
        yield_now();
        let st = self.state.get_raw();
        if st.signaled {
            sanitizer::vc_acquire(&self.clock);
            return;
        }
        let me = current_tid();
        st.waiters.push(me);
        core.park(|| {});
        sanitizer::vc_acquire(&self.clock);
    }

    pub fn signal(&self) {
        let core = current_core();
        yield_now();
        sanitizer::vc_release(&self.clock);
        let st = self.state.get_raw();
        st.signaled = true;
        let t = now();
        for w in st.waiters.drain(..) {
            core.unpark(w, t);
        }
    }

    pub fn is_signaled(&self) -> bool {
        yield_now();
        let signaled = self.state.get_raw().signaled;
        if signaled {
            sanitizer::vc_acquire(&self.clock);
        }
        signaled
    }

    pub fn reset(&self) {
        yield_now();
        self.state.get_raw().signaled = false;
    }
}

impl Default for SimEvent {
    fn default() -> Self {
        Self::new()
    }
}

/// A reusable n-party barrier (models `#pragma omp barrier`).
pub struct SimBarrier {
    state: SimCell<BarrierState>,
    parties: usize,
    /// SimSan: all pre-barrier work happens-before all post-barrier work.
    /// The clock persists across generations (conservatively safe).
    clock: SyncClock,
}

struct BarrierState {
    arrived: usize,
    waiters: Vec<usize>,
}

impl SimBarrier {
    pub fn new(parties: usize) -> Self {
        assert!(parties > 0);
        SimBarrier {
            state: SimCell::new(BarrierState { arrived: 0, waiters: Vec::new() }),
            parties,
            clock: SyncClock::new(),
        }
    }

    /// Block until all parties arrive. The last arriver releases everyone
    /// at its (maximal) clock — barrier semantics in virtual time.
    pub fn wait(&self) {
        let core = current_core();
        yield_now();
        advance(core.costs.atomic_rmw); // barrier arrival counter
        sanitizer::vc_release(&self.clock); // arrival: publish my history
        let st = self.state.get_raw();
        st.arrived += 1;
        if st.arrived == self.parties {
            st.arrived = 0;
            // Last arriver: absorb everyone's history before waking them,
            // so the unpark edge carries the full pre-barrier state.
            sanitizer::vc_acquire(&self.clock);
            let t = now();
            for w in st.waiters.drain(..) {
                core.unpark(w, t);
            }
        } else {
            st.waiters.push(current_tid());
            core.park(|| {});
            sanitizer::vc_acquire(&self.clock);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CostModel, Sim, SimOutcome};
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn mutex_provides_mutual_exclusion_and_charges_time() {
        let m = Arc::new(SimMutex::new(0u64));
        let mut sim = Sim::new(CostModel::default());
        for _ in 0..4 {
            let m = m.clone();
            sim.spawn_setup("worker", move || {
                for _ in 0..100 {
                    let mut g = m.lock();
                    *g += 1;
                    advance(10);
                    drop(g);
                }
            });
        }
        let r = sim.run();
        assert_eq!(r.outcome, SimOutcome::Completed);
        // 400 total increments.
        let m = Arc::try_unwrap(m).ok().expect("sole owner");
        assert_eq!(m.data.into_inner(), 400);
        // Virtual time must reflect serialization: 400 * (hold + lock costs).
        assert!(r.end_time >= 400 * 10);
    }

    #[test]
    fn contended_lock_costs_more_than_uncontended() {
        let run = |threads: usize| -> u64 {
            let m = Arc::new(SimMutex::new(()));
            let mut sim = Sim::new(CostModel::default());
            let per_thread = 2000 / threads;
            for _ in 0..threads {
                let m = m.clone();
                sim.spawn_setup("w", move || {
                    for _ in 0..per_thread {
                        let g = m.lock();
                        advance(50);
                        drop(g);
                    }
                });
            }
            sim.run().end_time
        };
        let uncontended = run(1);
        let contended = run(8);
        // Same total critical work, but contention adds handoff latency.
        assert!(
            contended > uncontended,
            "contended={contended} uncontended={uncontended}"
        );
    }

    #[test]
    fn barrier_releases_all_at_max_clock() {
        let b = Arc::new(SimBarrier::new(3));
        let after = Arc::new(AtomicU64::new(0));
        let mut sim = Sim::new(CostModel::default());
        for i in 0..3u64 {
            let b = b.clone();
            let after = after.clone();
            sim.spawn_setup("p", move || {
                advance(100 * (i + 1));
                b.wait();
                // All must resume at >= 300 (slowest party).
                assert!(crate::sim::now() >= 300);
                after.fetch_add(1, Ordering::SeqCst);
            });
        }
        let r = sim.run();
        assert_eq!(r.outcome, SimOutcome::Completed);
        assert_eq!(after.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn event_wakes_waiters() {
        let e = Arc::new(SimEvent::new());
        let mut sim = Sim::new(CostModel::default());
        let e1 = e.clone();
        sim.spawn_setup("waiter", move || {
            e1.wait();
            assert!(crate::sim::now() >= 500);
        });
        let e2 = e.clone();
        sim.spawn_setup("signaler", move || {
            advance(500);
            e2.signal();
        });
        let r = sim.run();
        assert_eq!(r.outcome, SimOutcome::Completed);
    }

    #[test]
    fn false_sharing_costs_show_up() {
        // Two threads hammering two locks on the SAME line vs separate lines.
        let run = |shared: bool| -> u64 {
            let line = CacheLine::new();
            let m1 = Arc::new(if shared {
                SimMutex::new(()).on_line(line.clone())
            } else {
                SimMutex::new(()).on_line(CacheLine::new())
            });
            let m2 = Arc::new(if shared {
                SimMutex::new(()).on_line(line)
            } else {
                SimMutex::new(()).on_line(CacheLine::new())
            });
            let mut sim = Sim::new(CostModel::default());
            for m in [m1, m2] {
                sim.spawn_setup("t", move || {
                    for _ in 0..500 {
                        let g = m.lock();
                        advance(20);
                        drop(g);
                    }
                });
            }
            sim.run().end_time
        };
        let same_line = run(true);
        let own_lines = run(false);
        assert!(same_line > own_lines, "same={same_line} own={own_lines}");
    }

    #[test]
    fn atomic_counter_is_coherent() {
        let a = Arc::new(SimAtomicU64::new(0));
        let mut sim = Sim::new(CostModel::default());
        for _ in 0..4 {
            let a = a.clone();
            sim.spawn_setup("inc", move || {
                for _ in 0..250 {
                    a.fetch_add(1);
                    advance(5);
                }
            });
        }
        let r = sim.run();
        assert_eq!(r.outcome, SimOutcome::Completed);
        // Read back on a fresh single-thread sim.
        let a2 = a.clone();
        let mut sim2 = Sim::new(CostModel::default());
        sim2.spawn_setup("check", move || {
            assert_eq!(a2.load(), 1000);
        });
        assert_eq!(sim2.run().outcome, SimOutcome::Completed);
    }
}
