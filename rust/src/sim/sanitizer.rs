//! SimSan — a deterministic lock-order + happens-before sanitizer for the
//! DES.
//!
//! The conservative baton-passing scheduler makes every run bit-for-bit
//! deterministic, which turns the classic dynamic-analysis trade-off on its
//! head: a ThreadSanitizer-equivalent built *into* the simulation's own
//! synchronization layer has zero false-positive flakiness — a reported
//! violation reproduces on every run with the same seed. SimSan checks two
//! contracts:
//!
//! 1. **Lock order.** Every classed acquisition ([`LockTag`], see
//!    `mpi::instrument::tag_of`) is pushed onto a per-simulated-thread
//!    held-lock stack and checked against (a) the declared rank hierarchy
//!    (host table → VCI → shard leaf; equal-rank re-acquisition only for
//!    `multi` classes in ascending ordinal order — the all-shard epoch
//!    pattern) and (b) a per-run lock-order graph whose edges carry the two
//!    first-acquisition sites; an acquisition that closes a cycle panics
//!    with both sites. Host (`std::sync`) mutexes additionally must never
//!    be held across a scheduler interaction: a parked holder would
//!    deadlock the *host* process, invisibly to virtual time.
//! 2. **Happens-before.** Each simulated thread carries a vector clock,
//!    advanced at the DES sync points (`SimMutex` release/acquire,
//!    `SimEvent` signal/wait, `SimBarrier`, `SimAtomicU64` ops, scheduler
//!    unpark). Plain [`super::SimCell`] accesses record a last-writer epoch;
//!    a cross-thread access not ordered after the last write by one of
//!    those edges is reported as a data race instead of silently resolving
//!    in baton-pass order.
//!
//! Everything here is feature-gated (`simsan`, a default feature): with
//! the feature off, every hook is a no-op and [`SyncClock`]/[`CellMeta`]
//! are zero-sized, so release benches pay nothing. Violations are raised
//! as ordinary `panic!(String)`s so a simulated run surfaces them as
//! `SimOutcome::Panicked("SimSan: ...")` — deterministic and assertable.

#![allow(dead_code)]

/// Static identity + ordering contract of a lock class.
///
/// Instances are `'static` (see `mpi::instrument::tag_of`); identity is by
/// reference address.
pub struct LockTag {
    pub name: &'static str,
    /// Position in the declared hierarchy; strictly increasing along any
    /// legal nesting chain (host table → VCI → shard leaf).
    pub rank: u32,
    /// Participates in rank/cycle checking. `false` for [`TAG_ANON`]:
    /// unclassed locks (sim unit tests, scratch users) are still tracked
    /// for the host-across-park check but impose no ordering constraints.
    pub ordered: bool,
    /// Several instances of this class may be held at once, provided they
    /// are acquired in ascending `ordinal` order (the stop-the-world
    /// all-shard pattern of `mpi::shard`).
    pub multi: bool,
    /// A host `std::sync` mutex. Must be leaf-only in practice and must
    /// never be held across a scheduler interaction (yield/park): the DES
    /// runs one OS thread at a time, so a baton handoff with a host lock
    /// held can deadlock the host process.
    pub host: bool,
}

/// The unclassed tag used by plain `SimMutex::lock()` /`PMutex::lock()`.
pub static TAG_ANON: LockTag =
    LockTag { name: "anon", rank: 0, ordered: false, multi: false, host: false };

// ---------------------------------------------------------------------------
// Per-object state carried by primitives (zero-sized with the feature off)
// ---------------------------------------------------------------------------

/// Vector clock attached to a synchronization object (mutex, event,
/// barrier, atomic). Mutated only by the running simulated thread.
pub struct SyncClock {
    #[cfg(feature = "simsan")]
    inner: std::cell::UnsafeCell<(usize, Vec<u64>)>, // (run id, clock)
}

// SAFETY: accessed only under the scheduler baton (one running thread),
// with happens-before edges provided by the baton's host mutex.
unsafe impl Send for SyncClock {}
unsafe impl Sync for SyncClock {}

impl SyncClock {
    pub const fn new() -> Self {
        SyncClock {
            #[cfg(feature = "simsan")]
            inner: std::cell::UnsafeCell::new((0, Vec::new())),
        }
    }
}

impl Default for SyncClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Last-writer epoch attached to a [`super::SimCell`].
pub struct CellMeta {
    #[cfg(feature = "simsan")]
    last: std::cell::UnsafeCell<Option<imp::LastWrite>>,
}

// SAFETY: as for `SyncClock`.
unsafe impl Send for CellMeta {}
unsafe impl Sync for CellMeta {}

impl CellMeta {
    pub const fn new() -> Self {
        CellMeta {
            #[cfg(feature = "simsan")]
            last: std::cell::UnsafeCell::new(None),
        }
    }
}

impl Default for CellMeta {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Feature-on implementation
// ---------------------------------------------------------------------------

#[cfg(feature = "simsan")]
mod imp {
    use std::cell::UnsafeCell;
    use std::collections::HashMap;
    use std::panic::Location;
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::super::sched::{current_core, current_tid, in_sim};
    use super::{CellMeta, LockTag, SyncClock};

    /// Distinguishes sequential `Sim` runs that share primitives (a mutex
    /// in an `Arc` reused by a follow-up verification run): epochs from a
    /// finished run must not alias a new run's thread ids.
    static NEXT_RUN: AtomicUsize = AtomicUsize::new(1);

    #[derive(Clone, Copy)]
    pub(super) struct LastWrite {
        run: usize,
        tid: usize,
        clock: u64,
        site: &'static Location<'static>,
    }

    #[derive(Clone, Copy)]
    struct Held {
        tag: &'static LockTag,
        id: usize,
        ordinal: u32,
        site: &'static Location<'static>,
        /// Acquired via `try_lock`: cannot block, so it is exempt from
        /// rank/cycle checking (both as acquirer and as held constraint),
        /// but still release-tracked and host-park-checked.
        exempt: bool,
    }

    struct ThreadSan {
        vc: Vec<u64>,
        held: Vec<Held>,
    }

    struct EdgeInfo {
        held_site: &'static Location<'static>,
        acq_site: &'static Location<'static>,
    }

    struct SanState {
        run: usize,
        threads: Vec<ThreadSan>,
        /// First-observed acquisition order between lock classes, with the
        /// two sites that established each edge.
        edges: HashMap<(&'static str, &'static str), EdgeInfo>,
        adj: HashMap<&'static str, Vec<&'static str>>,
    }

    /// Per-`Sim` sanitizer state. Lives in `SimCore`; every access happens
    /// on the thread currently holding the baton.
    pub struct SanCore {
        state: UnsafeCell<SanState>,
    }

    // SAFETY: scheduler-enforced mutual exclusion plus baton-handoff
    // happens-before, exactly as for `SimCell`.
    unsafe impl Send for SanCore {}
    unsafe impl Sync for SanCore {}

    impl SanCore {
        pub fn new() -> Self {
            SanCore {
                state: UnsafeCell::new(SanState {
                    run: 0,
                    threads: Vec::new(),
                    edges: HashMap::new(),
                    adj: HashMap::new(),
                }),
            }
        }

        /// Called once from `Sim::run` before any thread starts.
        pub(crate) fn init(&self, n_threads: usize) {
            let s = unsafe { &mut *self.state.get() };
            s.run = NEXT_RUN.fetch_add(1, Ordering::Relaxed);
            s.threads = (0..n_threads)
                .map(|i| {
                    let mut vc = vec![0u64; n_threads];
                    vc[i] = 1; // first epoch must be nonzero
                    ThreadSan { vc, held: Vec::new() }
                })
                .collect();
        }

        /// Host-lock-across-park check, run at every scheduler interaction
        /// *before* the baton can move.
        pub(crate) fn check_yield(&self, tid: usize) {
            let s = unsafe { &mut *self.state.get() };
            if let Some(h) = s.threads[tid].held.iter().find(|h| h.tag.host) {
                panic!(
                    "SimSan: host lock '{}' (acquired at {}) held across a scheduler \
                     interaction; a parked holder would deadlock the host process — \
                     release host mutexes before any sim lock/yield/park",
                    h.tag.name, h.site
                );
            }
        }

        /// Happens-before edge from the unparking thread to the woken one.
        pub(crate) fn unpark_edge(&self, from: usize, to: usize) {
            let s = unsafe { &mut *self.state.get() };
            if from == to || s.threads.is_empty() {
                return;
            }
            let src = s.threads[from].vc.clone();
            join(&mut s.threads[to].vc, &src);
        }
    }

    fn with_state<R>(f: impl FnOnce(&mut SanState, usize) -> R) -> Option<R> {
        if !in_sim() {
            return None;
        }
        let core = current_core();
        let me = current_tid();
        let s = unsafe { &mut *core.san.state.get() };
        if s.threads.is_empty() {
            return None; // primitive used outside a sanitized run
        }
        Some(f(s, me))
    }

    fn join(dst: &mut Vec<u64>, src: &[u64]) {
        if dst.len() < src.len() {
            dst.resize(src.len(), 0);
        }
        for (d, s) in dst.iter_mut().zip(src.iter()) {
            *d = (*d).max(*s);
        }
    }

    /// DFS: is `to` reachable from `from` through recorded edges?
    /// Returns the path (class names) if so.
    fn path(s: &SanState, from: &'static str, to: &'static str) -> Option<Vec<&'static str>> {
        let mut stack = vec![(from, vec![from])];
        let mut seen = std::collections::HashSet::new();
        while let Some((n, p)) = stack.pop() {
            if n == to {
                return Some(p);
            }
            if !seen.insert(n) {
                continue;
            }
            if let Some(next) = s.adj.get(n) {
                for &m in next {
                    let mut p2 = p.clone();
                    p2.push(m);
                    stack.push((m, p2));
                }
            }
        }
        None
    }

    fn on_attempt(
        s: &mut SanState,
        me: usize,
        tag: &'static LockTag,
        id: usize,
        ordinal: u32,
        site: &'static Location<'static>,
        exempt: bool,
    ) {
        if tag.ordered && !exempt {
            let held: Vec<Held> = s.threads[me].held.clone();
            for h in held.iter().filter(|h| !h.exempt && h.tag.ordered) {
                if h.id == id {
                    panic!(
                        "SimSan: recursive acquisition of lock '{}' at {} (first acquired \
                         at {})",
                        tag.name, site, h.site
                    );
                }
                let same_class = std::ptr::eq(h.tag, tag);
                let legal = tag.rank > h.tag.rank
                    || (same_class && tag.multi && ordinal > h.ordinal);
                if !legal {
                    panic!(
                        "SimSan: lock-order violation: acquiring '{}' (rank {}, ordinal \
                         {}) at {} while holding '{}' (rank {}, ordinal {}) acquired at \
                         {}; the declared hierarchy is host table -> VCI -> shard leaf \
                         with strictly increasing ranks",
                        tag.name, tag.rank, ordinal, site, h.tag.name, h.tag.rank,
                        h.ordinal, h.site
                    );
                }
                // Record the class-order edge; a new edge that closes a
                // cycle is a latent deadlock even if ranks were misdeclared.
                if !same_class && !s.edges.contains_key(&(h.tag.name, tag.name)) {
                    if let Some(p) = path(s, tag.name, h.tag.name) {
                        let back = s
                            .edges
                            .get(&(p[0], p[1]))
                            .map(|e| format!(" (reverse order first seen held at {}, acquired at {})", e.held_site, e.acq_site))
                            .unwrap_or_default();
                        panic!(
                            "SimSan: lock-order cycle: acquiring '{}' at {} while \
                             holding '{}' (acquired at {}) contradicts the established \
                             order {}{}",
                            tag.name,
                            site,
                            h.tag.name,
                            h.site,
                            p.join(" -> "),
                            back
                        );
                    }
                    s.edges.insert(
                        (h.tag.name, tag.name),
                        EdgeInfo { held_site: h.site, acq_site: site },
                    );
                    s.adj.entry(h.tag.name).or_default().push(tag.name);
                }
            }
        }
        s.threads[me].held.push(Held { tag, id, ordinal, site, exempt });
    }

    #[track_caller]
    pub fn lock_attempt(tag: &'static LockTag, id: usize, ordinal: u32) {
        let site = Location::caller();
        with_state(|s, me| on_attempt(s, me, tag, id, ordinal, site, false));
    }

    /// `try_lock` success: bookkeeping only, exempt from ordering checks.
    #[track_caller]
    pub fn lock_attempt_try(tag: &'static LockTag, id: usize) {
        let site = Location::caller();
        with_state(|s, me| on_attempt(s, me, tag, id, 0, site, true));
    }

    pub fn lock_released(id: usize) {
        with_state(|s, me| {
            let held = &mut s.threads[me].held;
            if let Some(i) = held.iter().rposition(|h| h.id == id) {
                held.remove(i);
            }
        });
    }

    fn obj_clock<'a>(s: &SanState, obj: &'a SyncClock) -> &'a mut Vec<u64> {
        // SAFETY: baton-holder exclusivity, as everywhere in this module.
        let slot = unsafe { &mut *obj.inner.get() };
        if slot.0 != s.run {
            // Object last used by a previous (finished) run: stale epochs.
            slot.0 = s.run;
            slot.1.clear();
        }
        &mut slot.1
    }

    /// Acquire edge: the object's history happens-before me.
    pub fn vc_acquire(obj: &SyncClock) {
        with_state(|s, me| {
            let oc = obj_clock(s, obj).clone();
            join(&mut s.threads[me].vc, &oc);
        });
    }

    /// Release edge: my history happens-before the next acquirer; bump my
    /// epoch so later work is not retroactively ordered.
    pub fn vc_release(obj: &SyncClock) {
        with_state(|s, me| {
            let vc = s.threads[me].vc.clone();
            join(obj_clock(s, obj), &vc);
            s.threads[me].vc[me] += 1;
        });
    }

    /// Full fence (atomic RMW): release + acquire.
    pub fn vc_fence(obj: &SyncClock) {
        with_state(|s, me| {
            let vc = s.threads[me].vc.clone();
            let oc = obj_clock(s, obj);
            join(oc, &vc);
            let oc = oc.clone();
            join(&mut s.threads[me].vc, &oc);
            s.threads[me].vc[me] += 1;
        });
    }

    /// A plain `SimCell` access (treated as a write — `get` hands out
    /// `&mut`). Race iff the last writer is a different thread and its
    /// write epoch is not covered by my vector clock.
    #[track_caller]
    pub fn cell_access(meta: &CellMeta) {
        let site = Location::caller();
        with_state(|s, me| {
            // SAFETY: baton-holder exclusivity.
            let last = unsafe { &mut *meta.last.get() };
            if let Some(lw) = *last {
                if lw.run == s.run && lw.tid != me {
                    let seen = s.threads[me].vc.get(lw.tid).copied().unwrap_or(0);
                    if lw.clock > seen {
                        panic!(
                            "SimSan: data race on SimCell: thread {} wrote at {} \
                             (epoch {}) with no happens-before edge to thread {}'s \
                             access at {} (vc[{}] = {}); synchronize via \
                             SimMutex/SimEvent/SimBarrier/SimAtomicU64 — baton order \
                             alone is not an HB edge",
                            lw.tid, lw.site, lw.clock, me, site, lw.tid, seen
                        );
                    }
                }
            }
            *last = Some(LastWrite {
                run: s.run,
                tid: me,
                clock: s.threads[me].vc[me],
                site,
            });
        });
    }
}

#[cfg(feature = "simsan")]
pub use imp::SanCore;
#[cfg(feature = "simsan")]
pub(crate) use imp::{
    cell_access, lock_attempt, lock_attempt_try, lock_released, vc_acquire, vc_fence,
    vc_release,
};

// ---------------------------------------------------------------------------
// Feature-off stubs (everything inlines to nothing)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "simsan"))]
mod noop {
    use super::{CellMeta, LockTag, SyncClock};

    pub struct SanCore;

    impl SanCore {
        pub fn new() -> Self {
            SanCore
        }
        pub(crate) fn init(&self, _n: usize) {}
        pub(crate) fn check_yield(&self, _tid: usize) {}
        pub(crate) fn unpark_edge(&self, _from: usize, _to: usize) {}
    }

    #[inline(always)]
    pub fn lock_attempt(_tag: &'static LockTag, _id: usize, _ordinal: u32) {}
    #[inline(always)]
    pub fn lock_attempt_try(_tag: &'static LockTag, _id: usize) {}
    #[inline(always)]
    pub fn lock_released(_id: usize) {}
    #[inline(always)]
    pub fn vc_acquire(_obj: &SyncClock) {}
    #[inline(always)]
    pub fn vc_release(_obj: &SyncClock) {}
    #[inline(always)]
    pub fn vc_fence(_obj: &SyncClock) {}
    #[inline(always)]
    pub fn cell_access(_meta: &CellMeta) {}
}

#[cfg(not(feature = "simsan"))]
pub use noop::SanCore;
#[cfg(not(feature = "simsan"))]
pub(crate) use noop::{
    cell_access, lock_attempt, lock_attempt_try, lock_released, vc_acquire, vc_fence,
    vc_release,
};
