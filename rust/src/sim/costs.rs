//! Calibrated virtual-time cost model for the simulated testbed.
//!
//! All values are virtual nanoseconds. Defaults are calibrated so that the
//! *ratios* the paper reports reproduce (see DESIGN.md §2 and
//! EXPERIMENTS.md): e.g. an uncontended fine-grained path is ~15-20% more
//! expensive than a global-lock path for small sends (Fig 2), while a
//! contended global lock costs the better part of a microsecond per
//! handoff (lock convoy + cache-line bouncing), which is what yields the
//! paper's ~94x gap between the optimized multi-VCI library and the
//! single-VCI global-lock baseline at 16 threads (§4.3).

use super::clock::Nanos;

/// Cost model for CPU-side primitives and the simulated NIC.
#[derive(Clone, Debug)]
pub struct CostModel {
    // ---- CPU primitives ----
    /// Uncontended mutex acquire (fast path CAS).
    pub lock_acquire: Nanos,
    /// Uncontended mutex release.
    pub lock_release: Nanos,
    /// Extra latency charged to a waiter when a contended lock is handed
    /// over (futex wake + scheduler + cache-line migration of the lock word
    /// and the data it protects).
    pub lock_handoff: Nanos,
    /// Cost charged to the RELEASER when it must wake a waiter
    /// (FUTEX_WAKE syscall + cache-line migration). Under sustained
    /// contention every release pays this — the dominant term of the
    /// "lock convoy" the paper blames for the 100x MPI+threads slowdown.
    pub lock_wake: Nanos,
    /// A single atomic read-modify-write on a cache-resident line.
    pub atomic_rmw: Nanos,
    /// Migrating a cache line between cores (false sharing, contended
    /// counters). Charged whenever a line's last owner differs.
    pub cacheline_transfer: Nanos,
    /// Plain function-call / bookkeeping overhead charged per instruction
    /// batch; used to price small fixed instruction counts such as the
    /// paper's "8 additional instructions" for the comm->VCI lookup.
    pub ns_per_instruction_batch: Nanos,

    // ---- MPI software path ----
    /// Base software cost of an MPI two-sided initiation (argument checks,
    /// header build, descriptor setup) excluding locks/atomics/NIC.
    pub mpi_sw_send: Nanos,
    /// Base software cost of posting a receive.
    pub mpi_sw_recv: Nanos,
    /// Base software cost of an RMA initiation (put/get/acc).
    pub mpi_sw_rma: Nanos,
    /// Matching-engine cost: walking/inserting posted & unexpected queues.
    pub match_cost: Nanos,
    /// Allocating/freeing a request from the global pool (excluding the
    /// pool lock itself).
    pub request_pool_op: Nanos,
    /// Allocating/freeing a request from a per-VCI cache (lock already
    /// held; just a pointer pop/push).
    pub request_cache_op: Nanos,
    /// One iteration of the progress engine polling an *empty* completion
    /// queue.
    pub poll_empty: Nanos,
    /// Consulting the pool-wide rx-doorbell bitmask and finding no bit
    /// rung (one cache-hot load; the poll that never happened).
    pub doorbell_check: Nanos,
    /// Checking one progress hook for activeness (MPICH/CH4 has two).
    pub progress_hook_check: Nanos,
    /// Completion processing for one CQ entry (request state update).
    pub completion_process: Nanos,

    // ---- NIC / fabric ----
    /// Writing a descriptor + doorbell to a hardware context (per message).
    pub nic_inject: Nanos,
    /// Per-KiB DMA/serialization cost on the TX side (link bandwidth).
    /// 80 ns/KiB ~= 12.8 GB/s, in the 100 Gb/s class of OPA/EDR.
    pub nic_dma_per_kib: Nanos,
    /// One-way wire + switch latency.
    pub wire_latency: Nanos,
    /// Intranode (shared-memory) per-message software cost — the shmmod
    /// path used for same-node ranks in MPI everywhere.
    pub shm_inject: Nanos,
    /// Intranode delivery latency.
    pub shm_latency: Nanos,
    /// RX-side delivery of one message into a context's queue.
    pub nic_rx_deliver: Nanos,
    /// Target-side software handling of an emulated-RMA active message
    /// (OPA personality), excluding the memcpy itself.
    pub rma_am_handle: Nanos,
    /// memcpy cost per KiB on the CPU (used by emulated RMA and window
    /// copies).
    pub memcpy_per_kib: Nanos,
    /// Interval at which the low-frequency PSM2-style progress thread of
    /// the OPA personality wakes up.
    pub psm2_progress_interval: Nanos,
    /// Cost of inserting one remote address into a context's address
    /// vector during connection establishment.
    pub av_insert: Nanos,
    /// Cost of creating one hardware context (init) on the NIC.
    pub ctx_create: Nanos,
    /// Cost of tearing one down (finalize).
    pub ctx_destroy: Nanos,

    // ---- protocol thresholds ----
    /// Eager/rendezvous switchover for two-sided messages (bytes).
    pub rendezvous_threshold: usize,
    /// Messages at or below this size complete at injection time
    /// ("immediate completion": no network polling needed for the send
    /// request), mirroring modern interconnects (paper §4.1).
    pub immediate_completion_max: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            lock_acquire: 16,
            lock_release: 6,
            lock_handoff: 700,
            lock_wake: 550,
            atomic_rmw: 18,
            cacheline_transfer: 40,
            ns_per_instruction_batch: 2,

            mpi_sw_send: 90,
            mpi_sw_recv: 90,
            mpi_sw_rma: 100,
            match_cost: 30,
            request_pool_op: 26,
            request_cache_op: 8,
            poll_empty: 30,
            doorbell_check: 4,
            progress_hook_check: 8,
            completion_process: 40,

            nic_inject: 55,
            nic_dma_per_kib: 80,
            wire_latency: 550,
            shm_inject: 45,
            shm_latency: 120,
            nic_rx_deliver: 55,
            rma_am_handle: 120,
            memcpy_per_kib: 28,
            psm2_progress_interval: 200_000,
            av_insert: 350,
            ctx_create: 35_000,
            ctx_destroy: 25_000,

            rendezvous_threshold: 16 * 1024,
            immediate_completion_max: 8 * 1024,
        }
    }
}

impl CostModel {
    /// DMA/serialization cost for a payload of `bytes`.
    pub fn dma_cost(&self, bytes: usize) -> Nanos {
        (self.nic_dma_per_kib as u128 * bytes as u128 / 1024) as Nanos
    }

    /// CPU memcpy cost for a payload of `bytes`.
    pub fn memcpy_cost(&self, bytes: usize) -> Nanos {
        (self.memcpy_per_kib as u128 * bytes as u128 / 1024) as Nanos
    }

    /// Price `n` "simple instructions" (paper: comm->VCI lookup costs 8
    /// instructions; storing the VCI in the request costs 3).
    pub fn instructions(&self, n: u64) -> Nanos {
        // ~3 simple ALU ops per ns on a Skylake-class core; round up via
        // batches of ~6 instructions per 2ns.
        (n * self.ns_per_instruction_batch).div_ceil(6).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dma_scales_linearly() {
        let c = CostModel::default();
        assert_eq!(c.dma_cost(1024), c.nic_dma_per_kib);
        assert_eq!(c.dma_cost(4096), 4 * c.nic_dma_per_kib);
        assert_eq!(c.dma_cost(0), 0);
    }

    #[test]
    fn instruction_pricing_monotone() {
        let c = CostModel::default();
        assert!(c.instructions(3) <= c.instructions(8));
        assert!(c.instructions(1) >= 1);
    }
}
