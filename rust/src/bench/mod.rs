//! Benchmark harness: the workload generators and execution modes behind
//! every figure in the paper's evaluation (§5, §6), plus the per-figure
//! drivers in [`figures`] that print the same rows/series the paper plots.

pub mod coll_rate;
pub mod figures;
pub mod message_rate;
pub mod rma_rate;
pub mod train_step;

pub use coll_rate::{coll_rate_run, CollMode, CollRateParams};
pub use message_rate::{message_rate, message_rate_run, Mode, Op, RateParams, RateReport};
pub use rma_rate::{ordered_window_program_order_preserved, rma_rate_run, RmaRateParams, WinMode};
pub use train_step::{train_step_run, StepMode, TrainStepParams};

/// A simple CSV emitter for figure output.
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        println!("{}", self.header.join(","));
        for r in &self.rows {
            println!("{}", r.join(","));
        }
    }
}

/// Format a message rate in mmsgs/s with stable precision.
pub fn fmt_rate(r: f64) -> String {
    format!("{:.4}", r / 1e6)
}
