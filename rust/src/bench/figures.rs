//! One driver per paper figure/table (DESIGN.md §6). Each prints a CSV
//! with the same rows/series the paper plots, and returns it for tests.
//!
//! Figure ids: fig2 fig3 fig4 fig5 fig6 fig7 fig8 table1 fig10 fig11 fig12
//! fig13 fig14 fig15 fig16 fig17 fig19 headline (+ app figures fig22 fig24
//! fig25 fig27 driven from `apps`), plus the DESIGN.md §9 ablations.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, Comm, MpiConfig, Src, Tag};
use crate::platform::{Backend, PBarrier};
use crate::sim::SimOutcome;

use super::message_rate::{message_rate, Mode, Op, RateParams};
use super::{fmt_rate, Csv};

/// Quick-run scaling knob: figures use `msgs_per_core = BASE_MSGS * scale`.
/// scale=1 is the EXPERIMENTS.md setting; tests use smaller.
pub const BASE_MSGS: usize = 1024;

fn thread_sweep() -> Vec<usize> {
    vec![1, 2, 4, 8, 16]
}

fn size_sweep() -> Vec<usize> {
    vec![8, 64, 512, 4096, 32 * 1024, 64 * 1024]
}

// ---------------------------------------------------------------------
// §4.1 — critical-section granularity
// ---------------------------------------------------------------------

/// Fig. 2: Global vs FG with ONE thread (uncontended): FG overhead.
pub fn fig2(scale: usize) -> Csv {
    let mut csv = Csv::new(&["config", "mmsgs_per_s", "relative"]);
    let mk = |cfg: MpiConfig| RateParams {
        mode: Mode::SerCommOrig,
        threads: 1,
        msgs_per_core: BASE_MSGS * scale,
        cfg_override: Some(cfg),
        ..Default::default()
    };
    let global = message_rate(mk(MpiConfig::original()));
    let fg = message_rate(mk(MpiConfig::fg_single_vci()));
    csv.row(&["global".into(), fmt_rate(global), "1.000".into()]);
    csv.row(&["fg".into(), fmt_rate(fg), format!("{:.3}", fg / global)]);
    csv
}

/// Fig. 3: Global vs FG message rate vs thread count (single VCI).
pub fn fig3(scale: usize) -> Csv {
    let mut csv = Csv::new(&["threads", "global_mmsgs", "fg_mmsgs"]);
    for t in thread_sweep() {
        let mk = |cfg: MpiConfig| RateParams {
            mode: Mode::SerCommOrig,
            threads: t,
            msgs_per_core: BASE_MSGS * scale,
            cfg_override: Some(cfg),
            ..Default::default()
        };
        let global = message_rate(mk(MpiConfig::original()));
        let fg = message_rate(mk(MpiConfig::fg_single_vci()));
        csv.row(&[t.to_string(), fmt_rate(global), fmt_rate(fg)]);
    }
    csv
}

// ---------------------------------------------------------------------
// §4.2 — VCI infrastructure overheads
// ---------------------------------------------------------------------

/// Fig. 4: MPI_Init / MPI_Finalize time vs number of VCIs.
pub fn fig4() -> Csv {
    let mut csv = Csv::new(&["vcis", "init_ms", "finalize_ms"]);
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 160,
            },
            MpiConfig::optimized(n),
            1,
        );
        let r = run_cluster(spec, |_proc, _t| {});
        assert_eq!(r.outcome, SimOutcome::Completed);
        let init = r.measurements["init_ns_p0"] / 1e6;
        let fini = r.measurements["finalize_ns_p0"] / 1e6;
        csv.row(&[n.to_string(), format!("{init:.4}"), format!("{fini:.4}")]);
    }
    csv
}

// ---------------------------------------------------------------------
// §4.3 — multi-VCI optimization ablations (16 threads, 8-byte isend)
// ---------------------------------------------------------------------

fn ablation_cfg(f: impl FnOnce(&mut MpiConfig)) -> MpiConfig {
    let mut cfg = MpiConfig::optimized(16);
    f(&mut cfg);
    cfg
}

fn ablation_run(scale: usize, cfg: MpiConfig, threads: usize) -> f64 {
    message_rate(RateParams {
        mode: Mode::ParCommVcis,
        threads,
        msgs_per_core: BASE_MSGS * scale,
        cfg_override: Some(cfg),
        ..Default::default()
    })
}

/// Fig. 5: multiple VCIs with NO optimizations vs original, vs threads.
pub fn fig5(scale: usize) -> Csv {
    let mut csv = Csv::new(&["threads", "original_mmsgs", "vcis_no_opts_mmsgs"]);
    for t in thread_sweep() {
        let orig = message_rate(RateParams {
            mode: Mode::ParCommOrig,
            threads: t,
            msgs_per_core: BASE_MSGS * scale,
            ..Default::default()
        });
        let no_opts = ablation_run(
            scale,
            ablation_cfg(|c| {
                c.per_vci_progress = false;
                c.per_vci_req_cache = false;
                c.per_vci_lightweight = false;
                c.cache_aligned_vcis = false;
            }),
            t,
        );
        csv.row(&[t.to_string(), fmt_rate(orig), fmt_rate(no_opts)]);
    }
    csv
}

/// Fig. 6: all opts vs all-without-per-VCI-progress.
pub fn fig6(scale: usize) -> Csv {
    let mut csv = Csv::new(&["threads", "all_mmsgs", "no_per_vci_progress_mmsgs", "ratio"]);
    for t in thread_sweep() {
        let all = ablation_run(scale, MpiConfig::optimized(16), t);
        let wo = ablation_run(scale, ablation_cfg(|c| c.per_vci_progress = false), t);
        csv.row(&[t.to_string(), fmt_rate(all), fmt_rate(wo), format!("{:.2}", all / wo)]);
    }
    csv
}

/// Fig. 7: all opts vs all-without-per-VCI-request-management.
pub fn fig7(scale: usize) -> Csv {
    let mut csv = Csv::new(&["threads", "all_mmsgs", "no_per_vci_reqmgmt_mmsgs", "ratio"]);
    for t in thread_sweep() {
        let all = ablation_run(scale, MpiConfig::optimized(16), t);
        let wo = ablation_run(
            scale,
            ablation_cfg(|c| {
                c.per_vci_req_cache = false;
                c.per_vci_lightweight = false;
            }),
            t,
        );
        csv.row(&[t.to_string(), fmt_rate(all), fmt_rate(wo), format!("{:.2}", all / wo)]);
    }
    csv
}

/// Fig. 8: all opts vs all-without-cache-aligned VCIs.
pub fn fig8(scale: usize) -> Csv {
    let mut csv = Csv::new(&["threads", "all_mmsgs", "no_cache_align_mmsgs", "ratio"]);
    for t in thread_sweep() {
        let all = ablation_run(scale, MpiConfig::optimized(16), t);
        let wo = ablation_run(scale, ablation_cfg(|c| c.cache_aligned_vcis = false), t);
        csv.row(&[t.to_string(), fmt_rate(all), fmt_rate(wo), format!("{:.2}", all / wo)]);
    }
    csv
}

/// §4.3 headline: optimized multi-VCI vs state of the art at 16 threads.
pub fn headline(scale: usize) -> Csv {
    let mut csv = Csv::new(&["config", "mmsgs_per_s", "speedup_vs_state_of_the_art"]);
    let sota = message_rate(RateParams {
        mode: Mode::SerCommOrig,
        threads: 16,
        msgs_per_core: BASE_MSGS * scale,
        ..Default::default()
    });
    let opt = message_rate(RateParams {
        mode: Mode::ParCommVcis,
        threads: 16,
        msgs_per_core: BASE_MSGS * scale,
        ..Default::default()
    });
    csv.row(&["state_of_the_art".into(), fmt_rate(sota), "1.00".into()]);
    csv.row(&["optimized_16vcis".into(), fmt_rate(opt), format!("{:.2}", opt / sota)]);
    csv
}

// ---------------------------------------------------------------------
// Table 1 — locks on the critical path
// ---------------------------------------------------------------------

/// Table 1: measured lock acquisitions per operation and CS mode.
pub fn table1() -> Csv {
    let mut csv = Csv::new(&[
        "cs_mode",
        "op",
        "global_locks",
        "vci_locks",
        "request_locks",
        "hook_locks",
        "shard_locks",
        "atomics",
        "anchored_allocs",
        "coll_segments",
        "coll_lane_spread",
        "coll_overlap_ms",
    ]);
    let rows: Arc<Mutex<Vec<Vec<String>>>> = Arc::new(Mutex::new(Vec::new()));
    for (mode_name, cfg) in [
        ("Global", MpiConfig::original()),
        ("FG", {
            let mut c = MpiConfig::optimized(4);
            c.per_vci_req_cache = false;
            c.per_vci_lightweight = false;
            c
        }),
        ("FG+req-cache", MpiConfig::optimized(4)),
    ] {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            cfg,
            1,
        );
        let rows2 = rows.clone();
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 4096);
            let mut local = Vec::new();
            if proc.rank() == 0 {
                use crate::mpi::instrument::snapshot;
                // Warm the request cache so the steady-state path is
                // measured (first alloc falls back to the global pool).
                let warm = proc.isend(&world, 1, 70, &vec![1u8; 32 * 1024]);
                proc.wait(warm);

                // Isend (non-immediate: needs a request object). Use an
                // eager-but-large payload so a real request is allocated.
                let base = snapshot();
                let req = proc.isend(&world, 1, 7, &vec![0u8; 12 * 1024]);
                let after_isend = snapshot();
                let d = after_isend - base;
                local.push(row(mode_name, "Isend", &d));

                // Wait on it: let the TX completion stamp pass first so
                // the wait observes completion after one progress round
                // (the paper's Table 1 accounting; a longer wait loop
                // would repeat the per-iteration locks).
                crate::platform::padvance(proc.backend, 50_000);
                let base = snapshot();
                proc.wait(req);
                let d = snapshot() - base;
                local.push(row(mode_name, "Wait", &d));

                // Immediate Isend (lightweight request).
                let base = snapshot();
                let req = proc.isend(&world, 1, 8, &[0u8; 8]);
                let d = snapshot() - base;
                local.push(row(mode_name, "Isend (immediate)", &d));

                // Wait (immediate).
                let base = snapshot();
                proc.wait(req);
                let d = snapshot() - base;
                local.push(row(mode_name, "Wait (immediate)", &d));

                // Put initiation.
                let base = snapshot();
                proc.put(&win, 1, 0, &[0u8; 64]);
                let d = snapshot() - base;
                local.push(row(mode_name, "Put", &d));
                proc.win_flush(&win);

                // One uncontended progress-engine iteration (the lock the
                // paper's FG Wait row includes for the completion poll).
                let base = snapshot();
                proc.progress_for_request(0);
                let d = snapshot() - base;
                local.push(row(mode_name, "Progress iteration", &d));

                rows2.lock().unwrap().extend(local);
                proc.send(&world, 1, 99, &[]);
            } else {
                // Absorb the sends.
                let _ = proc.recv(&world, Src::Rank(0), Tag::Value(70));
                let _ = proc.recv(&world, Src::Rank(0), Tag::Value(7));
                let _ = proc.recv(&world, Src::Rank(0), Tag::Value(8));
                let _ = proc.recv(&world, Src::Rank(0), Tag::Value(99));
            }
            // Segmented allreduce (collective on both ranks; rank 0
            // measures), on a striped-collectives comm so BOTH new
            // columns are live: coll_segments proves the segmented path
            // runs, coll_lane_spread that segments leave the home lane
            // (zero in the Global arm — a 1-lane pool has nowhere to
            // spread).
            {
                use crate::mpi::instrument::snapshot;
                let coll = proc.comm_dup_with_info(
                    &world,
                    &crate::mpi::Info::new().with("vcmpi_collectives", "striped"),
                );
                let mut v = [1.0f32; 64];
                let base = snapshot();
                proc.allreduce_f32(&coll, &mut v);
                let d = snapshot() - base;
                if proc.rank() == 0 {
                    rows2.lock().unwrap().push(row(mode_name, "Allreduce (segmented)", &d));
                }
                // Nonblocking allreduce with compute between issue and
                // wait: the coll_overlap_ms column is the communication
                // time hidden behind that compute window.
                let base = snapshot();
                let req = proc.iallreduce_f32(&coll, &v);
                crate::platform::padvance(proc.backend, 50_000);
                proc.coll_wait_f32(req, &mut v);
                let d = snapshot() - base;
                if proc.rank() == 0 {
                    rows2.lock().unwrap().push(row(mode_name, "Iallreduce (overlapped)", &d));
                }
                proc.comm_free(coll);
            }
            proc.barrier(&world);
            proc.win_free(&world, win);
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
    }
    for r in rows.lock().unwrap().iter() {
        csv.row(r);
    }
    csv
}

fn row(mode: &str, op: &str, d: &crate::mpi::instrument::OpCounters) -> Vec<String> {
    vec![
        mode.to_string(),
        op.to_string(),
        d.global_locks.to_string(),
        d.vci_locks.to_string(),
        d.request_locks.to_string(),
        d.hook_locks.to_string(),
        d.shard_locks.to_string(),
        d.atomics.to_string(),
        d.anchored_allocs.to_string(),
        d.coll_segments.to_string(),
        d.coll_lane_spread.to_string(),
        format!("{:.3}", d.coll_overlap_ns as f64 / 1e6),
    ]
}

// ---------------------------------------------------------------------
// §5.1 — well-behaved communication (Isend)
// ---------------------------------------------------------------------

/// Fig. 10: 8-byte Isend message-rate scaling, all six modes, both fabrics.
pub fn fig10(scale: usize) -> Csv {
    let mut csv = Csv::new(&["fabric", "mode", "threads", "mmsgs_per_s"]);
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for mode in Mode::all() {
            for t in thread_sweep() {
                let r = message_rate(RateParams {
                    mode,
                    interconnect: ic,
                    threads: t,
                    msgs_per_core: BASE_MSGS * scale,
                    ..Default::default()
                });
                csv.row(&[
                    format!("{ic:?}"),
                    mode.label().into(),
                    t.to_string(),
                    fmt_rate(r),
                ]);
            }
        }
    }
    csv
}

/// Fig. 11: Isend rate at 16 cores across message sizes.
pub fn fig11(scale: usize) -> Csv {
    let mut csv = Csv::new(&["fabric", "mode", "bytes", "mmsgs_per_s"]);
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for mode in Mode::all() {
            for size in size_sweep() {
                let r = message_rate(RateParams {
                    mode,
                    interconnect: ic,
                    threads: 16,
                    msg_size: size,
                    msgs_per_core: (BASE_MSGS * scale / 2).max(128),
                    ..Default::default()
                });
                csv.row(&[
                    format!("{ic:?}"),
                    mode.label().into(),
                    size.to_string(),
                    fmt_rate(r),
                ]);
            }
        }
    }
    csv
}

/// Fig. 12: the cost of thread safety — everywhere vs par_comm+vcis vs
/// par_comm+vcis with locks/atomics disabled.
pub fn fig12(scale: usize) -> Csv {
    let mut csv = Csv::new(&["config", "threads", "mmsgs_per_s"]);
    for t in thread_sweep() {
        let ew = message_rate(RateParams {
            mode: Mode::Everywhere,
            threads: t,
            msgs_per_core: BASE_MSGS * scale,
            ..Default::default()
        });
        let vcis = message_rate(RateParams {
            mode: Mode::ParCommVcis,
            threads: t,
            msgs_per_core: BASE_MSGS * scale,
            ..Default::default()
        });
        let unsafe_ = message_rate(RateParams {
            mode: Mode::ParCommVcis,
            threads: t,
            msgs_per_core: BASE_MSGS * scale,
            cfg_override: Some(ablation_cfg(|c| c.unsafe_no_thread_safety = true)),
            ..Default::default()
        });
        csv.row(&["everywhere".into(), t.to_string(), fmt_rate(ew)]);
        csv.row(&["vcis".into(), t.to_string(), fmt_rate(vcis)]);
        csv.row(&["vcis_no_locks_no_atomics".into(), t.to_string(), fmt_rate(unsafe_)]);
    }
    csv
}

// ---------------------------------------------------------------------
// §5.2 — not-so-well-behaved communication (Put)
// ---------------------------------------------------------------------

/// Fig. 13: 8-byte Put message-rate scaling, both fabrics.
pub fn fig13(scale: usize) -> Csv {
    let mut csv = Csv::new(&["fabric", "mode", "threads", "mmsgs_per_s"]);
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for mode in Mode::all() {
            for t in thread_sweep() {
                let r = message_rate(RateParams {
                    mode,
                    interconnect: ic,
                    threads: t,
                    op: Op::Put,
                    msgs_per_core: (BASE_MSGS * scale / 4).max(128),
                    ..Default::default()
                });
                csv.row(&[
                    format!("{ic:?}"),
                    mode.label().into(),
                    t.to_string(),
                    fmt_rate(r),
                ]);
            }
        }
    }
    csv
}

/// Fig. 14: Put rate at 16 cores across message sizes.
pub fn fig14(scale: usize) -> Csv {
    let mut csv = Csv::new(&["fabric", "mode", "bytes", "mmsgs_per_s"]);
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for mode in [Mode::Everywhere, Mode::ParCommVcis, Mode::Endpoints] {
            for size in size_sweep() {
                let r = message_rate(RateParams {
                    mode,
                    interconnect: ic,
                    threads: 16,
                    msg_size: size,
                    op: Op::Put,
                    msgs_per_core: (BASE_MSGS * scale / 8).max(64),
                    ..Default::default()
                });
                csv.row(&[
                    format!("{ic:?}"),
                    mode.label().into(),
                    size.to_string(),
                    fmt_rate(r),
                ]);
            }
        }
    }
    csv
}

/// Fig. 15/16: Put completion with target-side win_free progress, across
/// target busy-compute times (0 reproduces Fig. 15's "parallel Win_free";
/// growing compute reproduces Fig. 16's busy-target decay).
pub fn fig15_16(scale: usize) -> Csv {
    let mut csv = Csv::new(&["target_busy_us", "put_mmsgs_per_s"]);
    for busy_us in [0u64, 50, 200, 800, 3200] {
        let rate = busy_target_put_rate(scale, busy_us);
        csv.row(&[busy_us.to_string(), fmt_rate(rate)]);
    }
    csv
}

fn busy_target_put_rate(scale: usize, busy_us: u64) -> f64 {
    let threads = 8;
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(threads + 1),
        threads,
    );
    spec.time_limit = Some(600_000_000_000);
    let msgs = (BASE_MSGS * scale / 8).max(64);
    let wins: Arc<Mutex<HashMap<usize, Vec<Arc<crate::mpi::Window>>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, threads)).collect());
    let r = run_cluster(spec, move |proc, t| {
        let world = proc.comm_world();
        let me = proc.rank();
        if t == 0 {
            let v: Vec<_> = (0..threads).map(|_| proc.win_create(&world, 4096)).collect();
            wins.lock().unwrap().insert(me, v);
        }
        bars[me].wait();
        let win = wins.lock().unwrap().get(&me).unwrap()[t].clone();
        if t == 0 {
            proc.barrier(&world);
        }
        bars[me].wait();
        let t0 = crate::platform::pnow(proc.backend);
        if me == 0 {
            // Initiators: puts + flush.
            for _ in 0..msgs {
                proc.put(&win, 1, 0, &[0u8; 8]);
            }
            proc.win_flush(&win);
        } else {
            // Busy target: compute, then free-own-window-style progress
            // (paper Fig. 15/16): poll own window's VCI until the peer
            // finishes.
            crate::platform::pcompute(proc.backend, busy_us * 1000);
        }
        bars[me].wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bars[me].wait();
        let t1 = crate::platform::pnow(proc.backend);
        if me == 0 && t == 0 {
            let total = (threads * msgs) as f64;
            crate::mpi::world::record("rate", total / ((t1 - t0) as f64 / 1e9));
        }
        bars[me].wait();
        if t == 0 {
            let mine = wins.lock().unwrap().remove(&me).unwrap();
            for w in mine {
                proc.win_free(&world, w);
            }
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed);
    r.measurements["rate"]
}

// ---------------------------------------------------------------------
// Fig. 17 — mapping mismatch
// ---------------------------------------------------------------------

/// Fig. 17: 16 threads expose parallelism via 16 communicators, but the
/// hardware has only `16 - serialized` contexts: colliding communicators
/// fall back to VCI 0 and serialize.
pub fn fig17(scale: usize) -> Csv {
    let mut csv = Csv::new(&["serialized_threads", "mmsgs_per_s"]);
    for serialized in [0usize, 2, 4, 8, 12, 15] {
        let vcis = 17 - serialized; // fallback + 16-serialized usable
        let r = message_rate(RateParams {
            mode: Mode::ParCommVcis,
            threads: 16,
            msgs_per_core: BASE_MSGS * scale,
            cfg_override: Some(MpiConfig::optimized(vcis)),
            ..Default::default()
        });
        csv.row(&[serialized.to_string(), fmt_rate(r)]);
    }
    csv
}

// ---------------------------------------------------------------------
// Fig. 18/19 — the Legion pattern (dedicated senders + polling receiver)
// ---------------------------------------------------------------------

/// Fig. 19: N sender threads per node + 1 dedicated receiver thread.
/// MPI-3.1: the receiver must iterate over the senders' communicators,
/// contending on their VCIs. Endpoints: the receiver owns one endpoint.
pub fn fig19(scale: usize) -> Csv {
    let mut csv = Csv::new(&["senders", "comms_mmsgs_per_s", "endpoints_mmsgs_per_s"]);
    for senders in [1usize, 2, 4, 8, 15] {
        let c = legion_rate(scale, senders, false);
        let e = legion_rate(scale, senders, true);
        csv.row(&[senders.to_string(), fmt_rate(c), fmt_rate(e)]);
    }
    csv
}

fn legion_rate(scale: usize, senders: usize, endpoints: bool) -> f64 {
    let threads = senders + 1; // + dedicated receiver thread
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(threads + 2),
        threads,
    );
    spec.time_limit = Some(600_000_000_000);
    let msgs = (BASE_MSGS * scale / 2).max(128);
    let comms: Arc<Mutex<HashMap<usize, Vec<Comm>>>> = Arc::new(Mutex::new(HashMap::new()));
    let eps: Arc<Mutex<HashMap<usize, Comm>>> = Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, threads)).collect());
    let r = run_cluster(spec, move |proc, t| {
        let world = proc.comm_world();
        let me = proc.rank();
        let peer = 1 - me;
        if t == 0 {
            if endpoints {
                // One endpoint per thread (senders 0..senders-1, receiver
                // at index `senders`).
                let ep = proc.create_endpoints(&world, threads);
                eps.lock().unwrap().insert(me, ep);
            } else {
                let v: Vec<Comm> = (0..senders).map(|_| proc.comm_dup(&world)).collect();
                comms.lock().unwrap().insert(me, v);
            }
        }
        bars[me].wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bars[me].wait();
        let t0 = crate::platform::pnow(proc.backend);
        if t < senders {
            // Sender thread t: fire-and-forget stream to the remote
            // receiver.
            if endpoints {
                let ep = eps.lock().unwrap().get(&me).unwrap().clone();
                let to = proc.endpoint_rank(&ep, peer, senders); // receiver ep
                for _ in 0..msgs {
                    let r = proc.isend_ep(&ep, Some(t), to, t as i32, &[1u8; 8], false);
                    proc.wait(r);
                }
            } else {
                let comm = comms.lock().unwrap().get(&me).unwrap()[t].clone();
                for _ in 0..msgs {
                    let r = proc.isend(&comm, peer, t as i32, &[1u8; 8]);
                    proc.wait(r);
                }
            }
        } else {
            // The dedicated receiver: drain senders*msgs messages.
            let total = senders * msgs;
            if endpoints {
                let ep = eps.lock().unwrap().get(&me).unwrap().clone();
                let mut reqs = Vec::new();
                for _ in 0..total {
                    reqs.push(proc.irecv_ep(&ep, Some(senders), Src::Any, Tag::Any));
                    if reqs.len() >= 64 {
                        proc.waitall(reqs.drain(..).collect::<Vec<_>>());
                    }
                }
                proc.waitall(reqs);
            } else {
                // MPI-3.1 semantics: iterate over the communicators.
                let v = comms.lock().unwrap().get(&me).unwrap().clone();
                let mut done = 0usize;
                let mut pending: Vec<(usize, crate::mpi::Request)> = v
                    .iter()
                    .enumerate()
                    .map(|(i, c)| (i, proc.irecv(c, Src::Rank(peer), Tag::Value(i as i32))))
                    .collect();
                while done < total {
                    let mut next = Vec::new();
                    for (i, req) in pending.drain(..) {
                        if proc.test(&req) {
                            proc.wait(req);
                            done += 1;
                            if done + next.len() < total {
                                next.push((
                                    i,
                                    proc.irecv(&v[i], Src::Rank(peer), Tag::Value(i as i32)),
                                ));
                            }
                        } else {
                            next.push((i, req));
                        }
                    }
                    pending = next;
                }
            }
        }
        bars[me].wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bars[me].wait();
        let t1 = crate::platform::pnow(proc.backend);
        if me == 0 && t == 0 {
            let total = (2 * senders * msgs) as f64; // both directions
            crate::mpi::world::record("rate", total / ((t1 - t0) as f64 / 1e9));
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "legion run: {:?}", r.outcome);
    r.measurements["rate"]
}

// ---------------------------------------------------------------------
// DESIGN.md §9 ablations
// ---------------------------------------------------------------------

/// Hybrid progress interval sweep (correctness/performance trade-off).
pub fn ablate_progress(scale: usize) -> Csv {
    let mut csv = Csv::new(&["global_interval", "mmsgs_per_s"]);
    for interval in [1u32, 4, 16, 64, 256, 1024] {
        let r = message_rate(RateParams {
            mode: Mode::ParCommVcis,
            threads: 8,
            msgs_per_core: BASE_MSGS * scale,
            cfg_override: Some(ablation_cfg(|c| c.global_progress_interval = interval)),
            ..Default::default()
        });
        csv.row(&[interval.to_string(), fmt_rate(r)]);
    }
    csv
}

/// VCI mapping policy comparison under pool pressure (24 comms, 16 VCIs).
pub fn ablate_policy(scale: usize) -> Csv {
    use crate::mpi::VciPolicy;
    let mut csv = Csv::new(&["policy", "mmsgs_per_s"]);
    for (name, policy) in [
        ("first_come", VciPolicy::FirstComePool),
        ("round_robin", VciPolicy::RoundRobin),
        ("hashed", VciPolicy::Hashed),
    ] {
        let r = message_rate(RateParams {
            mode: Mode::ParCommVcis,
            threads: 16,
            msgs_per_core: BASE_MSGS * scale,
            cfg_override: Some(ablation_cfg(|c| {
                c.vci_policy = policy;
                c.num_vcis = 12; // fewer VCIs than threads: collisions matter
            })),
            ..Default::default()
        });
        csv.row(&[name.into(), fmt_rate(r)]);
    }
    csv
}

/// §7 (MPI-4.0): a single communicator, one tag per thread. Without the
/// `no_any_source`/`no_any_tag` hints all traffic funnels through the
/// communicator's one VCI; with them, envelopes spread across the pool.
pub fn ablate_hints(scale: usize) -> Csv {
    let mut csv = Csv::new(&["hints", "threads", "mmsgs_per_s"]);
    for t in thread_sweep() {
        for (label, hinted) in [("off", false), ("no_any_source+tag", true)] {
            let mut cfg = MpiConfig::optimized(t + 1);
            cfg.hints.no_any_source = hinted;
            cfg.hints.no_any_tag = hinted;
            let r = message_rate(RateParams {
                mode: Mode::SerCommVcis, // ONE communicator for all threads
                threads: t,
                msgs_per_core: BASE_MSGS * scale,
                cfg_override: Some(cfg),
                ..Default::default()
            });
            csv.row(&[label.into(), t.to_string(), fmt_rate(r)]);
        }
    }
    csv
}

/// Dispatch a figure by id. `scale` scales the per-core message count.
pub fn run_figure(id: &str, scale: usize) -> Option<Csv> {
    use crate::apps;
    Some(match id {
        "fig22" => apps::stencil::fig22(&[1536, 3072, 6144], (2 * scale).min(6)),
        "fig24" => apps::ebms::fig24(&[16 * 1024, 64 * 1024, 256 * 1024], (2 * scale).min(6)),
        "fig25" => apps::ebms::fig25(&[16 * 1024, 64 * 1024, 256 * 1024], (2 * scale).min(6)),
        "fig27" => apps::bspmm::fig27(&[128, 256, 512], (scale + 1).min(3)),
        "fig2" => fig2(scale),
        "fig3" => fig3(scale),
        "fig4" => fig4(),
        "fig5" => fig5(scale),
        "fig6" => fig6(scale),
        "fig7" => fig7(scale),
        "fig8" => fig8(scale),
        "table1" => table1(),
        "fig10" => fig10(scale),
        "fig11" => fig11(scale),
        "fig12" => fig12(scale),
        "fig13" => fig13(scale),
        "fig14" => fig14(scale),
        "fig15" | "fig16" | "fig15_16" => fig15_16(scale),
        "fig17" => fig17(scale),
        "fig18" | "fig19" => fig19(scale),
        "headline" => headline(scale),
        "ablate-progress" => ablate_progress(scale),
        "ablate-hints" => ablate_hints(scale),
        "ablate-policy" => ablate_policy(scale),
        _ => return None,
    })
}

/// All figure ids (for `repro list` and the full regeneration loop).
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "table1", "fig10", "fig11",
        "fig12", "fig13", "fig14", "fig15_16", "fig17", "fig19", "fig22", "fig24", "fig25",
        "fig27", "headline", "ablate-progress", "ablate-policy", "ablate-hints",
    ]
}
