//! The train-step benchmark behind the nonblocking-collectives tentpole:
//! a data-parallel trainer's backward pass produces gradient buckets in
//! order, and the question the paper's whole argument turns on is
//! whether the library can put bucket *i*'s allreduce on the wire while
//! bucket *i+1* is still being computed. Two arms, same schedule, same
//! comms, same payloads, on the 2x2-proc topology:
//!
//!  * [`StepMode::StepBlocking`] — the pre-PR trainer: compute bucket,
//!    block in `allreduce_f32`, compute the next. Every byte of exchange
//!    time lands on the critical path.
//!  * [`StepMode::StepOverlap`] — compute bucket, issue `iallreduce`,
//!    keep computing; wait all handles once the backward pass finishes.
//!    The per-lane poller threads (the shared-progress model) drive the
//!    resumable schedules through progress hook 0 while the trainer
//!    thread is busy in `pcompute`, so communication hides behind
//!    compute and only the exposed tail blocks.
//!
//! The figure of merit is reduced f32 elements per second of the trainer
//! thread (virtual time), so `overlap_over_blocking > 1.0` is precisely
//! "the overlapped step is faster than the blocking step". The overlap
//! arm additionally proves real hiding happened (`coll_overlap_ns > 0`,
//! the Table-1 `coll_overlap_ms` counter).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{instrument, run_cluster, ClusterSpec, Comm, Info, MpiConfig};
use crate::platform::{pcompute, pnow, Backend, PBarrier};
use crate::sim::SimOutcome;

use super::message_rate::RateReport;

/// Trainer-arm under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepMode {
    /// Compute bucket → blocking allreduce → next bucket.
    StepBlocking,
    /// Compute bucket → issue iallreduce → next bucket; wait all at the
    /// end of the backward pass.
    StepOverlap,
}

impl StepMode {
    pub fn label(&self) -> &'static str {
        match self {
            StepMode::StepBlocking => "step_blocking",
            StepMode::StepOverlap => "step_overlap",
        }
    }
}

#[derive(Clone)]
pub struct TrainStepParams {
    pub mode: StepMode,
    /// Threads per process: thread 0 is the trainer; threads 1.. are
    /// per-lane pollers (the shared-progress model). Also the VCI pool
    /// size (lane 0 = fallback).
    pub threads: usize,
    /// Gradient buckets = dedicated-lane communicators.
    pub buckets: usize,
    /// Total f32 gradient elements per step (split across buckets).
    pub elems: usize,
    /// Modeled backward-pass compute per bucket (virtual ns) — the time
    /// the overlap arm hides communication behind.
    pub compute_ns: u64,
    /// Train steps measured.
    pub steps: usize,
    pub cfg_override: Option<MpiConfig>,
}

impl Default for TrainStepParams {
    fn default() -> Self {
        TrainStepParams {
            mode: StepMode::StepBlocking,
            threads: 8,
            buckets: 4,
            elems: 32 * 1024,
            compute_ns: 50_000,
            steps: 4,
            cfg_override: None,
        }
    }
}

/// Run the train-step scenario; the report's `rate` is reduced f32
/// elements per second of the trainer thread (virtual time). The overlap
/// arm also records `coll_overlap_ns` (rank 0).
pub fn train_step_run(p: TrainStepParams) -> RateReport {
    let fab = FabricConfig {
        interconnect: Interconnect::Opa,
        nodes: 2,
        procs_per_node: 2,
        max_contexts_per_node: 64,
    };
    let tpp = p.threads;
    let cfg = p.cfg_override.clone().unwrap_or_else(|| MpiConfig::optimized(tpp));
    let mut spec = ClusterSpec::new(fab, cfg, tpp);
    spec.time_limit = Some(600_000_000_000);
    let p = Arc::new(p);
    let pp = p.clone();

    type CommMap = HashMap<usize, Vec<Comm>>;
    let comms: Arc<Mutex<CommMap>> = Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    let stops: Arc<Mutex<HashMap<usize, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        let mut s = stops.lock().unwrap();
        for proc in 0..4 {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
            s.insert(proc, Arc::new(AtomicBool::new(false)));
        }
    }

    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let world = proc.comm_world();
        let me = proc.rank();
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();
        let stop = stops.lock().unwrap().get(&me).unwrap().clone();

        // ---- setup: one dedicated-lane comm per gradient bucket, the
        // trainer's production policy (auto segment sizing from the
        // fabric cost model) ----
        if t == 0 {
            let coll_info = Info::new()
                .with("vcmpi_collectives", "dedicated")
                .with("vcmpi_coll_segments", "auto");
            let v: Vec<Comm> =
                (0..p.buckets).map(|_| proc.comm_dup_with_info(&world, &coll_info)).collect();
            comms.lock().unwrap().insert(me, v);
        }
        bar.wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();

        // ---- measured phase ----
        if t == 0 {
            let bucket_comms = comms.lock().unwrap().get(&me).unwrap().clone();
            let mut grads: Vec<f32> = (0..p.elems).map(|i| (me + i) as f32).collect();
            let per = p.elems.div_ceil(p.buckets);
            let inst0 = instrument::snapshot();
            let t0 = pnow(proc.backend);
            for _ in 0..p.steps {
                match p.mode {
                    StepMode::StepBlocking => {
                        for b in 0..p.buckets {
                            let (lo, hi) = ((b * per).min(p.elems), ((b + 1) * per).min(p.elems));
                            pcompute(proc.backend, p.compute_ns);
                            if lo < hi {
                                proc.allreduce_f32(&bucket_comms[b], &mut grads[lo..hi]);
                            }
                        }
                    }
                    StepMode::StepOverlap => {
                        let mut reqs = Vec::with_capacity(p.buckets);
                        for b in 0..p.buckets {
                            let (lo, hi) = ((b * per).min(p.elems), ((b + 1) * per).min(p.elems));
                            pcompute(proc.backend, p.compute_ns);
                            if lo < hi {
                                reqs.push((
                                    proc.iallreduce_f32(&bucket_comms[b], &grads[lo..hi]),
                                    lo,
                                    hi,
                                ));
                            }
                        }
                        for (req, lo, hi) in reqs {
                            proc.coll_wait_f32(req, &mut grads[lo..hi]);
                        }
                    }
                }
            }
            let t1 = pnow(proc.backend);
            if me == 0 {
                let reduced = (p.steps * p.elems) as f64;
                crate::mpi::world::record("rate", reduced / ((t1 - t0) as f64 / 1e9));
                crate::mpi::world::record(
                    "coll_overlap_ns",
                    (instrument::snapshot() - inst0).coll_overlap_ns as f64,
                );
            }
            proc.barrier(&world);
            stop.store(true, Ordering::Release);
        } else {
            // Per-lane pollers: thread t drives progress on lane t. Each
            // progress iteration ends in `check_hooks`, so the pollers —
            // not the trainer thread — advance the in-flight collective
            // schedules while the trainer computes.
            let lane = t % proc.vcis().len();
            while !stop.load(Ordering::Acquire) {
                proc.progress_for_request(lane);
            }
        }
        bar.wait();

        // ---- proof points + teardown ----
        if t == 0 {
            crate::mpi::world::record(
                format!("stale_ctrl_drops_p{me}"),
                proc.stale_ctrl_drop_count() as f64,
            );
            crate::mpi::world::record(
                format!("policy_mismatch_p{me}"),
                proc.policy_mismatch_count() as f64,
            );
            // The least-loaded placement claim (the PR's bugfix): every
            // bucket comm holds a DISTINCT dedicated lane while the pool
            // has enough of them.
            let bucket_comms = { comms.lock().unwrap().remove(&me).unwrap() };
            let mut lanes: Vec<usize> =
                bucket_comms.iter().map(|c| proc.dedicated_coll_lane(c)).collect();
            lanes.sort_unstable();
            lanes.dedup();
            crate::mpi::world::record(
                format!("distinct_coll_lanes_p{me}"),
                lanes.len() as f64,
            );
            for c in bucket_comms {
                proc.comm_free(c);
            }
        }
    });
    assert_eq!(
        r.outcome,
        SimOutcome::Completed,
        "train_step run failed ({:?}): {:?}",
        p.mode,
        r.outcome
    );
    RateReport { rate: r.measurements["rate"], measurements: r.measurements }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlapped_train_step_beats_blocking() {
        // The tentpole ratio (the CI gate enforces it at the full bench
        // sizes): issuing every bucket's iallreduce during the backward
        // pass must beat blocking bucket-by-bucket.
        let base = TrainStepParams {
            threads: 6,
            buckets: 3,
            elems: 24 * 1024,
            compute_ns: 50_000,
            steps: 2,
            ..Default::default()
        };
        let blocking =
            train_step_run(TrainStepParams { mode: StepMode::StepBlocking, ..base.clone() });
        let overlap = train_step_run(TrainStepParams { mode: StepMode::StepOverlap, ..base });
        assert!(
            overlap.rate > blocking.rate,
            "overlapped train step must beat blocking bucket-by-bucket: \
             overlap={:.0} blocking={:.0}",
            overlap.rate,
            blocking.rate
        );
        assert!(
            overlap.measurements["coll_overlap_ns"] > 0.0,
            "the overlap arm must actually hide communication behind compute"
        );
        assert_eq!(overlap.sum_stat("stale_ctrl_drops"), 0.0);
        assert_eq!(overlap.sum_stat("policy_mismatch"), 0.0);
        // Bugfix proof: 3 dedicated comms on a 6-lane pool → 3 distinct
        // lanes on every proc (the old comm-id hash could collide).
        assert_eq!(overlap.sum_stat("distinct_coll_lanes"), 12.0);
    }
}
