//! The one-sided rate benchmark behind the paper's §7 RMA claim: VCIs pay
//! off on the one-sided path only when a **single origin thread's**
//! operations can spread across network contexts. One origin thread
//! hammers a remote window with accumulates in flush-bounded batches; the
//! target's threads poll their own lanes (the paper's shared-progress
//! model: any thread inside MPI progresses the library).
//!
//! Two scenarios, identical topology and process config — the only
//! difference is the window's info keys:
//!
//!  * [`WinMode::WinOrdered`]: the default window policy. Every accumulate
//!    funnels through the window's home VCI, so exactly one target thread
//!    does all the active-message handling — the serialized baseline.
//!  * [`WinMode::WinStriped`]: `accumulate_ordering=none` +
//!    `vcmpi_striping=rr` (+ doorbell-gated flush sweeps). The SAME single
//!    origin thread fans its accumulates across the stripe lanes; the
//!    target's per-lane pollers handle them in parallel and completion is
//!    counted per (window, target, lane).
//!
//! The CI gate requires `win_striped_over_ordered > 1.0` plus the
//! [`ordered_window_program_order_preserved`] probe (striping must never
//! leak reordering into the default accumulate path).
//!
//! Three passive-target arms ride the same topology, replacing the
//! explicit flush with a lock epoch per batch (`win_lock` … ops …
//! `win_unlock`; the unlock completes the batch):
//!
//!  * [`WinMode::PassiveShared`]: shared locks on the striped window — the
//!    lock protocol pays its wire round trips but the ops still stripe.
//!  * [`WinMode::PassiveExclusive`]: exclusive locks on the ordered
//!    window — serialized handling *and* the full protocol.
//!  * [`WinMode::PassiveNoLocks`]: shared locks on the striped window
//!    with `mpi_assert_no_locks` — identical program text, but the lock
//!    protocol is elided to a local no-op grant.
//!
//! The CI gates: `no_locks_over_locked >= 1.0` (the elision must pay) and
//! `passive_striped_over_exclusive > 1.0` (striping must survive epochs).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{AccOp, FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, Info, LockKind, MpiConfig, Src, Tag};
use crate::platform::{Backend, PBarrier};
use crate::sim::SimOutcome;

use super::message_rate::RateReport;

/// Tag of the origin's "all batches flushed" stop message.
const STOP_TAG: i32 = 901;

/// Window-policy arm under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WinMode {
    /// Default (ordered) window: accumulates funnel through the home VCI.
    WinOrdered,
    /// Info-keyed striped window: `accumulate_ordering=none`,
    /// `vcmpi_striping=rr`, `vcmpi_rx_doorbell=true`.
    WinStriped,
    /// Striped window WITHOUT `mpi_assert_no_locks`; each batch runs in a
    /// shared lock epoch (the lock protocol pays real round trips).
    PassiveShared,
    /// Ordered (default-policy) window; each batch runs in an exclusive
    /// lock epoch.
    PassiveExclusive,
    /// Striped window WITH `mpi_assert_no_locks`; the same epoch-based
    /// program text as [`WinMode::PassiveShared`], lock protocol elided.
    PassiveNoLocks,
}

impl WinMode {
    pub fn label(&self) -> &'static str {
        match self {
            WinMode::WinOrdered => "win_ordered",
            WinMode::WinStriped => "win_striped",
            WinMode::PassiveShared => "passive_shared",
            WinMode::PassiveExclusive => "passive_excl",
            WinMode::PassiveNoLocks => "passive_no_locks",
        }
    }

    /// The lock kind a passive arm's batches run under (`None`: flush arm).
    fn lock_kind(&self) -> Option<LockKind> {
        match self {
            WinMode::WinOrdered | WinMode::WinStriped => None,
            WinMode::PassiveShared | WinMode::PassiveNoLocks => Some(LockKind::Shared),
            WinMode::PassiveExclusive => Some(LockKind::Exclusive),
        }
    }
}

#[derive(Clone)]
pub struct RmaRateParams {
    pub mode: WinMode,
    /// Threads per process; also the VCI pool size (lane 0 = fallback,
    /// lanes 1.. = stripe lanes, each with a dedicated target poller).
    pub threads: usize,
    /// Accumulate payload bytes (multiple of 8: SumU64 elements). Large
    /// payloads shift the bottleneck to target-side handling — exactly
    /// the term striping parallelizes.
    pub msg_size: usize,
    /// Accumulates issued by the one origin thread.
    pub msgs_per_core: usize,
    /// Outstanding-operation window between flushes.
    pub window: usize,
    pub cfg_override: Option<MpiConfig>,
}

impl Default for RmaRateParams {
    fn default() -> Self {
        RmaRateParams {
            mode: WinMode::WinOrdered,
            threads: 8,
            msg_size: 4096,
            msgs_per_core: 256,
            window: 32,
            cfg_override: None,
        }
    }
}

/// Info keys for the arm under test (empty = the default window policy).
fn win_info(mode: WinMode) -> Info {
    let striped = Info::new()
        .with("accumulate_ordering", "none")
        .with("vcmpi_striping", "rr")
        .with("vcmpi_rx_doorbell", "true");
    match mode {
        WinMode::WinOrdered | WinMode::PassiveExclusive => Info::new(),
        WinMode::WinStriped | WinMode::PassiveNoLocks => {
            striped.with("mpi_assert_no_locks", "true")
        }
        WinMode::PassiveShared => striped,
    }
}

/// Run the one-origin-thread RMA rate scenario; the report's `rate` is
/// accumulates/second of the single origin thread (virtual time).
pub fn rma_rate_run(p: RmaRateParams) -> RateReport {
    let fab = FabricConfig {
        interconnect: Interconnect::Opa,
        nodes: 2,
        procs_per_node: 1,
        max_contexts_per_node: 64,
    };
    let cfg = p.cfg_override.clone().unwrap_or_else(|| MpiConfig::optimized(p.threads));
    let tpp = p.threads;
    let mut spec = ClusterSpec::new(fab, cfg, tpp);
    spec.time_limit = Some(600_000_000_000);
    let p = Arc::new(p);
    let pp = p.clone();

    let wins: Arc<Mutex<HashMap<usize, Arc<crate::mpi::Window>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    let stops: Arc<Mutex<HashMap<usize, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        let mut s = stops.lock().unwrap();
        for proc in 0..2 {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
            s.insert(proc, Arc::new(AtomicBool::new(false)));
        }
    }

    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let world = proc.comm_world();
        let me = proc.rank();
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();
        let stop = stops.lock().unwrap().get(&me).unwrap().clone();
        let win_size = p.msg_size.max(8) * p.window;

        // ---- setup: collective window creation under the arm's policy ----
        if t == 0 {
            let win = proc.win_create_with_info(&world, win_size, &win_info(p.mode));
            wins.lock().unwrap().insert(me, win);
        }
        bar.wait();
        let win = wins.lock().unwrap().get(&me).unwrap().clone();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();

        // ---- measured phase ----
        if me == 0 {
            if t == 0 {
                // THE origin thread: flush-bounded accumulate batches.
                let t0 = crate::platform::pnow(proc.backend);
                let payload = vec![1u8; p.msg_size.max(8)];
                let batches = p.msgs_per_core / p.window;
                let kind = p.mode.lock_kind();
                for _ in 0..batches {
                    if let Some(k) = kind {
                        proc.win_lock(&win, k, 1);
                    }
                    for k in 0..p.window {
                        let offset = (k * p.msg_size.max(8)) % win_size;
                        proc.accumulate(&win, 1, offset, &payload, AccOp::SumU64);
                    }
                    if kind.is_some() {
                        // The unlock completes the batch (per-target flush
                        // waits) and releases the target-side lock.
                        proc.win_unlock(&win, 1);
                    } else {
                        proc.win_flush(&win);
                    }
                }
                let t1 = crate::platform::pnow(proc.backend);
                let msgs = p.msgs_per_core as f64;
                crate::mpi::world::record("rate", msgs / ((t1 - t0) as f64 / 1e9));
                // Release the target's pollers.
                proc.send(&world, 1, STOP_TAG, &[]);
            }
            // Other origin-side threads stay OUT of MPI: the claim under
            // test is a single origin thread's rate.
        } else if t == 0 {
            // Target rank, thread 0: wait out the origin (polls the
            // fallback lane; the hybrid fallback keeps liveness), then
            // release this process's pollers.
            let _ = proc.recv(&world, Src::Rank(0), Tag::Value(STOP_TAG));
            stop.store(true, Ordering::Release);
        } else {
            // Target pollers: thread t drives progress on lane t — the
            // shared-progress model that gives striped windows their
            // parallel handling (and the ordered arm its serialization:
            // only the home lane's poller ever finds work).
            let lane = t % proc.vcis().len();
            while !stop.load(Ordering::Acquire) {
                proc.progress_for_request(lane);
            }
        }
        bar.wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();

        if t == 0 {
            crate::mpi::world::record(
                format!("doorbell_skips_p{me}"),
                proc.doorbell_skip_count() as f64,
            );
            crate::mpi::world::record(format!("empty_polls_p{me}"), proc.empty_poll_count() as f64);
            crate::mpi::world::record(
                format!("stale_ctrl_drops_p{me}"),
                proc.stale_ctrl_drop_count() as f64,
            );
            crate::mpi::world::record(
                format!("win_lane_pinned_p{me}"),
                if proc.stripe_lane_pinned(win.vci) { 1.0 } else { 0.0 },
            );
            crate::mpi::world::record(
                format!("lock_elisions_p{me}"),
                proc.lock_elision_count() as f64,
            );
            crate::mpi::world::record(
                format!("lock_wire_reqs_p{me}"),
                proc.lock_wire_req_count() as f64,
            );
        }

        // ---- teardown ----
        bar.wait();
        if t == 0 {
            let mine = { wins.lock().unwrap().remove(&me) };
            if let Some(w) = mine {
                proc.win_free(&world, w);
            }
        }
    });
    assert_eq!(
        r.outcome,
        SimOutcome::Completed,
        "rma_rate run failed ({:?}): {:?}",
        p.mode,
        r.outcome
    );
    RateReport { rate: r.measurements["rate"], measurements: r.measurements }
}

/// Correctness probe for the CI gate: on a default (ordered) window, two
/// Replace accumulates from one origin to one location must apply in
/// program order — the later one wins. Striped windows relax this ONLY
/// via `accumulate_ordering=none`; the default path must never reorder.
pub fn ordered_window_program_order_preserved() -> bool {
    let fab = FabricConfig {
        interconnect: Interconnect::Opa,
        nodes: 2,
        procs_per_node: 1,
        max_contexts_per_node: 64,
    };
    let spec = ClusterSpec::new(fab, MpiConfig::optimized(4), 1);
    let r = run_cluster(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 64);
        if proc.rank() == 0 {
            proc.accumulate(&win, 1, 0, &[1u8; 8], AccOp::Replace);
            proc.accumulate(&win, 1, 0, &[2u8; 8], AccOp::Replace);
            proc.win_flush(&win);
            proc.send(&world, 1, 1, &[]);
        } else {
            let _ = proc.recv(&world, Src::Rank(0), Tag::Value(1));
            let got = win.read_local(0, 8);
            crate::mpi::world::record("last", got[0] as f64);
        }
        proc.win_free(&world, win);
    });
    r.outcome == SimOutcome::Completed && r.measurements.get("last").copied() == Some(2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn striped_window_beats_ordered_single_origin_thread() {
        // The §7 RMA tentpole ratio (the CI gate enforces it on the full
        // bench sizes): one origin thread's accumulate rate on a striped
        // window must beat the ordered-window baseline, because the
        // target-side handling parallelizes across the stripe lanes.
        let base = RmaRateParams { threads: 8, msgs_per_core: 256, ..Default::default() };
        let ordered = rma_rate_run(RmaRateParams { mode: WinMode::WinOrdered, ..base.clone() });
        let striped = rma_rate_run(RmaRateParams { mode: WinMode::WinStriped, ..base });
        assert!(
            striped.rate > ordered.rate,
            "striped window must lift a single origin thread: striped={:.0} ordered={:.0}",
            striped.rate,
            ordered.rate
        );
        assert_eq!(striped.sum_stat("stale_ctrl_drops"), 0.0);
        assert_eq!(ordered.sum_stat("stale_ctrl_drops"), 0.0);
        // Pin interaction: the ordered window protects its lane, the
        // striped window leaves its home lane in the stripe set.
        assert!(ordered.sum_stat("win_lane_pinned") > 0.0, "ordered window pins its lane");
        assert_eq!(striped.sum_stat("win_lane_pinned"), 0.0, "striped window does not pin");
        // The striped flush participates in doorbell-gated sweeps.
        assert!(striped.sum_stat("doorbell_skips") > 0.0, "doorbell-gated flush sweeps");
    }

    #[test]
    fn ordered_program_order_probe_holds() {
        assert!(ordered_window_program_order_preserved());
    }

    #[test]
    fn passive_arms_complete_and_no_locks_elides() {
        // Small sizes: the point here is completion + counter proof, not
        // the rate ratios (the CI bench gates check those at full size).
        let base = RmaRateParams { threads: 4, msgs_per_core: 64, window: 16, ..Default::default() };
        let shared =
            rma_rate_run(RmaRateParams { mode: WinMode::PassiveShared, ..base.clone() });
        let excl =
            rma_rate_run(RmaRateParams { mode: WinMode::PassiveExclusive, ..base.clone() });
        let elided = rma_rate_run(RmaRateParams { mode: WinMode::PassiveNoLocks, ..base });
        for r in [&shared, &excl, &elided] {
            assert!(r.rate > 0.0);
            assert_eq!(r.sum_stat("stale_ctrl_drops"), 0.0);
        }
        // The locked arms pay wire acquisitions and elide nothing; the
        // no_locks arm is the exact mirror.
        assert!(shared.sum_stat("lock_wire_reqs") > 0.0);
        assert_eq!(shared.sum_stat("lock_elisions"), 0.0);
        assert!(excl.sum_stat("lock_wire_reqs") > 0.0);
        assert!(elided.sum_stat("lock_elisions") > 0.0);
        assert_eq!(elided.sum_stat("lock_wire_reqs"), 0.0);
    }
}
