//! The collective-rate benchmark behind the segmented multi-lane
//! collectives tentpole: an allreduce is bulk-synchronous traffic — the
//! paper's "Scalable Communication Endpoints" line is that dedicated
//! channels matter *most* for exactly this pattern — yet the seed
//! implementation serialized every ring step through blocking wait pairs
//! on one lane. Two claims under test, on the 2x2-proc topology:
//!
//!  * [`CollMode::CollStriped`] vs [`CollMode::CollLockstep`]: the
//!    segmented multi-lane ring (`vcmpi_collectives=striped` +
//!    `vcmpi_coll_segments`) must beat the seed lockstep whole-chunk ring
//!    on identical payloads — segments pipeline injection/wire/handling,
//!    and per-lane poller threads (the shared-progress model) handle them
//!    in parallel instead of funneling through one lane's queue.
//!  * [`CollMode::CollDedicatedStorm`] vs [`CollMode::CollDedicated`]: a
//!    `vcmpi_collectives=dedicated` comm's allreduce rate must hold
//!    (>= 0.9x in the CI gate) under a concurrent striped p2p storm
//!    sharing the pool — the reserved lane is pinned out of the stripe
//!    set, so the storm can never head-of-line-block a collective step.
//!
//! Deterministic DES runs; the headline `rate` is reduced f32 elements
//! per second of the collective thread (virtual time).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, Comm, Info, MpiConfig, Src, Tag};
use crate::platform::{Backend, PBarrier};
use crate::sim::SimOutcome;

use super::message_rate::RateReport;

/// Collectives-policy arm under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollMode {
    /// Seed baseline: the lockstep whole-chunk ring on an ordinary
    /// (ordered) dup of MPI_COMM_WORLD — blocking wait pairs, one lane.
    CollLockstep,
    /// Segmented multi-lane: `vcmpi_collectives=striped` spreads each
    /// step's segments over the pool by the envelope hash.
    CollStriped,
    /// Dedicated-lane comm (`vcmpi_collectives=dedicated`), quiet pool —
    /// the baseline the storm arm is measured against.
    CollDedicated,
    /// Dedicated-lane comm under a concurrent striped p2p storm on a
    /// second, info-keyed hot communicator sharing the pool.
    CollDedicatedStorm,
}

impl CollMode {
    pub fn label(&self) -> &'static str {
        match self {
            CollMode::CollLockstep => "coll_lockstep",
            CollMode::CollStriped => "coll_striped",
            CollMode::CollDedicated => "coll_dedicated",
            CollMode::CollDedicatedStorm => "coll_dedicated_storm",
        }
    }
}

#[derive(Clone)]
pub struct CollRateParams {
    pub mode: CollMode,
    /// Threads per process: thread 0 drives the collective; threads 1..
    /// are per-lane pollers (lockstep/striped arms), storm workers
    /// (the storm arm), or idle (the quiet dedicated arm). Also the VCI
    /// pool size (lane 0 = fallback).
    pub threads: usize,
    /// f32 elements per allreduce. Sized so the lockstep arm's whole
    /// ring chunks exceed the rendezvous threshold while segments stay
    /// eager — the protocol split segmentation wins on.
    pub elems: usize,
    /// Allreduces measured.
    pub reps: usize,
    /// `vcmpi_coll_segments` for the segmented arms.
    pub segments: usize,
    /// Striped p2p messages per storm thread (the storm arm only).
    pub storm_msgs: usize,
    pub cfg_override: Option<MpiConfig>,
}

impl Default for CollRateParams {
    fn default() -> Self {
        CollRateParams {
            mode: CollMode::CollLockstep,
            threads: 8,
            elems: 32 * 1024,
            reps: 8,
            segments: 8,
            storm_msgs: 256,
            cfg_override: None,
        }
    }
}

/// Info keys of the collective comm for the arm under test.
fn coll_info(mode: CollMode, segments: usize) -> Info {
    match mode {
        CollMode::CollLockstep => Info::new(),
        CollMode::CollStriped => Info::new()
            .with("vcmpi_collectives", "striped")
            .with("vcmpi_coll_segments", segments.to_string()),
        CollMode::CollDedicated | CollMode::CollDedicatedStorm => Info::new()
            .with("vcmpi_collectives", "dedicated")
            .with("vcmpi_coll_segments", segments.to_string()),
    }
}

/// Run the collective-rate scenario; the report's `rate` is reduced f32
/// elements per second of one collective thread (virtual time).
pub fn coll_rate_run(p: CollRateParams) -> RateReport {
    let fab = FabricConfig {
        interconnect: Interconnect::Opa,
        nodes: 2,
        procs_per_node: 2,
        max_contexts_per_node: 64,
    };
    let tpp = p.threads;
    let cfg = p.cfg_override.clone().unwrap_or_else(|| MpiConfig::optimized(tpp));
    let mut spec = ClusterSpec::new(fab, cfg, tpp);
    spec.time_limit = Some(600_000_000_000);
    let p = Arc::new(p);
    let pp = p.clone();

    // Per-proc shared state: (collective comm, storm comm).
    type CommMap = HashMap<usize, Vec<Comm>>;
    let comms: Arc<Mutex<CommMap>> = Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    let stops: Arc<Mutex<HashMap<usize, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        let mut s = stops.lock().unwrap();
        for proc in 0..4 {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
            s.insert(proc, Arc::new(AtomicBool::new(false)));
        }
    }

    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let world = proc.comm_world();
        let me = proc.rank();
        let half = proc.nprocs() / 2;
        let is_sender_proc = me < half;
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();
        let stop = stops.lock().unwrap().get(&me).unwrap().clone();
        let dedicated = matches!(p.mode, CollMode::CollDedicated | CollMode::CollDedicatedStorm);

        // ---- setup: the collective comm, plus the storm comm for both
        // dedicated arms (identical lane layout; only the storm arm
        // drives traffic over it) ----
        if t == 0 {
            let coll = proc.comm_dup_with_info(&world, &coll_info(p.mode, p.segments));
            let mut v = vec![coll];
            if dedicated {
                v.push(proc.comm_dup_with_info(
                    &world,
                    &Info::new()
                        .with("vcmpi_striping", "rr")
                        .with("vcmpi_match_shards", "8")
                        .with("vcmpi_rx_doorbell", "true"),
                ));
            }
            comms.lock().unwrap().insert(me, v);
        }
        bar.wait();
        let coll = comms.lock().unwrap().get(&me).unwrap()[0].clone();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();

        // ---- measured phase ----
        if t == 0 {
            // The collective thread: back-to-back allreduces.
            let t0 = crate::platform::pnow(proc.backend);
            let mut data: Vec<f32> = (0..p.elems).map(|i| (me + i) as f32).collect();
            for _ in 0..p.reps {
                match p.mode {
                    CollMode::CollLockstep => proc.allreduce_f32_lockstep(&coll, &mut data),
                    _ => proc.allreduce_f32(&coll, &mut data),
                }
            }
            let t1 = crate::platform::pnow(proc.backend);
            if me == 0 {
                let reduced = (p.reps * p.elems) as f64;
                crate::mpi::world::record("rate", reduced / ((t1 - t0) as f64 / 1e9));
            }
            // Sync all procs out of the measured phase, then release this
            // process's pollers.
            proc.barrier(&world);
            stop.store(true, Ordering::Release);
        } else {
            match p.mode {
                CollMode::CollLockstep | CollMode::CollStriped => {
                    // Per-lane pollers (the shared-progress model): thread
                    // t drives progress on lane t, so multi-lane segments
                    // are handled in parallel — and the lockstep arm's
                    // single lane by a single poller.
                    let lane = t % proc.vcis().len();
                    while !stop.load(Ordering::Acquire) {
                        proc.progress_for_request(lane);
                    }
                }
                CollMode::CollDedicated => {
                    // Quiet pool: the collective thread polls its own
                    // dedicated lane; nothing else runs.
                }
                CollMode::CollDedicatedStorm => {
                    // Striped p2p storm on the hot comm, concurrent with
                    // the dedicated-lane allreduces: sender procs blast
                    // the mirror proc on the other node.
                    let hot = comms.lock().unwrap().get(&me).unwrap()[1].clone();
                    let payload = vec![0u8; 1024];
                    let window = 32;
                    let batches = p.storm_msgs / window;
                    if is_sender_proc {
                        for _ in 0..batches {
                            let reqs: Vec<_> = (0..window)
                                .map(|_| {
                                    proc.isend_ep(
                                        &hot,
                                        None,
                                        me + half,
                                        t as i32,
                                        &payload,
                                        false,
                                    )
                                })
                                .collect();
                            proc.waitall(reqs);
                        }
                    } else {
                        for _ in 0..batches {
                            let reqs: Vec<_> = (0..window)
                                .map(|_| {
                                    proc.irecv_ep(
                                        &hot,
                                        None,
                                        Src::Rank(me - half),
                                        Tag::Value(t as i32),
                                    )
                                })
                                .collect();
                            proc.waitall(reqs);
                        }
                    }
                }
            }
        }
        bar.wait();

        // ---- proof points + teardown ----
        if t == 0 {
            crate::mpi::world::record(
                format!("stale_ctrl_drops_p{me}"),
                proc.stale_ctrl_drop_count() as f64,
            );
            crate::mpi::world::record(
                format!("policy_mismatch_p{me}"),
                proc.policy_mismatch_count() as f64,
            );
            if dedicated {
                // The reserved lane is pinned while the comm lives...
                let lane = proc.dedicated_coll_lane(&coll);
                crate::mpi::world::record(
                    format!("coll_lane_pinned_p{me}"),
                    if proc.stripe_lane_pinned(lane) { 1.0 } else { 0.0 },
                );
                let mine = { comms.lock().unwrap().remove(&me) };
                if let Some(v) = mine {
                    for c in v {
                        proc.comm_free(c);
                    }
                }
                // ...and released at comm_free (the acceptance tripwire).
                crate::mpi::world::record(
                    format!("coll_lane_released_p{me}"),
                    if proc.stripe_lane_pinned(lane) { 0.0 } else { 1.0 },
                );
            } else {
                let mine = { comms.lock().unwrap().remove(&me) };
                if let Some(v) = mine {
                    for c in v {
                        proc.comm_free(c);
                    }
                }
            }
        }
    });
    assert_eq!(
        r.outcome,
        SimOutcome::Completed,
        "coll_rate run failed ({:?}): {:?}",
        p.mode,
        r.outcome
    );
    RateReport { rate: r.measurements["rate"], measurements: r.measurements }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segmented_multilane_allreduce_beats_lockstep_ring() {
        // The collectives tentpole ratio (the CI gate enforces it at the
        // full bench sizes): the segmented multi-lane ring must beat the
        // seed lockstep whole-chunk ring on identical payloads.
        let base = CollRateParams {
            threads: 6,
            elems: 32 * 1024,
            reps: 4,
            segments: 8,
            ..Default::default()
        };
        let lockstep =
            coll_rate_run(CollRateParams { mode: CollMode::CollLockstep, ..base.clone() });
        let striped = coll_rate_run(CollRateParams { mode: CollMode::CollStriped, ..base });
        assert!(
            striped.rate > lockstep.rate,
            "segmented multi-lane allreduce must beat the lockstep ring: \
             striped={:.0} lockstep={:.0}",
            striped.rate,
            lockstep.rate
        );
        assert_eq!(striped.sum_stat("stale_ctrl_drops"), 0.0);
        assert_eq!(striped.sum_stat("policy_mismatch"), 0.0);
    }

    #[test]
    fn dedicated_lane_allreduce_survives_striped_storm() {
        // The dedicated-lane claim: a concurrent striped p2p storm on the
        // same pool must not crater the allreduce (the CI gate enforces
        // the strict 0.9x budget; this tier-1 test uses a lenient floor),
        // and the reserved lane is pinned while the comm lives and
        // released at comm_free.
        let base = CollRateParams {
            threads: 6,
            elems: 8 * 1024,
            reps: 4,
            segments: 4,
            storm_msgs: 128,
            ..Default::default()
        };
        let quiet = coll_rate_run(CollRateParams { mode: CollMode::CollDedicated, ..base.clone() });
        let storm =
            coll_rate_run(CollRateParams { mode: CollMode::CollDedicatedStorm, ..base });
        assert!(
            storm.rate > 0.5 * quiet.rate,
            "dedicated-lane allreduce fell off a cliff under the storm: \
             storm={:.0} quiet={:.0}",
            storm.rate,
            quiet.rate
        );
        assert_eq!(storm.sum_stat("coll_lane_pinned"), 4.0, "all 4 procs pin the lane");
        assert_eq!(storm.sum_stat("coll_lane_released"), 4.0, "comm_free releases the pin");
        assert_eq!(storm.sum_stat("policy_mismatch"), 0.0, "wire contract holds");
        assert_eq!(storm.sum_stat("stale_ctrl_drops"), 0.0);
    }
}
