//! The communication-intensive message-rate benchmark of paper §5:
//! "the maximum rate at which multiple cores can inject messages into the
//! network simultaneously. Each core on the host node targets a distinct
//! core on the remote node."
//!
//! Six modes of execution (paper §5) plus config overrides for the §4.3
//! ablations (Figs. 5-8, 12), plus the striped scenario: ONE communicator
//! shared by every thread with per-message VCI striping — the step beyond
//! both par_comm (N communicators) and user-visible endpoints.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, Comm, Info, MpiConfig, MpiProc, Src, Tag};
use crate::platform::{Backend, PBarrier};
use crate::sim::SimOutcome;

/// Execution modes from paper §5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// MPI everywhere: one single-threaded process per core.
    Everywhere,
    /// MPI+threads, no exposed parallelism, original (1 VCI, Global CS).
    SerCommOrig,
    /// MPI+threads, no exposed parallelism, optimized multi-VCI library.
    SerCommVcis,
    /// MPI+threads, ONE shared communicator with per-message VCI striping
    /// (receiver-side seq reordering restores nonovertaking): the
    /// single-communicator answer to par_comm/endpoints. Single matching
    /// shard + round-robin sweep — the PR-1 "home engine" arm.
    SerCommStriped,
    /// Striping with per-source **sharded** matching and doorbell-gated
    /// progress, on a multi-source topology (2 sender procs x 2 receiver
    /// procs): striped arrivals match on the VCI they land on, per-source
    /// shards in parallel.
    SerCommStripedSharded,
    /// Sharded striping under a wildcard storm: receiver threads
    /// periodically post MPI_ANY_SOURCE receives, driving the serialized
    /// wildcard-epoch protocol through continuous flip/unflip cycles.
    SerCommStripedWildcard,
    /// Mixed per-communicator policies (the per-comm policy tentpole):
    /// the same multi-source topology as `SerCommStripedSharded`, but the
    /// process config leaves striping OFF and the hot communicator opts
    /// in via MPI-4 info keys (`vcmpi_striping=rr`, `vcmpi_match_shards=8`,
    /// `vcmpi_rx_doorbell=true`), while one extra thread per process runs
    /// latency ping-pongs on a second, default-policy (ordered)
    /// communicator whose VCI is pinned out of the stripe lanes.
    SerCommMixedPolicy,
    /// MPI+threads, per-thread communicators/windows, original library.
    ParCommOrig,
    /// MPI+threads, per-thread communicators/windows, multi-VCI library.
    ParCommVcis,
    /// Serial execution streams: the `par_comm+vcis` topology (per-thread
    /// communicators, one VCI each), but every communicator carries
    /// `vcmpi_stream=local` and its thread binds it with `stream_bind`
    /// before the measured phase — so every measured isend/irecv/wait
    /// takes the lock-free single-writer fast path. The Table-1 probe
    /// records the measured phase's lock counts (`t1_vci_locks` et al
    /// must be ZERO here, nonzero on the locked twin) and the CI gate
    /// demands rate > `par_comm+vcis`.
    SerCommStreamed,
    /// MPI+threads with user-visible endpoints (one per thread).
    Endpoints,
}

impl Mode {
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Everywhere => "everywhere",
            Mode::SerCommOrig => "ser_comm+orig_mpich",
            Mode::SerCommVcis => "ser_comm+vcis",
            Mode::SerCommStriped => "ser_comm+striped",
            Mode::SerCommStripedSharded => "ser_comm+striped_sharded",
            Mode::SerCommStripedWildcard => "ser_comm+striped_wildcard",
            Mode::SerCommMixedPolicy => "ser_comm+mixed_policy",
            Mode::ParCommOrig => "par_comm+orig_mpich",
            Mode::ParCommVcis => "par_comm+vcis",
            Mode::SerCommStreamed => "par_comm+streamed",
            Mode::Endpoints => "endpoints",
        }
    }

    /// The paper's six execution modes (§5). The striped / sharded /
    /// wildcard-storm modes are this repo's post-paper extensions and are
    /// deliberately NOT included, so the fig10/11/13 reproductions keep
    /// the paper's exact series; the striping scenarios have their own
    /// bench section (the CI gate) and tests.
    pub fn all() -> [Mode; 6] {
        [
            Mode::Everywhere,
            Mode::SerCommOrig,
            Mode::SerCommVcis,
            Mode::ParCommOrig,
            Mode::ParCommVcis,
            Mode::Endpoints,
        ]
    }
}

/// Operation under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Isend,
    Put,
}

#[derive(Clone)]
pub struct RateParams {
    pub mode: Mode,
    pub interconnect: Interconnect,
    /// Cores per node engaged (threads for MPI+threads, processes for
    /// MPI everywhere).
    pub threads: usize,
    pub msg_size: usize,
    /// Messages issued per core.
    pub msgs_per_core: usize,
    /// Outstanding-operations window (batch size between waitalls/flushes).
    pub window: usize,
    pub op: Op,
    /// Override the derived MpiConfig (ablations).
    pub cfg_override: Option<MpiConfig>,
}

impl Default for RateParams {
    fn default() -> Self {
        RateParams {
            mode: Mode::ParCommVcis,
            interconnect: Interconnect::Opa,
            threads: 16,
            msg_size: 8,
            msgs_per_core: 1500,
            window: 64,
            op: Op::Isend,
            cfg_override: None,
        }
    }
}

/// Derive (fabric topology, mpi config, threads per proc) for a mode.
fn derive(p: &RateParams) -> (FabricConfig, MpiConfig, usize) {
    let t = p.threads;
    let fabric = |ppn: usize| FabricConfig {
        interconnect: p.interconnect,
        nodes: 2,
        procs_per_node: ppn,
        max_contexts_per_node: 64,
    };
    let (fab, cfg, tpp) = match p.mode {
        Mode::Everywhere => (fabric(t), MpiConfig::everywhere(), 1),
        Mode::SerCommOrig | Mode::ParCommOrig => (fabric(1), MpiConfig::original(), t),
        Mode::SerCommVcis | Mode::ParCommVcis => (fabric(1), MpiConfig::optimized(t + 1), t),
        Mode::SerCommStriped => (fabric(1), MpiConfig::striped(t + 1), t),
        // Multi-source: 2 procs per node, so each receiver proc matches
        // striped streams from 2 sender procs — the per-source shards
        // (and the doorbell-gated sweep) are what this mode measures.
        Mode::SerCommStripedSharded => (fabric(2), MpiConfig::striped_sharded(t + 1), t),
        Mode::SerCommStripedWildcard => (fabric(1), MpiConfig::striped_sharded(t + 1), t),
        // Process default is NOT striped: the hot comm's policy comes
        // entirely from info keys. t striped threads + 1 ordered thread;
        // t+2 VCIs = fallback + the ordered comm's pinned lane + t stripe
        // lanes (the same lane count as the pure sharded arm).
        Mode::SerCommMixedPolicy => (fabric(2), MpiConfig::optimized(t + 2), t + 1),
        // Identical shape to ParCommVcis so the rate ratio isolates the
        // stream layer's lock elision (same lanes, same traffic).
        Mode::SerCommStreamed => (fabric(1), MpiConfig::optimized(t + 1), t),
        // +1 VCI: endpoints come from the pool (fallback excluded).
        Mode::Endpoints => (fabric(1), MpiConfig::optimized(t + 1), t),
    };
    let cfg = p.cfg_override.clone().unwrap_or(cfg);
    (fab, cfg, tpp)
}

/// Detailed result of one message-rate run: the headline rate plus every
/// measurement the workload recorded (per-proc engine diagnostics —
/// epoch flips, doorbell skips, empty polls, drop counters — under
/// `<name>_p<rank>` keys).
#[derive(Clone, Debug)]
pub struct RateReport {
    pub rate: f64,
    pub measurements: HashMap<String, f64>,
}

impl RateReport {
    /// Sum a per-proc diagnostic over all ranks (`prefix` without the
    /// `_p<rank>` suffix).
    pub fn sum_stat(&self, prefix: &str) -> f64 {
        self.measurements
            .iter()
            .filter(|(k, _)| {
                k.strip_prefix(prefix)
                    .is_some_and(|rest| rest.starts_with("_p"))
            })
            .map(|(_, v)| *v)
            .sum()
    }
}

/// Run the benchmark; returns aggregate messages/second (virtual time).
pub fn message_rate(p: RateParams) -> f64 {
    message_rate_run(p).rate
}

/// Run the benchmark and return the full [`RateReport`].
pub fn message_rate_run(p: RateParams) -> RateReport {
    let (fab, cfg, tpp) = derive(&p);
    let nodes_procs = fab.procs_per_node;
    let mut spec = ClusterSpec::new(fab, cfg, tpp);
    spec.time_limit = Some(600_000_000_000);
    let p = Arc::new(p);
    let pp = p.clone();

    // Shared setup state (comms / windows / endpoints), per process.
    type CommMap = HashMap<usize, Vec<Comm>>;
    let comms: Arc<Mutex<CommMap>> = Arc::new(Mutex::new(HashMap::new()));
    let wins: Arc<Mutex<HashMap<usize, Vec<Arc<crate::mpi::Window>>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let eps: Arc<Mutex<HashMap<usize, Comm>>> = Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        for proc in 0..2 * nodes_procs {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
        }
    }
    // Cluster-wide quiesce barrier for the streamed arm's Table-1 probe: a
    // sim-object barrier spanning every thread of every proc (NOT an MPI
    // barrier — it must not touch any locked comm path). It brackets the
    // lock-count snapshots so no rank's locked world-barrier traffic can
    // leak into another rank's probe window through the shared counters.
    let probe_bar = Arc::new(PBarrier::new(Backend::Sim, 2 * nodes_procs * tpp));

    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let world = proc.comm_world();
        let me = proc.rank();
        let nprocs = proc.nprocs();
        let half = nprocs / 2;
        let is_sender_proc = me < half;
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();

        // ---- setup: communication channels per mode ----
        if t == 0 {
            match p.mode {
                Mode::ParCommOrig | Mode::ParCommVcis => {
                    let v: Vec<Comm> = (0..p.threads).map(|_| proc.comm_dup(&world)).collect();
                    comms.lock().unwrap().insert(me, v);
                }
                Mode::SerCommStreamed => {
                    // Per-thread comms, each declared a serial execution
                    // stream; the owning thread binds its own below
                    // (binding is a calling-thread property).
                    let v: Vec<Comm> = (0..p.threads)
                        .map(|_| {
                            proc.comm_dup_with_info(
                                &world,
                                &Info::new().with("vcmpi_stream", "local"),
                            )
                        })
                        .collect();
                    comms.lock().unwrap().insert(me, v);
                }
                Mode::Endpoints => {
                    let ep = proc.create_endpoints(&world, p.threads);
                    eps.lock().unwrap().insert(me, ep);
                }
                Mode::SerCommMixedPolicy => {
                    // Creation order matters for symmetric VCI assignment:
                    // the hot comm takes lane 1 (its home), the ordered
                    // comm takes lane 2 (pinned out of the stripe set).
                    let hot = proc.comm_dup_with_info(
                        &world,
                        &Info::new()
                            .with("vcmpi_striping", "rr")
                            .with("vcmpi_match_shards", "8")
                            .with("vcmpi_rx_doorbell", "true"),
                    );
                    let ordered = proc.comm_dup(&world);
                    comms.lock().unwrap().insert(me, vec![hot, ordered]);
                }
                _ => {}
            }
            if p.op == Op::Put {
                let per_thread_wins = matches!(
                    p.mode,
                    Mode::ParCommOrig | Mode::ParCommVcis | Mode::Endpoints
                );
                let n_wins = if per_thread_wins { p.threads } else { 1 };
                let v: Vec<Arc<crate::mpi::Window>> = (0..n_wins)
                    .map(|_| proc.win_create(&world, p.msg_size.max(8) * p.threads * 2))
                    .collect();
                wins.lock().unwrap().insert(me, v);
            }
        }
        // Funneled world barrier (collectives are per-process ops; only
        // one thread may drive a given communicator's collective).
        bar.wait();
        if p.mode == Mode::SerCommStreamed && t < p.threads {
            // Bind outside the measured window: the bind's one locked
            // ownership transition must not pollute the zero-lock claim.
            let c = comms.lock().unwrap().get(&me).unwrap()[t].clone();
            proc.stream_bind(&c);
        }
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();

        // ---- the measured phase ----
        // Table-1 probe: snapshot the critical-path counters around the
        // measured phase, on BOTH twins — the locked par_comm+vcis arm and
        // the streamed arm — so the bench can print per-op lock/atomic
        // costs side by side. On the Sim backend these thread-locals are
        // shared by every simulated thread (one OS thread runs them all),
        // so the diff counts the WHOLE cluster's measured-phase lock
        // traffic — which is exactly the claim: zero VCI/Request/Global
        // acquisitions while every thread drives its stream. The probe
        // barrier guarantees every rank's (locked) world barrier fully
        // retired before any base snapshot is taken.
        let probed = matches!(p.mode, Mode::SerCommStreamed | Mode::ParCommVcis);
        if probed {
            probe_bar.wait();
        }
        let table1 = if probed && t == 0 {
            Some(crate::mpi::instrument::snapshot())
        } else {
            None
        };
        let t0 = crate::platform::pnow(proc.backend);
        match p.op {
            Op::Isend if p.mode == Mode::SerCommStripedSharded => {
                // Multi-source sharding workload: every sender-node proc's
                // thread alternates between BOTH receiver procs, so each
                // receiver matches striped streams from `half` distinct
                // sources concurrently — one matching shard per source.
                let payload = vec![0u8; p.msg_size];
                let batches = p.msgs_per_core / p.window;
                debug_assert_eq!(p.window % half, 0, "window must split over receivers");
                if is_sender_proc {
                    for _ in 0..batches {
                        let reqs: Vec<_> = (0..p.window)
                            .map(|k| {
                                let dst = half + k % half;
                                proc.isend_ep(&world, None, dst, t as i32, &payload, false)
                            })
                            .collect();
                        proc.waitall(reqs);
                    }
                } else {
                    for _ in 0..batches {
                        let reqs: Vec<_> = (0..p.window)
                            .map(|k| {
                                let src = k % half;
                                proc.irecv_ep(
                                    &world,
                                    None,
                                    Src::Rank(src),
                                    Tag::Value(t as i32),
                                )
                            })
                            .collect();
                        proc.waitall(reqs);
                    }
                }
            }
            Op::Isend if p.mode == Mode::SerCommMixedPolicy => {
                let (hot, ordered) = {
                    let m = comms.lock().unwrap();
                    let v = m.get(&me).unwrap();
                    (v[0].clone(), v[1].clone())
                };
                let payload = vec![0u8; p.msg_size];
                if t == p.threads {
                    // The ordered lane: latency ping-pongs on the
                    // default-policy communicator, concurrent with the
                    // striped storm, between mirror procs across nodes.
                    let rounds = (p.msgs_per_core / 32).max(2);
                    if is_sender_proc {
                        for _ in 0..rounds {
                            proc.send(&ordered, me + half, 1000, &payload);
                            let _ = proc.recv(&ordered, Src::Rank(me + half), Tag::Value(1001));
                        }
                    } else {
                        for _ in 0..rounds {
                            let _ = proc.recv(&ordered, Src::Rank(me - half), Tag::Value(1000));
                            proc.send(&ordered, me - half, 1001, &payload);
                        }
                    }
                } else {
                    // The hot lane: identical multi-source sharded
                    // workload to `SerCommStripedSharded`, driven by the
                    // info-keyed communicator.
                    let batches = p.msgs_per_core / p.window;
                    debug_assert_eq!(p.window % half, 0, "window must split over receivers");
                    if is_sender_proc {
                        for _ in 0..batches {
                            let reqs: Vec<_> = (0..p.window)
                                .map(|k| {
                                    let dst = half + k % half;
                                    proc.isend_ep(&hot, None, dst, t as i32, &payload, false)
                                })
                                .collect();
                            proc.waitall(reqs);
                        }
                    } else {
                        for _ in 0..batches {
                            let reqs: Vec<_> = (0..p.window)
                                .map(|k| {
                                    let src = k % half;
                                    proc.irecv_ep(&hot, None, Src::Rank(src), Tag::Value(t as i32))
                                })
                                .collect();
                            proc.waitall(reqs);
                        }
                    }
                }
            }
            Op::Isend if p.mode == Mode::SerCommStripedWildcard => {
                // Wildcard storm: every 4th receive is MPI_ANY_SOURCE, so
                // the communicator continuously flips into and out of the
                // serialized wildcard epoch while striped traffic flows.
                let payload = vec![0u8; p.msg_size];
                let batches = p.msgs_per_core / p.window;
                let peer = 1 - me;
                if is_sender_proc {
                    for _ in 0..batches {
                        let reqs: Vec<_> = (0..p.window)
                            .map(|_| {
                                proc.isend_ep(&world, None, peer, t as i32, &payload, false)
                            })
                            .collect();
                        proc.waitall(reqs);
                    }
                } else {
                    for _ in 0..batches {
                        let reqs: Vec<_> = (0..p.window)
                            .map(|k| {
                                let src =
                                    if k % 4 == 3 { Src::Any } else { Src::Rank(peer) };
                                proc.irecv_ep(&world, None, src, Tag::Value(t as i32))
                            })
                            .collect();
                        proc.waitall(reqs);
                    }
                }
            }
            Op::Isend => {
                // Pairing: everywhere: proc i <-> proc half+i (tag 0);
                // threads: thread t <-> thread t (tag t).
                let (comm, my_ep, peer_rank, tag) = match p.mode {
                    Mode::Everywhere => {
                        let peer = if is_sender_proc { me + half } else { me - half };
                        (world.clone(), None, peer, 0i32)
                    }
                    // The guard-matched modes above never reach here;
                    // listed for exhaustiveness.
                    Mode::SerCommOrig
                    | Mode::SerCommVcis
                    | Mode::SerCommStriped
                    | Mode::SerCommStripedSharded
                    | Mode::SerCommStripedWildcard
                    | Mode::SerCommMixedPolicy => {
                        let peer = 1 - me;
                        (world.clone(), None, peer, t as i32)
                    }
                    Mode::ParCommOrig | Mode::ParCommVcis | Mode::SerCommStreamed => {
                        let c = comms.lock().unwrap().get(&me).unwrap()[t].clone();
                        (c, None, 1 - me, t as i32)
                    }
                    Mode::Endpoints => {
                        let ep = eps.lock().unwrap().get(&me).unwrap().clone();
                        let peer_proc = 1 - me;
                        let peer = peer_proc * p.threads + t;
                        (ep, Some(t), peer, t as i32)
                    }
                };
                let payload = vec![0u8; p.msg_size];
                let batches = p.msgs_per_core / p.window;
                if is_sender_proc {
                    for _ in 0..batches {
                        let reqs: Vec<_> = (0..p.window)
                            .map(|_| {
                                proc.isend_ep(&comm, my_ep, peer_rank, tag, &payload, false)
                            })
                            .collect();
                        proc.waitall(reqs);
                    }
                } else {
                    for _ in 0..batches {
                        let reqs: Vec<_> = (0..p.window)
                            .map(|_| {
                                proc.irecv_ep(&comm, my_ep, Src::Rank(peer_rank), Tag::Value(tag))
                            })
                            .collect();
                        proc.waitall(reqs);
                    }
                }
            }
            Op::Put => {
                // Senders put into the peer's window; receivers wait in an
                // MPI barrier (paper §5.2's benchmark shape).
                if is_sender_proc {
                    let (win, ep_vci) = put_channel(p, proc, t, &wins);
                    let peer = match p.mode {
                        // Multi-proc topologies: pair with the mirror proc
                        // on the other node.
                        Mode::Everywhere
                        | Mode::SerCommStripedSharded
                        | Mode::SerCommMixedPolicy => me + half,
                        _ => 1 - me,
                    };
                    let payload = vec![0u8; p.msg_size];
                    let offset = (t * p.msg_size.max(8)) % win.size.max(1);
                    let batches = p.msgs_per_core / p.window;
                    for _ in 0..batches {
                        for _ in 0..p.window {
                            proc.put_via(&win, ep_vci, peer, offset, &payload);
                        }
                        proc.win_flush(&win);
                    }
                }
            }
        }
        bar.wait();
        if probed {
            // Quiesce the whole cluster, snapshot, then quiesce again —
            // the locked world barrier below must not start anywhere
            // until every rank has ended its Table-1 window.
            probe_bar.wait();
        }
        if let Some(base) = table1 {
            // End the Table-1 window before the world barrier below (the
            // barrier rides the ordered world comm's locked path).
            let d = crate::mpi::instrument::snapshot() - base;
            crate::mpi::world::record(format!("t1_vci_locks_p{me}"), d.vci_locks as f64);
            crate::mpi::world::record(format!("t1_request_locks_p{me}"), d.request_locks as f64);
            crate::mpi::world::record(format!("t1_global_locks_p{me}"), d.global_locks as f64);
            crate::mpi::world::record(format!("t1_stream_ops_p{me}"), d.stream_ops as f64);
            crate::mpi::world::record(
                format!("t1_freelist_hits_p{me}"),
                d.stream_freelist_hits as f64,
            );
        }
        if probed {
            probe_bar.wait();
        }
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();
        let t1 = crate::platform::pnow(proc.backend);
        if me == 0 && t == 0 {
            // total sender cores:
            let cores = match p.mode {
                Mode::Everywhere => half,
                // Multi-source topology: `half` sender procs x threads
                // (the mixed mode's ordered thread is not counted — the
                // rate is the STRIPED comm's).
                Mode::SerCommStripedSharded | Mode::SerCommMixedPolicy => half * p.threads,
                _ => p.threads,
            } as f64;
            let msgs = cores * p.msgs_per_core as f64;
            crate::mpi::world::record("rate", msgs / ((t1 - t0) as f64 / 1e9));
        }
        if t == 0 {
            // Per-proc engine diagnostics for the bench JSON (summable
            // across ranks via `RateReport::sum_stat`).
            let (dups, _parked) = proc.reorder_stats();
            let es = proc.epoch_stats();
            crate::mpi::world::record(format!("epoch_flips_p{me}"), es.flips as f64);
            crate::mpi::world::record(format!("epoch_unflips_p{me}"), es.unflips as f64);
            crate::mpi::world::record(format!("wildcard_posts_p{me}"), es.wildcard_posts as f64);
            crate::mpi::world::record(
                format!("doorbell_skips_p{me}"),
                proc.doorbell_skip_count() as f64,
            );
            crate::mpi::world::record(format!("empty_polls_p{me}"), proc.empty_poll_count() as f64);
            crate::mpi::world::record(
                format!("stale_ctrl_drops_p{me}"),
                proc.stale_ctrl_drop_count() as f64,
            );
            crate::mpi::world::record(format!("dup_seq_drops_p{me}"), dups as f64);
            if p.mode == Mode::SerCommMixedPolicy {
                // Per-comm policy proof points: the info-keyed comm grew a
                // sharded engine on the receive side, the ordered comm
                // never did, and no wire-contract mismatch was seen.
                let m = comms.lock().unwrap();
                if let Some(v) = m.get(&me) {
                    crate::mpi::world::record(
                        format!("striped_engine_p{me}"),
                        if proc.has_match_engine(v[0].id) { 1.0 } else { 0.0 },
                    );
                    crate::mpi::world::record(
                        format!("ordered_striped_engine_p{me}"),
                        if proc.has_match_engine(v[1].id) { 1.0 } else { 0.0 },
                    );
                    crate::mpi::world::record(
                        format!("policy_mismatch_p{me}"),
                        proc.policy_mismatch_count() as f64,
                    );
                }
            }
        }

        // ---- teardown ----
        bar.wait();
        if p.mode == Mode::SerCommStreamed && t < p.threads {
            // Each stream's OWNER must free (and thereby unbind) its own
            // comm — only the binding thread may tear a stream down, and
            // finalize asserts no lane is left stream-owned.
            let mine = { comms.lock().unwrap().get(&me).unwrap()[t].clone() };
            proc.comm_free(mine);
        }
        if t == 0 {
            // Host lock must not be held across collective win_free (see
            // apps::ebms teardown comment).
            let mine = { wins.lock().unwrap().remove(&me) };
            if let Some(v) = mine {
                for w in v {
                    proc.win_free(&world, w);
                }
            }
            if p.mode == Mode::SerCommMixedPolicy {
                // Free the policy comms: exercises the freed-comm engine /
                // cache teardown that finalize asserts.
                let mine = { comms.lock().unwrap().remove(&me) };
                if let Some(v) = mine {
                    for c in v {
                        proc.comm_free(c);
                    }
                }
            }
        }
    });
    assert_eq!(
        r.outcome,
        SimOutcome::Completed,
        "message_rate run failed ({:?}): {:?}",
        p.mode,
        r.outcome
    );
    RateReport { rate: r.measurements["rate"], measurements: r.measurements }
}

fn put_channel(
    p: &RateParams,
    proc: &Arc<MpiProc>,
    t: usize,
    wins: &Arc<Mutex<HashMap<usize, Vec<Arc<crate::mpi::Window>>>>>,
) -> (Arc<crate::mpi::Window>, Option<usize>) {
    let me = proc.rank();
    match p.mode {
        Mode::Everywhere
        | Mode::SerCommOrig
        | Mode::SerCommVcis
        | Mode::SerCommStriped
        | Mode::SerCommStripedSharded
        | Mode::SerCommStripedWildcard
        | Mode::SerCommMixedPolicy
        | Mode::SerCommStreamed => {
            // Streams accelerate the two-sided path; RMA windows stay on
            // the shared (locked) channel, so one window suffices.
            (wins.lock().unwrap().get(&me).unwrap()[0].clone(), None)
        }
        Mode::ParCommOrig | Mode::ParCommVcis => {
            (wins.lock().unwrap().get(&me).unwrap()[t].clone(), None)
        }
        Mode::Endpoints => {
            // Endpoint t drives its own VCI explicitly (paper: "each
            // endpoint is a VCI"); window t provides the memory handle.
            let win = wins.lock().unwrap().get(&me).unwrap()[t].clone();
            let ep_vci = Some(1 + t); // pool VCIs 1..=threads
            (win, ep_vci)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isend_rate_runs_and_is_positive() {
        let r = message_rate(RateParams {
            threads: 2,
            msgs_per_core: 256,
            window: 32,
            ..Default::default()
        });
        assert!(r > 0.0);
    }

    #[test]
    fn everywhere_beats_ser_comm_orig() {
        let base = RateParams {
            threads: 4,
            msgs_per_core: 512,
            window: 32,
            ..Default::default()
        };
        let ew = message_rate(RateParams { mode: Mode::Everywhere, ..base.clone() });
        let ser = message_rate(RateParams { mode: Mode::SerCommOrig, ..base });
        assert!(
            ew > 2.0 * ser,
            "everywhere ({ew:.0}) should dwarf ser_comm+orig ({ser:.0})"
        );
    }

    #[test]
    fn striped_single_comm_beats_single_vci_baseline() {
        // The tentpole claim: ONE hot communicator, multithreaded senders.
        // Unhinted ser_comm funnels everything through one VCI; striping
        // spreads the same traffic across the pool (with receiver-side
        // reordering) and must come out ahead.
        let base = RateParams {
            threads: 8,
            msgs_per_core: 512,
            window: 32,
            ..Default::default()
        };
        let striped = message_rate(RateParams { mode: Mode::SerCommStriped, ..base.clone() });
        let single = message_rate(RateParams { mode: Mode::SerCommVcis, ..base });
        assert!(
            striped > single,
            "striping should lift a single hot communicator: \
             striped={striped:.0} single_vci={single:.0}"
        );
    }

    #[test]
    fn striped_modes_complete_for_put_and_hashed() {
        // Put traffic under a striped config (RMA is out-of-stripe but
        // must coexist), and the hashed selection policy.
        let put = message_rate(RateParams {
            mode: Mode::SerCommStriped,
            threads: 2,
            msgs_per_core: 128,
            window: 32,
            op: Op::Put,
            ..Default::default()
        });
        assert!(put > 0.0);
        let mut cfg = crate::mpi::MpiConfig::striped(5);
        cfg.vci_striping = crate::mpi::VciStriping::HashedByRequest;
        let hashed = message_rate(RateParams {
            mode: Mode::SerCommStriped,
            threads: 4,
            msgs_per_core: 256,
            window: 32,
            cfg_override: Some(cfg),
            ..Default::default()
        });
        assert!(hashed > 0.0);
        // RMA stays out-of-stripe under the sharded config too.
        let put_sharded = message_rate(RateParams {
            mode: Mode::SerCommStripedSharded,
            threads: 2,
            msgs_per_core: 128,
            window: 32,
            op: Op::Put,
            ..Default::default()
        });
        assert!(put_sharded > 0.0);
    }

    #[test]
    fn sharded_matching_beats_home_engine_striped() {
        // The PR-2 tentpole ratio: per-source sharded matching + doorbell
        // polling vs PR 1's single home engine + round-robin sweep, on
        // identical multi-source striped traffic (2 sender procs).
        let base = RateParams {
            mode: Mode::SerCommStripedSharded,
            threads: 8,
            msgs_per_core: 512,
            window: 32,
            ..Default::default()
        };
        let sharded = message_rate_run(base.clone());
        let home = message_rate_run(RateParams {
            cfg_override: Some(crate::mpi::MpiConfig::striped(8 + 1)),
            ..base
        });
        assert!(
            sharded.rate > home.rate,
            "per-source sharding + rx doorbells must beat the home engine: \
             sharded={:.0} home={:.0}",
            sharded.rate,
            home.rate
        );
        assert!(
            sharded.sum_stat("doorbell_skips") > 0.0,
            "doorbell polling must skip empty sweeps"
        );
        assert_eq!(home.sum_stat("doorbell_skips"), 0.0, "home arm has no doorbell");
        assert_eq!(sharded.sum_stat("epoch_flips"), 0.0, "no wildcards -> no epochs");
        assert_eq!(sharded.sum_stat("dup_seq_drops"), 0.0);
        assert_eq!(sharded.sum_stat("stale_ctrl_drops"), 0.0);
    }

    #[test]
    fn mixed_policy_comms_coexist_in_one_process() {
        // The per-comm policy acceptance scenario: process-global striping
        // OFF, one hot comm striped+sharded via info keys, one ordered
        // comm on a pinned lane — concurrently. The hot comm must still
        // deliver striping-class rates (the CI bench gate enforces the
        // strict 10% budget; this test uses a lenient floor), and the
        // ordered comm must never touch the sharded path.
        let base = RateParams {
            mode: Mode::SerCommMixedPolicy,
            threads: 4,
            msgs_per_core: 256,
            window: 32,
            ..Default::default()
        };
        let mixed = message_rate_run(base.clone());
        assert!(mixed.rate > 0.0);
        assert!(mixed.sum_stat("striped_engine") > 0.0, "hot comm must shard on receivers");
        assert_eq!(
            mixed.sum_stat("ordered_striped_engine"),
            0.0,
            "the default-policy comm must stay off the sharded path"
        );
        assert_eq!(mixed.sum_stat("policy_mismatch"), 0.0, "wire contract must hold");
        assert!(mixed.sum_stat("doorbell_skips") > 0.0, "info-keyed doorbell participation");
        assert_eq!(mixed.sum_stat("epoch_flips"), 0.0, "no wildcards -> no epochs");
        assert_eq!(mixed.sum_stat("dup_seq_drops"), 0.0);
        let pure = message_rate_run(RateParams {
            mode: Mode::SerCommStripedSharded,
            ..base
        });
        assert!(
            mixed.rate > 0.5 * pure.rate,
            "mixed-policy striped comm fell off a cliff: mixed={:.0} pure={:.0}",
            mixed.rate,
            pure.rate
        );
    }

    #[test]
    fn wildcard_storm_exercises_epochs_and_completes() {
        let r = message_rate_run(RateParams {
            mode: Mode::SerCommStripedWildcard,
            threads: 4,
            msgs_per_core: 256,
            window: 32,
            ..Default::default()
        });
        assert!(r.rate > 0.0);
        assert!(r.sum_stat("wildcard_posts") > 0.0, "storm posts wildcards");
        assert!(r.sum_stat("epoch_flips") > 0.0, "wildcards must flip epochs");
        assert_eq!(
            r.sum_stat("epoch_flips"),
            r.sum_stat("epoch_unflips"),
            "every epoch must resolve by quiescence"
        );
        assert_eq!(r.sum_stat("dup_seq_drops"), 0.0);
    }

    #[test]
    fn streamed_beats_locked_par_comm_with_zero_locks() {
        // The PR-8 tentpole ratio AND the Table-1 zero-lock claim, on the
        // same topology: par_comm+vcis takes the SimMutex VCI lock and the
        // shared request cache for every op; par_comm+streamed binds each
        // thread to its comm's lane and must (a) come out ahead and
        // (b) acquire ZERO VCI/Request/Global locks inside the measured
        // window — the whole point of a serial execution stream.
        let base = RateParams {
            threads: 4,
            msgs_per_core: 512,
            window: 32,
            ..Default::default()
        };
        let streamed = message_rate_run(RateParams { mode: Mode::SerCommStreamed, ..base.clone() });
        let locked = message_rate_run(RateParams { mode: Mode::ParCommVcis, ..base });
        assert!(
            streamed.rate > locked.rate,
            "stream fast path must beat the locked twin on identical topology: \
             streamed={:.0} locked={:.0}",
            streamed.rate,
            locked.rate
        );
        // Table-1 columns: the probe brackets the measured phase with a
        // cluster-wide quiesce, so any nonzero count here is a real lock
        // acquisition on the streamed critical path.
        assert_eq!(streamed.sum_stat("t1_vci_locks"), 0.0, "VCI lock on stream path");
        assert_eq!(streamed.sum_stat("t1_request_locks"), 0.0, "request-cache lock on stream path");
        assert_eq!(streamed.sum_stat("t1_global_locks"), 0.0, "global lock on stream path");
        assert!(
            streamed.sum_stat("t1_stream_ops") > 0.0,
            "measured phase must actually ride the single-writer entry"
        );
        assert!(
            streamed.sum_stat("t1_freelist_hits") > 0.0,
            "receive-side allocs must come from the per-lane freelist"
        );
        // The locked twin pays for every op under the same probe: its VCI
        // lock column must be nonzero and its stream column zero.
        assert!(
            locked.sum_stat("t1_vci_locks") > 0.0,
            "locked twin must show per-op VCI acquisitions"
        );
        assert_eq!(locked.sum_stat("t1_stream_ops"), 0.0, "locked twin has no stream entries");
    }

    #[test]
    fn par_comm_vcis_scales_with_threads() {
        let base = RateParams {
            mode: Mode::ParCommVcis,
            msgs_per_core: 512,
            window: 32,
            ..Default::default()
        };
        let r1 = message_rate(RateParams { threads: 1, ..base.clone() });
        let r8 = message_rate(RateParams { threads: 8, ..base });
        assert!(
            r8 > 3.0 * r1,
            "8 threads ({r8:.0}) should scale over 1 thread ({r1:.0})"
        );
    }
}
