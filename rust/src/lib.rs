//! # vcmpi — "Stop Worrying about User-Visible Endpoints and Love MPI", reproduced
//!
//! A from-scratch reproduction of Zambre, Chandramowlishwaran & Balaji
//! (ICS '20): an MPI-3.1-subset message-passing library whose internals map
//! user-exposed communication parallelism (communicators, windows, ranks,
//! tags) onto a pool of **virtual communication interfaces (VCIs)**, each
//! bound to a dedicated NIC hardware context — plus the user-visible
//! **MPI Endpoints** extension it argues against, so the two can be compared
//! head-to-head on every experiment in the paper.
//!
//! The paper's testbed (16-core Skylake/Gomez sockets, Omni-Path and
//! InfiniBand fabrics) is reproduced as a deterministic discrete-event
//! simulation ([`sim`]) driving a NIC model ([`fabric`]); the library also
//! runs on a native OS-thread backend ([`platform`]) for end-to-end
//! applications whose compute is AOT-compiled JAX/Pallas executed through
//! PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod sim;

pub mod fabric;
pub mod mpi;

pub mod apps;
pub mod bench;

pub mod coordinator;

pub mod runtime;
pub mod platform;

pub mod util;
