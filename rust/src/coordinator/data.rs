//! Synthetic training corpus: a noisy affine token chain — structured
//! enough that a small causal LM's loss drops quickly, cheap to generate,
//! and fully deterministic per seed.

use crate::util::SplitMix64;

/// Deterministic synthetic token stream sharded across workers.
pub struct SyntheticCorpus {
    vocab: i32,
    noise: f64,
    rng: SplitMix64,
    state: i32,
}

impl SyntheticCorpus {
    /// `worker`-seeded shard: workers draw disjoint streams.
    pub fn new(vocab: i32, noise: f64, seed: u64, worker: usize) -> Self {
        let mut rng = SplitMix64::new(seed ^ (worker as u64).wrapping_mul(0x9E37_79B9));
        let state = (rng.next_u64() % vocab as u64) as i32;
        SyntheticCorpus { vocab, noise, rng, state }
    }

    fn next_token(&mut self) -> i32 {
        if self.rng.gen_bool(self.noise) {
            self.state = self.rng.gen_range(self.vocab as u64) as i32;
        } else {
            // Affine chain: highly learnable next-token structure.
            self.state = (self.state.wrapping_mul(5).wrapping_add(17)) % self.vocab;
        }
        self.state
    }

    /// One (batch, seq) batch of token ids, flattened row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        (0..batch * seq).map(|_| self.next_token()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_worker() {
        let mut a = SyntheticCorpus::new(512, 0.05, 42, 0);
        let mut b = SyntheticCorpus::new(512, 0.05, 42, 0);
        assert_eq!(a.batch(2, 16), b.batch(2, 16));
        let mut c = SyntheticCorpus::new(512, 0.05, 42, 1);
        assert_ne!(a.batch(2, 16), c.batch(2, 16), "workers draw distinct shards");
    }

    #[test]
    fn tokens_in_vocab_range() {
        let mut c = SyntheticCorpus::new(100, 0.5, 7, 3);
        assert!(c.batch(4, 64).iter().all(|&t| (0..100).contains(&t)));
    }
}
