//! The data-parallel training loop (native backend): each worker process
//! runs fwd/bwd through the AOT-compiled `train_grad_step`, gradients are
//! averaged over vcmpi with the **overlapped bucket exchange**, and
//! `train_sgd_step` applies the update. Workers stay bit-identical
//! because they apply identical averaged gradients.
//!
//! # The overlap pattern (production data-parallel)
//!
//! ```text
//! grads ready ─► issue iallreduce(bucket 0..B)   // all in flight at once,
//!                │                               // each on its own comm →
//!                │                               // own dedicated lane +
//!                │                               // own resumable schedule
//!                ├─ coll_wait(bucket 0) ─ scale bucket 0 by 1/w ─┐
//!                ├─ coll_wait(bucket 1) ─ scale bucket 1 ........│ buckets
//!                ┆                                               │ i+1..
//!                └─ coll_wait(bucket B-1) ─ scale bucket B-1 ────┘ still on
//!                                                                  the wire
//! ```
//!
//! Every `coll_wait` (and any other thread's progress call, via progress
//! hook 0) advances *all* outstanding schedules, so bucket `i+1` crosses
//! the wire while bucket `i` is being waited on and scaled — compute
//! hides communication instead of serializing behind it. The
//! [`TrainReport`] splits the exchange time accordingly:
//! `allreduce_blocked_ms` (parked inside `coll_wait`) vs
//! `allreduce_overlap_ms` (in-flight time hidden behind compute, the
//! Table-1 `coll_overlap_ms` metric).

use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, Info, MpiConfig};
use crate::platform::Backend;
use crate::runtime::{SharedRuntime, Tensor};
use crate::sim::SimOutcome;

use super::data::SyntheticCorpus;

#[derive(Clone)]
pub struct TrainConfig {
    pub artifacts_dir: String,
    pub workers: usize,
    pub steps: usize,
    pub lr: f32,
    /// Gradient buckets = communicators used for the exchange (1 =
    /// ser_comm; >1 = the paper's par_comm recommendation).
    pub buckets: usize,
    pub seed: u64,
    /// Log every n steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts_dir: "artifacts".into(),
            workers: 2,
            steps: 60,
            lr: 0.2,
            buckets: 4,
            seed: 7,
            log_every: 10,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Mean per-step wallclock (ms) and the slice spent in allreduce.
    pub step_ms: f64,
    pub allreduce_ms: f64,
    /// Slice of `allreduce_ms` spent parked inside `coll_wait` (the
    /// exchange time compute could NOT hide).
    pub allreduce_blocked_ms: f64,
    /// Mean per-step in-flight collective time hidden behind compute
    /// (issue-to-wait gap, clamped at completion — `coll_overlap_ms`).
    pub allreduce_overlap_ms: f64,
    pub params: usize,
}

/// Run data-parallel training; returns the loss curve (averaged across
/// workers per step).
pub fn train(cfg: TrainConfig) -> anyhow::Result<TrainReport> {
    let rt = Arc::new(SharedRuntime::open(&cfg.artifacts_dir)?);
    let params_n = rt.config("param_count").unwrap() as usize;
    let batch = rt.config("batch").unwrap() as usize;
    let seq = rt.config("seq").unwrap() as usize;
    let vocab = rt.config("vocab").unwrap() as i32;
    // Compile once up-front (shared across workers).
    rt.warm("train_grad_step")?;
    rt.warm("train_sgd_step")?;

    // Identical init on every worker (deterministic golden-ratio hash —
    // matches no particular scheme, but scale ~0.04 keeps logits sane).
    let init: Vec<f32> =
        (0..params_n).map(|i| ((i as f32 * 0.6180339887).fract() - 0.5) * 0.04).collect();

    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: cfg.workers,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(cfg.buckets + 1),
        1,
    );
    spec.backend = Backend::Native;

    let losses: Arc<Mutex<Vec<Vec<f32>>>> =
        Arc::new(Mutex::new(vec![Vec::new(); cfg.workers]));
    let timing: Arc<Mutex<(f64, f64, f64, f64)>> = Arc::new(Mutex::new((0.0, 0.0, 0.0, 0.0)));
    let cfg2 = cfg.clone();
    let losses2 = losses.clone();
    let timing2 = timing.clone();
    let rt = rt.clone();
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        // Bucket communicators opt into the segmented collectives policy:
        // each bucket's allreduce pipelines its ring chunks as tagged
        // segments on a dedicated (pinned, least-loaded) lane, so the
        // gradient exchange overlaps injection/wire/handling per step and
        // can never queue behind other traffic sharing the pool. `auto`
        // sizes the segment count from the fabric cost model (chunk DMA
        // time balanced against per-segment latency) instead of a static
        // guess.
        let coll_info = Info::new()
            .with("vcmpi_collectives", "dedicated")
            .with("vcmpi_coll_segments", "auto");
        let comms: Vec<_> =
            (0..cfg2.buckets).map(|_| proc.comm_dup_with_info(&world, &coll_info)).collect();
        let mut corpus = SyntheticCorpus::new(vocab, 0.05, cfg2.seed, proc.rank());
        let mut params = init.clone();
        let w = cfg2.workers as f32;
        let mut ar_ms = 0.0f64;
        let mut ar_blocked_ms = 0.0f64;
        let inst_start = crate::mpi::instrument::snapshot();
        let t_start = std::time::Instant::now();
        for step in 0..cfg2.steps {
            let tokens = corpus.batch(batch, seq);
            let out = rt
                .run("train_grad_step", &[
                    Tensor::f32(&[params_n], params.clone()),
                    Tensor::i32(&[batch, seq], tokens),
                ])
                .expect("grad_step");
            let loss = out[0].as_f32()[0];
            let mut grads = match &out[1] {
                Tensor::F32 { data, .. } => data.clone(),
                _ => unreachable!(),
            };
            // Average gradients across workers over vcmpi — overlapped:
            // every bucket's iallreduce goes out at once, and bucket i is
            // scaled by 1/w while buckets i+1.. are still on the wire
            // (see the module doc).
            let t0 = std::time::Instant::now();
            let reqs = super::issue_bucketed_iallreduce(proc, &comms, &grads);
            for (req, lo, hi) in reqs {
                let tw = std::time::Instant::now();
                proc.coll_wait_f32(req, &mut grads[lo..hi]);
                ar_blocked_ms += tw.elapsed().as_secs_f64() * 1e3;
                for g in grads[lo..hi].iter_mut() {
                    *g /= w;
                }
            }
            ar_ms += t0.elapsed().as_secs_f64() * 1e3;
            let out = rt
                .run("train_sgd_step", &[
                    Tensor::f32(&[params_n], params),
                    Tensor::f32(&[params_n], grads),
                    Tensor::scalar_f32(cfg2.lr),
                ])
                .expect("sgd_step");
            params = match &out[0] {
                Tensor::F32 { data, .. } => data.clone(),
                _ => unreachable!(),
            };
            losses2.lock().unwrap()[proc.rank()].push(loss);
            if proc.rank() == 0 && cfg2.log_every > 0 && step % cfg2.log_every == 0 {
                println!("step {step:4}  loss {loss:.4}");
            }
        }
        if proc.rank() == 0 {
            let total_ms = t_start.elapsed().as_secs_f64() * 1e3;
            let overlap_ms = (crate::mpi::instrument::snapshot() - inst_start).coll_overlap_ns
                as f64
                / 1e6;
            let n = cfg2.steps as f64;
            *timing2.lock().unwrap() =
                (total_ms / n, ar_ms / n, ar_blocked_ms / n, overlap_ms / n);
        }
        for c in comms {
            proc.comm_free(c);
        }
    });
    anyhow::ensure!(r.outcome == SimOutcome::Completed, "training run failed: {:?}", r.outcome);

    // Average the per-worker curves (and sanity-check they agree: same
    // averaged gradients => same params => near-identical losses modulo
    // their distinct data shards).
    let per_worker = losses.lock().unwrap().clone();
    let steps = per_worker[0].len();
    let mean: Vec<f32> = (0..steps)
        .map(|s| per_worker.iter().map(|w| w[s]).sum::<f32>() / per_worker.len() as f32)
        .collect();
    let (step_ms, allreduce_ms, allreduce_blocked_ms, allreduce_overlap_ms) =
        *timing.lock().unwrap();
    Ok(TrainReport {
        first_loss: mean[0],
        final_loss: *mean.last().unwrap(),
        losses: mean,
        step_ms,
        allreduce_ms,
        allreduce_blocked_ms,
        allreduce_overlap_ms,
        params: params_n,
    })
}
