//! Dist-train coordinator: data-parallel training over vcmpi with
//! **bucketed gradient allreduce over multiple communicators** — the
//! paper's recommendation ("maximize independence between threads with
//! MPI communicators") applied to a training system. Workers execute the
//! AOT-compiled `train_grad_step` / `train_sgd_step` HLO via PJRT; all
//! gradient exchange goes through vcmpi. Python never runs here.

mod data;
mod trainer;

pub use data::SyntheticCorpus;
pub use trainer::{train, TrainConfig, TrainReport};

use crate::mpi::{Comm, MpiProc};

/// Split a flat gradient vector into `n` contiguous buckets and allreduce
/// each on its own communicator. With the multi-VCI library, buckets map
/// to distinct VCIs — parallel communication streams for one logical
/// allreduce (ser_comm: pass a single comm in `comms`).
pub fn bucketed_allreduce(proc: &MpiProc, comms: &[Comm], grads: &mut [f32]) {
    assert!(!comms.is_empty());
    let n = comms.len();
    let len = grads.len();
    let per = len.div_ceil(n);
    let mut chunks: Vec<(usize, usize)> = Vec::with_capacity(n);
    for i in 0..n {
        let lo = (i * per).min(len);
        let hi = ((i + 1) * per).min(len);
        chunks.push((lo, hi));
    }
    for (i, &(lo, hi)) in chunks.iter().enumerate() {
        if lo < hi {
            proc.allreduce_f32(&comms[i], &mut grads[lo..hi]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, Interconnect};
    use crate::mpi::{run_cluster, ClusterSpec, MpiConfig};
    use crate::sim::SimOutcome;
    use std::sync::{Arc, Mutex};

    #[test]
    fn bucketed_allreduce_sums_across_workers() {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 4,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(8),
            1,
        );
        let out: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(Vec::new()));
        let o2 = out.clone();
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let comms: Vec<_> = (0..3).map(|_| proc.comm_dup(&world)).collect();
            let mut grads: Vec<f32> =
                (0..1000).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
            bucketed_allreduce(proc, &comms, &mut grads);
            if proc.rank() == 0 {
                o2.lock().unwrap().push(grads);
            }
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
        let got = out.lock().unwrap();
        let g = &got[0];
        // Sum over ranks 1..=4 of r*i = 10*i.
        for (i, &v) in g.iter().enumerate() {
            let want = 10.0 * i as f32;
            assert!((v - want).abs() <= want.abs() * 1e-5 + 1e-3, "i={i} v={v} want={want}");
        }
    }
}
