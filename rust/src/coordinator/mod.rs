//! Dist-train coordinator: data-parallel training over vcmpi with
//! **bucketed gradient allreduce over multiple communicators** — the
//! paper's recommendation ("maximize independence between threads with
//! MPI communicators") applied to a training system. Workers execute the
//! AOT-compiled `train_grad_step` / `train_sgd_step` HLO via PJRT; all
//! gradient exchange goes through vcmpi. Python never runs here.

mod data;
mod trainer;

pub use data::SyntheticCorpus;
pub use trainer::{train, TrainConfig, TrainReport};

use crate::mpi::{CollReq, Comm, MpiProc};

/// Contiguous bucket bounds: gradient slice `i` of `n` (identical on
/// every worker — part of the exchange's wire contract, like the
/// collective segment bounds).
fn bucket_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let per = len.div_ceil(n);
    (0..n).map(|i| ((i * per).min(len), ((i + 1) * per).min(len))).collect()
}

/// Split a flat gradient vector into `n` contiguous buckets and allreduce
/// each on its own communicator, bucket-by-bucket **blocking**. With the
/// multi-VCI library, buckets map to distinct VCIs — parallel
/// communication streams for one logical allreduce (ser_comm: pass a
/// single comm in `comms`). The trainer and the `train_step` bench use
/// the overlapped form below; this one is the comparison arm.
pub fn bucketed_allreduce(proc: &MpiProc, comms: &[Comm], grads: &mut [f32]) {
    assert!(!comms.is_empty());
    for (i, &(lo, hi)) in bucket_bounds(grads.len(), comms.len()).iter().enumerate() {
        if lo < hi {
            proc.allreduce_f32(&comms[i], &mut grads[lo..hi]);
        }
    }
}

/// Issue one nonblocking allreduce per bucket — every bucket's exchange
/// is in flight at once, each on its own communicator (own dedicated
/// lane, own resumable schedule). Returns the handles with their bucket
/// bounds in bucket order; the caller waits each with
/// `MpiProc::coll_wait_f32` into `grads[lo..hi]`, free to compute in
/// between (the trainer scales bucket `i` by `1/w` while buckets
/// `i+1..` are still on the wire).
pub fn issue_bucketed_iallreduce(
    proc: &MpiProc,
    comms: &[Comm],
    grads: &[f32],
) -> Vec<(CollReq, usize, usize)> {
    assert!(!comms.is_empty());
    bucket_bounds(grads.len(), comms.len())
        .into_iter()
        .enumerate()
        .filter(|&(_, (lo, hi))| lo < hi)
        .map(|(i, (lo, hi))| (proc.iallreduce_f32(&comms[i], &grads[lo..hi]), lo, hi))
        .collect()
}

/// [`issue_bucketed_iallreduce`] + in-order waits: the overlapped
/// exchange as one call (all buckets in flight together; bucket `i+1`
/// progresses while bucket `i` is being waited).
pub fn bucketed_allreduce_overlapped(proc: &MpiProc, comms: &[Comm], grads: &mut [f32]) {
    for (req, lo, hi) in issue_bucketed_iallreduce(proc, comms, grads) {
        proc.coll_wait_f32(req, &mut grads[lo..hi]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, Interconnect};
    use crate::mpi::{run_cluster, ClusterSpec, MpiConfig};
    use crate::sim::SimOutcome;
    use std::sync::{Arc, Mutex};

    #[test]
    fn bucketed_allreduce_sums_across_workers() {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 4,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(8),
            1,
        );
        let out: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(Vec::new()));
        let o2 = out.clone();
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let comms: Vec<_> = (0..3).map(|_| proc.comm_dup(&world)).collect();
            let mut grads: Vec<f32> =
                (0..1000).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
            bucketed_allreduce(proc, &comms, &mut grads);
            if proc.rank() == 0 {
                o2.lock().unwrap().push(grads);
            }
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
        let got = out.lock().unwrap();
        let g = &got[0];
        // Sum over ranks 1..=4 of r*i = 10*i.
        for (i, &v) in g.iter().enumerate() {
            let want = 10.0 * i as f32;
            assert!((v - want).abs() <= want.abs() * 1e-5 + 1e-3, "i={i} v={v} want={want}");
        }
    }

    #[test]
    fn overlapped_bucketed_allreduce_matches_blocking() {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 4,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(8),
            1,
        );
        let out: Arc<Mutex<Vec<(Vec<f32>, Vec<f32>)>>> = Arc::new(Mutex::new(Vec::new()));
        let o2 = out.clone();
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let comms: Vec<_> = (0..3).map(|_| proc.comm_dup(&world)).collect();
            let base: Vec<f32> =
                (0..1000).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
            let mut blocking = base.clone();
            bucketed_allreduce(proc, &comms, &mut blocking);
            let mut overlapped = base;
            bucketed_allreduce_overlapped(proc, &comms, &mut overlapped);
            if proc.rank() == 0 {
                o2.lock().unwrap().push((blocking, overlapped));
            }
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
        let got = out.lock().unwrap();
        let (blocking, overlapped) = &got[0];
        // One engine behind both forms: bit-identical, not just close.
        assert_eq!(blocking, overlapped);
    }
}
