//! On-the-wire message formats.

/// Global process id (0..nprocs across all nodes).
pub type ProcId = usize;

/// Window id, unique per process that exposed it.
pub type WinId = u64;

/// Reduction op carried by Accumulate-class operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccOp {
    /// Element-wise f64 sum (MPI_SUM).
    SumF64,
    /// Element-wise u64 sum.
    SumU64,
    /// Replace (MPI_REPLACE).
    Replace,
}

/// Passive-target lock flavor carried by [`Payload::RmaLockReq`] /
/// [`Payload::RmaUnlock`] (MPI_Win_lock's `lock_type`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockKind {
    /// MPI_LOCK_SHARED: concurrent holders allowed; the target grants
    /// immediately unless an exclusive holder (or a queued exclusive
    /// waiter — FIFO fairness) is in the way.
    Shared,
    /// MPI_LOCK_EXCLUSIVE: sole holder; contenders queue FIFO per window.
    Exclusive,
}

/// Two-sided wire protocol step.
#[derive(Clone, Debug)]
pub enum P2pProtocol {
    /// Payload rides along; matches and completes on arrival.
    /// `send_handle` identifies the sender's request for synchronous-mode
    /// acks (0 when no ack is needed).
    Eager { send_handle: u64 },
    /// Rendezvous request-to-send: payload stays at the sender until the
    /// receiver matches and pulls it (clear-to-send).
    Rts { send_handle: u64 },
    /// Receiver's clear-to-send answering an Rts.
    Cts { send_handle: u64, recv_handle: u64 },
    /// Rendezvous payload delivery.
    Data { recv_handle: u64 },
}

/// Reliable-delivery header stamped on every frame while a
/// [`FaultPlan`](crate::fabric::FaultPlan) is installed; `None` on the
/// fault-free path (zero cost, zero state).
#[derive(Clone, Copy, Debug)]
pub struct RelHeader {
    /// Per-channel wire sequence number (1-based). The channel is
    /// (src proc, src ctx, dst proc, logical dst ctx).
    pub seq: u64,
    /// [`Payload::digest`] at injection; admission drops on mismatch.
    pub checksum: u64,
    /// Piggybacked cumulative ack for the *reverse* channel: the sender
    /// has admitted everything up to this sequence from the receiver.
    pub ack: u64,
    /// The destination context the sender addressed — the channel key —
    /// which may differ from the context the frame physically lands on
    /// after a lane-failover redirect.
    pub chan_dst_ctx: u32,
}

/// A message sitting in (or headed for) a hardware context's rx queue.
#[derive(Clone, Debug)]
pub struct WireMsg {
    /// Virtual time at which the message becomes visible to the target.
    pub arrival: u64,
    pub src_proc: ProcId,
    /// Index of the source context (for addressing replies).
    pub src_ctx: usize,
    /// Reliable-delivery header; `None` when no fault plan is installed
    /// (and on NIC-level [`Payload::RelAck`] frames, which are exempt).
    pub rel: Option<RelHeader>,
    pub payload: Payload,
}

/// What the message carries.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Two-sided traffic (send/ssend/isend).
    TwoSided {
        comm_id: u64,
        src_rank: usize,
        dst_rank: usize,
        tag: i32,
        /// Sender-side FIFO sequence number. Without striping this counts
        /// per (comm, vci) stream and merely documents injection order
        /// (FIFO queues preserve it). With VCI striping it counts the
        /// single logical (comm, destination) stream across ALL VCIs, and
        /// the receiver's reorder stage admits messages to matching
        /// strictly in this order (nonovertaking despite independent
        /// per-VCI delivery).
        seq: u64,
        /// `Some(home)` marks a striped envelope (Eager/Rts): `home` is
        /// the communicator's assigned VCI, whose matching engine on the
        /// receiver owns the stream's reorder buffer and queues (reduced
        /// modulo the receiver's pool size). `None` for unstriped traffic
        /// and for out-of-stripe control steps (CTS/DATA/acks), which
        /// bypass the reorder stage.
        stripe_home: Option<usize>,
        protocol: P2pProtocol,
        /// True for synchronous-mode sends (MPI_Ssend): an explicit ack is
        /// returned on match.
        needs_ack: bool,
        data: Vec<u8>,
    },
    /// Ack for a matched synchronous send (or rendezvous completion).
    SendAck { send_handle: u64 },
    /// Software-emulated RMA put (OPA personality): target CPU applies it.
    /// `lane: Some(l)` marks a *striped* op (per-window VCI striping):
    /// the origin issued it on stripe lane `l` and completion is counted
    /// per (window, target, lane) instead of tracked per flush handle —
    /// the target answers with [`Payload::RmaAckCount`] echoing the lane.
    /// `None` keeps the ordered flush-handle protocol.
    RmaPut { win: WinId, offset: usize, data: Vec<u8>, flush_handle: u64, lane: Option<u32> },
    /// Software-emulated RMA get request. `lane` as in [`Payload::RmaPut`]:
    /// `Some(l)` marks a striped get whose reply is counted per
    /// (window, target, lane) instead of parked on a flush handle.
    RmaGetReq { win: WinId, offset: usize, len: usize, get_handle: u64, lane: Option<u32> },
    /// Reply carrying the got bytes. `win`/`lane` echo the request: a
    /// striped get's reply (`lane: Some`) returns to the issuing lane's
    /// context and bumps that lane's per-(window, target) ack counter —
    /// the same counted-completion model as [`Payload::RmaAckCount`] —
    /// while the data itself parks under `get_handle` as always.
    RmaGetReply { win: WinId, get_handle: u64, data: Vec<u8>, lane: Option<u32> },
    /// Accumulate: applied by the target CPU on both personalities
    /// (MPI datatype reductions are not NIC-offloadable in general).
    /// `lane` as in [`Payload::RmaPut`].
    RmaAcc {
        win: WinId,
        offset: usize,
        data: Vec<u8>,
        op: AccOp,
        flush_handle: u64,
        lane: Option<u32>,
    },
    /// Fetch-and-op (e.g. MPI_Fetch_and_op on a u64 counter).
    RmaFetchOp { win: WinId, offset: usize, operand: Vec<u8>, op: AccOp, fetch_handle: u64 },
    /// Reply to a fetch-and-op with the previous value.
    RmaFetchOpReply { fetch_handle: u64, data: Vec<u8> },
    /// Remote completion ack for ordered puts/accumulates (counts toward
    /// flush via the per-VCI `acked` set).
    RmaAck { flush_handle: u64 },
    /// Counted completion ack for a *striped* put/accumulate: one more op
    /// on window `win` from the origin's stripe lane `lane` has applied at
    /// the target (identified by the message's `src_proc`). The ack
    /// returns to the issuing lane's context, where the origin bumps that
    /// lane's per-(window, target) ack counter; `win_flush` waits until
    /// every lane's acked count reaches its issued watermark.
    RmaAckCount { win: WinId, lane: u32 },
    /// Passive-target lock request (MPI_Win_lock, OPA software protocol):
    /// the target's lock table either grants now (shared with no
    /// exclusive holder/waiter, or exclusive on an idle window) or queues
    /// the request FIFO. `handle` identifies the origin's wait; the grant
    /// echoes it. Windows whose policy carries `mpi_assert_no_locks`
    /// never put this on the wire — the epoch is a local no-op grant.
    RmaLockReq { win: WinId, kind: LockKind, handle: u64 },
    /// Grant for a queued or immediate [`Payload::RmaLockReq`]: lands in
    /// the issuing VCI's `lock_granted` set, releasing the origin's
    /// `win_lock` wait.
    RmaLockGrant { win: WinId, handle: u64 },
    /// Passive-target unlock (MPI_Win_unlock): releases the origin's hold
    /// on the target's lock table and drains the grantable FIFO prefix of
    /// queued waiters. Acked with [`Payload::RmaAck`] echoing `handle`
    /// (the same completion set ordered flushes use), so the origin's
    /// unlock blocks until the epoch is closed at the target — a later
    /// lock request (possibly relayed through a third rank) can never
    /// find the old epoch still open.
    RmaUnlock { win: WinId, kind: LockKind, handle: u64 },
    /// Standalone reliable-delivery ack, emitted when a receiver drops a
    /// duplicate frame (the sender is clearly retransmitting past the
    /// piggyback window). Modeled as NIC-level traffic: fault-exempt,
    /// zero wire bytes, and consumed inside the fabric's poll wrapper —
    /// the MPI layer never sees it. `chan_src_ctx`/`chan_dst_ctx`
    /// identify the acked channel from the *original sender's*
    /// perspective; `ack` is the cumulative admitted sequence.
    RelAck { ack: u64, chan_src_ctx: u32, chan_dst_ctx: u32 },
}

/// Initiator-side record of an RMA operation's completion semantics.
#[derive(Clone, Copy, Debug)]
pub enum RmaCompletion {
    /// Completes at a fixed virtual time (hardware RMA on IB): flushing is
    /// just waiting until that time.
    AtTime(u64),
    /// Completes when the ack counter identified by `flush_handle` fires
    /// (software RMA on OPA): flushing requires polling progress.
    OnAck { flush_handle: u64 },
}

impl Payload {
    /// Payload bytes that occupy wire bandwidth.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::TwoSided { data, .. } => data.len(),
            Payload::RmaPut { data, .. } => data.len(),
            Payload::RmaAcc { data, .. } => data.len(),
            Payload::RmaGetReply { data, .. } => data.len(),
            Payload::RmaFetchOp { operand, .. } => operand.len(),
            Payload::RmaFetchOpReply { data, .. } => data.len(),
            Payload::RmaGetReq { .. }
            | Payload::SendAck { .. }
            | Payload::RmaAck { .. }
            | Payload::RmaAckCount { .. }
            | Payload::RmaLockReq { .. }
            | Payload::RmaLockGrant { .. }
            | Payload::RmaUnlock { .. }
            | Payload::RelAck { .. } => 0,
        }
    }

    /// Checksum over every field that crosses the wire — a mix64 chain,
    /// not a CRC, but collision-resistant enough to catch the fault
    /// layer's single-bit flips with certainty. Stamped into
    /// [`RelHeader::checksum`] at injection and re-computed at
    /// admission.
    pub fn digest(&self) -> u64 {
        use crate::util::mix64;
        fn fold(h: u64, v: u64) -> u64 {
            mix64(h.wrapping_mul(0x9E3779B97F4A7C15) ^ v)
        }
        fn fold_bytes(mut h: u64, data: &[u8]) -> u64 {
            for chunk in data.chunks(8) {
                let mut w = [0u8; 8];
                w[..chunk.len()].copy_from_slice(chunk);
                h = fold(h, u64::from_le_bytes(w));
            }
            fold(h, data.len() as u64)
        }
        match self {
            Payload::TwoSided {
                comm_id,
                src_rank,
                dst_rank,
                tag,
                seq,
                stripe_home,
                protocol,
                needs_ack,
                data,
            } => {
                let mut h = fold(1, *comm_id);
                h = fold(h, *src_rank as u64);
                h = fold(h, *dst_rank as u64);
                h = fold(h, *tag as u64);
                h = fold(h, *seq);
                h = fold(h, stripe_home.map_or(u64::MAX, |s| s as u64));
                h = match protocol {
                    P2pProtocol::Eager { send_handle } => fold(fold(h, 10), *send_handle),
                    P2pProtocol::Rts { send_handle } => fold(fold(h, 11), *send_handle),
                    P2pProtocol::Cts { send_handle, recv_handle } => {
                        fold(fold(fold(h, 12), *send_handle), *recv_handle)
                    }
                    P2pProtocol::Data { recv_handle } => fold(fold(h, 13), *recv_handle),
                };
                h = fold(h, *needs_ack as u64);
                fold_bytes(h, data)
            }
            Payload::SendAck { send_handle } => fold(2, *send_handle),
            Payload::RmaPut { win, offset, data, flush_handle, lane } => {
                let mut h = fold(3, *win);
                h = fold(h, *offset as u64);
                h = fold(h, *flush_handle);
                h = fold(h, lane.map_or(u64::MAX, u64::from));
                fold_bytes(h, data)
            }
            Payload::RmaGetReq { win, offset, len, get_handle, lane } => {
                let mut h = fold(4, *win);
                h = fold(h, *offset as u64);
                h = fold(h, *len as u64);
                h = fold(h, *get_handle);
                fold(h, lane.map_or(u64::MAX, u64::from))
            }
            Payload::RmaGetReply { win, get_handle, data, lane } => {
                let mut h = fold(5, *win);
                h = fold(h, *get_handle);
                h = fold(h, lane.map_or(u64::MAX, u64::from));
                fold_bytes(h, data)
            }
            Payload::RmaAcc { win, offset, data, op, flush_handle, lane } => {
                let mut h = fold(6, *win);
                h = fold(h, *offset as u64);
                h = fold(h, *op as u64);
                h = fold(h, *flush_handle);
                h = fold(h, lane.map_or(u64::MAX, u64::from));
                fold_bytes(h, data)
            }
            Payload::RmaFetchOp { win, offset, operand, op, fetch_handle } => {
                let mut h = fold(7, *win);
                h = fold(h, *offset as u64);
                h = fold(h, *op as u64);
                h = fold(h, *fetch_handle);
                fold_bytes(h, operand)
            }
            Payload::RmaFetchOpReply { fetch_handle, data } => {
                fold_bytes(fold(8, *fetch_handle), data)
            }
            Payload::RmaAck { flush_handle } => fold(9, *flush_handle),
            Payload::RmaAckCount { win, lane } => fold(fold(14, *win), u64::from(*lane)),
            Payload::RmaLockReq { win, kind, handle } => {
                fold(fold(fold(15, *win), *kind as u64), *handle)
            }
            Payload::RmaLockGrant { win, handle } => fold(fold(16, *win), *handle),
            Payload::RmaUnlock { win, kind, handle } => {
                fold(fold(fold(17, *win), *kind as u64), *handle)
            }
            Payload::RelAck { ack, chan_src_ctx, chan_dst_ctx } => {
                fold(fold(fold(18, *ack), u64::from(*chan_src_ctx)), u64::from(*chan_dst_ctx))
            }
        }
    }

    /// Flip one bit of the wire payload data (a `Corrupt` fault). For
    /// dataless control frames there is nothing to flip; the caller
    /// corrupts the checksum header instead. Returns true if a data bit
    /// was flipped.
    pub fn flip_data_bit(&mut self, bit: usize) -> bool {
        let data = match self {
            Payload::TwoSided { data, .. }
            | Payload::RmaPut { data, .. }
            | Payload::RmaAcc { data, .. }
            | Payload::RmaGetReply { data, .. }
            | Payload::RmaFetchOpReply { data, .. } => data,
            Payload::RmaFetchOp { operand, .. } => operand,
            _ => return false,
        };
        if data.is_empty() {
            return false;
        }
        let bit = bit % (data.len() * 8);
        data[bit / 8] ^= 1 << (bit % 8);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_counts_payload_only() {
        let p = Payload::RmaPut {
            win: 1,
            offset: 0,
            data: vec![0; 4096],
            flush_handle: 9,
            lane: None,
        };
        assert_eq!(p.wire_bytes(), 4096);
        let ack = Payload::RmaAck { flush_handle: 9 };
        assert_eq!(ack.wire_bytes(), 0);
        let counted = Payload::RmaAckCount { win: 1, lane: 3 };
        assert_eq!(counted.wire_bytes(), 0);
        // Lock-protocol control traffic is pure latency: zero wire bytes.
        let lock = Payload::RmaLockReq { win: 1, kind: LockKind::Exclusive, handle: 4 };
        assert_eq!(lock.wire_bytes(), 0);
        let grant = Payload::RmaLockGrant { win: 1, handle: 4 };
        assert_eq!(grant.wire_bytes(), 0);
        let unlock = Payload::RmaUnlock { win: 1, kind: LockKind::Exclusive, handle: 5 };
        assert_eq!(unlock.wire_bytes(), 0);
    }
}
