//! Cluster-wide fabric state: context allocation (with per-node hardware
//! limits), address exchange, and window-memory registration.
//!
//! The registry itself models *hardware* tables (the adapter's context
//! table, the address vector, the memory-registration cache), so its host
//! synchronization is free in virtual time; the software costs the paper
//! measures (ctx create/destroy, AV insertion — Fig. 4) are charged
//! explicitly by the callers through the cost model.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::platform::{padvance, pnow, Backend};
use crate::sim::CostModel;

use super::context::{HwContext, Injector};
use super::fault::{self, ChanKey, FaultDecision, FaultPlan, RelState, RxChannel, TxEntry};
use super::wire::{Payload, ProcId, RelHeader, WinId, WireMsg};
use super::Interconnect;

/// Fabric/topology configuration.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    pub interconnect: Interconnect,
    /// Number of nodes in the cluster.
    pub nodes: usize,
    /// Processes per node (1 for MPI+threads, cores-per-node for
    /// MPI everywhere).
    pub procs_per_node: usize,
    /// Hardware contexts available per node (Intel HFI: 160; set low to
    /// reproduce the Fig. 17 mapping-mismatch experiments).
    pub max_contexts_per_node: usize,
}

impl FabricConfig {
    pub fn nprocs(&self) -> usize {
        self.nodes * self.procs_per_node
    }
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 160,
        }
    }
}

/// Registered window memory. The buffer is guarded by a host mutex that
/// models the DMA engine's coherent access — never contended in virtual
/// time under the DES (single running thread) and cheap natively.
pub struct WindowMem {
    buf: Mutex<Vec<u8>>,
}

impl WindowMem {
    pub fn new(size: usize) -> Arc<Self> {
        Arc::new(WindowMem { buf: Mutex::new(vec![0; size]) })
    }

    pub fn len(&self) -> usize {
        self.buf.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn write(&self, offset: usize, data: &[u8]) {
        let mut b = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        b[offset..offset + data.len()].copy_from_slice(data);
    }

    pub fn read(&self, offset: usize, len: usize) -> Vec<u8> {
        let b = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        b[offset..offset + len].to_vec()
    }

    /// Read-modify-write with `f` applied under the memory lock — used by
    /// accumulate handlers to guarantee element-wise atomicity.
    pub fn rmw<R>(&self, f: impl FnOnce(&mut Vec<u8>) -> R) -> R {
        let mut b = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut b)
    }
}

/// The passive-target lock word registered alongside a window's memory:
/// a reader/writer count the IB personality's origins manipulate
/// *directly* with NIC atomics (compare-and-swap on target memory — no
/// target CPU involvement, like hardware Put/Get). There is deliberately
/// no queue here: hardware CAS has no fairness, so IB exclusive
/// contenders retry (each retry costing an atomic round trip), while the
/// OPA personality ignores this word entirely and runs the software
/// FIFO lock-queue protocol in the target's active-message handlers
/// (`mpi::rma::WinLockTable`).
///
/// Like [`WindowMem`], the host mutex models the NIC's coherent access
/// and is free in virtual time; the atomic's latency is charged by the
/// caller per attempt.
pub struct WinLockWord {
    state: Mutex<(usize, bool)>, // (shared holders, exclusive held)
}

impl WinLockWord {
    pub fn new() -> Arc<Self> {
        Arc::new(WinLockWord { state: Mutex::new((0, false)) })
    }

    /// One NIC-atomic acquisition attempt. Shared succeeds unless an
    /// exclusive holder is present (the IB shared fast path: typically
    /// one round trip, no target CPU); exclusive additionally requires
    /// zero shared holders.
    pub fn try_acquire(&self, exclusive: bool) -> bool {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        match (exclusive, &mut *s) {
            (false, (readers, false)) => {
                *readers += 1;
                true
            }
            (true, (0, held @ false)) => {
                *held = true;
                true
            }
            _ => false,
        }
    }

    /// Release a held lock (one NIC atomic).
    pub fn release(&self, exclusive: bool) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if exclusive {
            debug_assert!(s.1, "exclusive release without a holder");
            s.1 = false;
        } else {
            debug_assert!(s.0 > 0, "shared release without a holder");
            s.0 = s.0.saturating_sub(1);
        }
    }

    /// No holder of either flavor (win_free tripwire).
    pub fn is_idle(&self) -> bool {
        let s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        s.0 == 0 && !s.1
    }
}

const MAX_CTXS: usize = 1024;

struct ProcEntry {
    /// Fixed-capacity context table (hardware context slots).
    ctxs: Vec<OnceLock<Arc<HwContext>>>,
    n_open: AtomicUsize,
    windows: Mutex<Vec<(WinId, Arc<WindowMem>, Arc<WinLockWord>)>>,
}

/// The whole simulated network.
pub struct Network {
    cfg: FabricConfig,
    backend: Backend,
    costs: Arc<CostModel>,
    procs: Vec<ProcEntry>,
    /// Open contexts per node (hardware limit accounting).
    node_open: Vec<AtomicUsize>,
    /// Installed fault schedule (`vcmpi_fault_plan`). Empty on the
    /// fault-free path: every hot-path check is one `OnceLock` load.
    fault: OnceLock<Arc<FaultPlan>>,
    /// Reliable-delivery state; allocated with the plan, never before.
    rel: OnceLock<RelState>,
}

impl Network {
    pub fn new(cfg: FabricConfig, backend: Backend, costs: Arc<CostModel>) -> Arc<Network> {
        let procs = (0..cfg.nprocs())
            .map(|_| ProcEntry {
                ctxs: (0..MAX_CTXS).map(|_| OnceLock::new()).collect(),
                n_open: AtomicUsize::new(0),
                windows: Mutex::new(Vec::new()),
            })
            .collect();
        let node_open = (0..cfg.nodes).map(|_| AtomicUsize::new(0)).collect();
        Arc::new(Network {
            cfg,
            backend,
            costs,
            procs,
            node_open,
            fault: OnceLock::new(),
            rel: OnceLock::new(),
        })
    }

    /// Install a fault schedule. Must happen before the program's
    /// traffic starts (run_cluster installs it before procs spawn);
    /// scheduled context kills are also applied to any already-open
    /// contexts. Installing twice panics — a plan is per-run.
    pub fn install_fault_plan(&self, plan: Arc<FaultPlan>) {
        for k in &plan.kills {
            if k.proc < self.cfg.nprocs() {
                if let Some(ctx) = self.procs[k.proc].ctxs.get(k.ctx).and_then(|c| c.get()) {
                    ctx.kill_at(k.at_ns);
                }
            }
        }
        self.rel.set(RelState::default()).ok().expect("fault plan already installed");
        self.fault.set(plan).ok().expect("fault plan already installed");
    }

    /// The installed fault schedule, if any.
    pub fn fault_plan(&self) -> Option<&Arc<FaultPlan>> {
        self.fault.get()
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    pub fn interconnect(&self) -> Interconnect {
        self.cfg.interconnect
    }

    pub fn costs(&self) -> &Arc<CostModel> {
        &self.costs
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn node_of(&self, proc: ProcId) -> usize {
        proc / self.cfg.procs_per_node
    }

    /// Per-process view.
    pub fn proc_fabric(self: &Arc<Self>, proc: ProcId) -> ProcFabric {
        assert!(proc < self.cfg.nprocs());
        ProcFabric { net: self.clone(), proc }
    }
}

/// A process's handle onto the fabric.
#[derive(Clone)]
pub struct ProcFabric {
    net: Arc<Network>,
    pub proc: ProcId,
}

impl ProcFabric {
    pub fn interconnect(&self) -> Interconnect {
        self.net.interconnect()
    }

    pub fn costs(&self) -> &Arc<CostModel> {
        self.net.costs()
    }

    pub fn backend(&self) -> Backend {
        self.net.backend
    }

    pub fn nprocs(&self) -> usize {
        self.net.cfg.nprocs()
    }

    pub fn node_of(&self, proc: ProcId) -> usize {
        self.net.node_of(proc)
    }

    /// Open a hardware context. Charges creation cost; respects the node's
    /// hardware limit (returns `None` when exhausted, in which case the MPI
    /// layer falls back to sharing an existing VCI — paper §4.2).
    pub fn open_context(&self) -> Option<(usize, Arc<HwContext>)> {
        let node = self.net.node_of(self.proc);
        let limit = self.net.cfg.max_contexts_per_node;
        // Reserve a node slot.
        let prev = self.net.node_open[node].fetch_add(1, Ordering::SeqCst);
        if prev >= limit {
            self.net.node_open[node].fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        padvance(self.net.backend, self.net.costs.ctx_create);
        let entry = &self.net.procs[self.proc];
        let idx = entry.n_open.fetch_add(1, Ordering::SeqCst);
        assert!(idx < MAX_CTXS, "context table overflow");
        let ctx = Arc::new(HwContext::new(self.net.backend));
        if let Some(plan) = self.net.fault.get() {
            for k in &plan.kills {
                if k.proc == self.proc && k.ctx == idx {
                    ctx.kill_at(k.at_ns);
                }
            }
        }
        entry.ctxs[idx].set(ctx.clone()).ok().expect("slot already set");
        Some((idx, ctx))
    }

    /// Tear down a context (finalize path). The slot is not reused — real
    /// adapters recycle lazily, and processes close only at finalize.
    pub fn close_context(&self, _idx: usize) {
        let node = self.net.node_of(self.proc);
        padvance(self.net.backend, self.net.costs.ctx_destroy);
        self.net.node_open[node].fetch_sub(1, Ordering::SeqCst);
    }

    /// Model inserting one remote context address into this process's
    /// address vector (connection establishment, Fig. 4).
    pub fn insert_address(&self) {
        padvance(self.net.backend, self.net.costs.av_insert);
    }

    /// Look up a remote (or local) context for injection/polling.
    pub fn context(&self, proc: ProcId, idx: usize) -> Arc<HwContext> {
        self.net.procs[proc].ctxs[idx]
            .get()
            .unwrap_or_else(|| panic!("context {idx} of proc {proc} not open"))
            .clone()
    }

    /// Number of contexts this process has opened.
    pub fn open_count(&self, proc: ProcId) -> usize {
        self.net.procs[proc].n_open.load(Ordering::SeqCst)
    }

    /// TX handle bound to one of this process's contexts.
    pub fn injector(&self, ctx_index: usize) -> Injector {
        Injector::new(self.proc, ctx_index, self.net.backend, self.net.costs.clone())
    }

    /// Inject `payload` from local context `src_ctx` toward context
    /// `dst_ctx` of `dst_proc`. Picks the internode NIC path or the
    /// intranode shared-memory path by topology; charges the caller the
    /// per-message injection cost, and stamps the arrival with DMA + wire
    /// (or shm) latency.
    pub fn inject(
        &self,
        src_ctx: usize,
        dst_proc: ProcId,
        dst_ctx: usize,
        payload: crate::fabric::Payload,
    ) {
        let arrival = self.charge_inject(dst_proc, payload.wire_bytes());
        if let Some(plan) = self.net.fault.get() {
            return self.inject_faulted(plan, src_ctx, dst_proc, dst_ctx, payload, arrival);
        }
        let target = self.context(dst_proc, dst_ctx);
        target.deliver(WireMsg { arrival, src_proc: self.proc, src_ctx, rel: None, payload });
    }

    /// Charge the caller the per-message injection cost (shm or NIC by
    /// topology) and stamp the arrival time.
    fn charge_inject(&self, dst_proc: ProcId, bytes: usize) -> u64 {
        let costs = &self.net.costs;
        let backend = self.net.backend;
        let intranode = self.net.node_of(self.proc) == self.net.node_of(dst_proc);
        if intranode {
            padvance(backend, costs.shm_inject);
            pnow(backend) + costs.shm_latency + costs.memcpy_cost(bytes)
        } else {
            padvance(backend, costs.nic_inject);
            pnow(backend) + costs.dma_cost(bytes) + costs.wire_latency
        }
    }

    /// Slow-path inject while a fault plan is installed: stamp a
    /// reliable-delivery header (sequence, checksum, piggyback ack),
    /// record the frame in the unacked window, then roll the fault
    /// decision and deliver/drop/dup/corrupt/delay accordingly.
    fn inject_faulted(
        &self,
        plan: &Arc<FaultPlan>,
        src_ctx: usize,
        dst_proc: ProcId,
        dst_ctx: usize,
        payload: Payload,
        arrival: u64,
    ) {
        let rel = self.net.rel.get().expect("rel state installed with plan");
        let now = pnow(self.net.backend);
        let chan: ChanKey = (self.proc, src_ctx, dst_proc, dst_ctx);
        let seq = {
            let mut tx = rel.tx.lock().unwrap_or_else(|e| e.into_inner());
            let ch = tx.entry(chan).or_default();
            ch.next_seq += 1;
            let seq = ch.next_seq;
            ch.unacked.insert(
                seq,
                TxEntry {
                    payload: payload.clone(),
                    resend_at: now + plan.retransmit_timeout_ns,
                    backoff: plan.retransmit_timeout_ns,
                    attempts: 0,
                },
            );
            seq
        };
        let header = RelHeader {
            seq,
            checksum: payload.digest(),
            ack: self.rx_cumulative(rel, (dst_proc, dst_ctx, self.proc, src_ctx)),
            chan_dst_ctx: dst_ctx as u32,
        };
        let mut msg =
            WireMsg { arrival, src_proc: self.proc, src_ctx, rel: Some(header), payload };
        match plan.decide(self.proc, src_ctx, dst_proc, dst_ctx, seq, 0) {
            FaultDecision::Drop => {
                fault::bump(&plan.counters.drops);
            }
            FaultDecision::Duplicate => {
                fault::bump(&plan.counters.dups);
                self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg.clone());
                self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg);
            }
            FaultDecision::Corrupt => {
                fault::bump(&plan.counters.corrupts);
                let bit = plan.corrupt_bit(seq, msg.payload.wire_bytes() * 8);
                if !msg.payload.flip_data_bit(bit) {
                    // Dataless control frame: corrupt the checksum
                    // header instead — same receiver-side outcome.
                    if let Some(h) = msg.rel.as_mut() {
                        h.checksum ^= 1 << (bit % 64);
                    }
                }
                self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg);
            }
            FaultDecision::Delay(extra) => {
                fault::bump(&plan.counters.delays);
                let release = msg.arrival + extra;
                let mut limbo = rel.limbo.lock().unwrap_or_else(|e| e.into_inner());
                limbo.entry((dst_proc, dst_ctx)).or_default().push((release, msg));
            }
            FaultDecision::None => {
                self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg);
            }
        }
    }

    /// Cumulative admitted sequence on one of our rx channels (what we
    /// piggyback as an ack on reverse traffic).
    fn rx_cumulative(&self, rel: &RelState, chan: ChanKey) -> u64 {
        let rx = rel.rx.lock().unwrap_or_else(|e| e.into_inner());
        rx.get(&chan).map_or(0, |c| c.next - 1)
    }

    /// Deliver through the failover redirect table; frames landing on a
    /// hard-failed context vanish (counted — retransmit recovers them
    /// once the owning proc installs a redirect).
    fn deliver_resolved(
        &self,
        rel: &RelState,
        plan: &Arc<FaultPlan>,
        dst_proc: ProcId,
        logical_dst: usize,
        msg: WireMsg,
    ) {
        let phys = rel.resolve(dst_proc, logical_dst);
        let target = self.context(dst_proc, phys);
        if target.is_killed() {
            fault::bump(&plan.counters.kill_drops);
            return;
        }
        target.deliver(msg);
    }

    /// Poll local context `ctx_index` for one admissible message.
    ///
    /// Fault-free path: exactly `HwContext::poll` (one `OnceLock` load
    /// of overhead). With a plan installed, this is the
    /// reliable-delivery admission point: due limbo frames are released
    /// first, then frames are popped and checked — corrupt frames
    /// (checksum mismatch) and duplicates (stale sequence) are dropped
    /// and counted, out-of-order frames are parked until the gap fills,
    /// piggybacked acks prune the reverse unacked window, and NIC-level
    /// `RelAck` frames are consumed here so the MPI layer never sees
    /// them.
    pub fn poll_ctx(&self, ctx_index: usize) -> Option<WireMsg> {
        let ctx = self.context(self.proc, ctx_index);
        let Some(plan) = self.net.fault.get() else {
            return ctx.poll(&self.net.costs);
        };
        let rel = self.net.rel.get().expect("rel state installed with plan");
        self.release_due_limbo(rel, plan);
        loop {
            let msg = ctx.poll(&self.net.costs)?;
            let Some(hdr) = msg.rel else {
                if let Payload::RelAck { ack, chan_src_ctx, chan_dst_ctx } = msg.payload {
                    // Ack for frames WE sent: (us, chan_src_ctx) →
                    // (them, chan_dst_ctx).
                    self.prune_acked(
                        rel,
                        (self.proc, chan_src_ctx as usize, msg.src_proc, chan_dst_ctx as usize),
                        ack,
                    );
                    continue;
                }
                return Some(msg);
            };
            // Piggybacked ack covers the reverse channel: frames we
            // sent from the context they addressed.
            self.prune_acked(
                rel,
                (self.proc, hdr.chan_dst_ctx as usize, msg.src_proc, msg.src_ctx),
                hdr.ack,
            );
            if msg.payload.digest() != hdr.checksum {
                fault::bump(&plan.counters.rel_corrupt_drops);
                continue;
            }
            let chan: ChanKey = (msg.src_proc, msg.src_ctx, self.proc, hdr.chan_dst_ctx as usize);
            let mut rx = rel.rx.lock().unwrap_or_else(|e| e.into_inner());
            let ch = rx.entry(chan).or_default();
            if hdr.seq < ch.next {
                // Already admitted: the sender is retransmitting past
                // our piggyback window — answer with a standalone ack.
                fault::bump(&plan.counters.rel_dup_drops);
                let ack = ch.next - 1;
                drop(rx);
                self.send_rel_ack(rel, msg.src_proc, msg.src_ctx, hdr.chan_dst_ctx, ack);
                continue;
            }
            if hdr.seq > ch.next {
                // Gap: park until the missing frames arrive. A parked
                // duplicate is dropped.
                if ch.parked.insert(hdr.seq, msg).is_none() {
                    fault::bump(&plan.counters.rel_reorders);
                } else {
                    fault::bump(&plan.counters.rel_dup_drops);
                }
                continue;
            }
            // In sequence: admit, then splice any contiguous parked run
            // back into the rx queue front (order-preserving).
            ch.next += 1;
            let mut run = Vec::new();
            while let Some(parked) = ch.parked.remove(&ch.next) {
                ch.next += 1;
                run.push(parked);
            }
            drop(rx);
            let now = pnow(self.net.backend);
            for mut parked in run.into_iter().rev() {
                parked.rel = None; // already admitted; bypass re-checks
                parked.arrival = parked.arrival.min(now);
                ctx.push_front(parked);
            }
            return Some(msg);
        }
    }

    /// Deliver every limbo (reorder-delayed) frame destined to this
    /// process whose release time has passed.
    fn release_due_limbo(&self, rel: &RelState, plan: &Arc<FaultPlan>) {
        let now = pnow(self.net.backend);
        let due: Vec<(usize, WireMsg)> = {
            let mut limbo = rel.limbo.lock().unwrap_or_else(|e| e.into_inner());
            let mut due = Vec::new();
            for ((dst_proc, logical), frames) in limbo.iter_mut() {
                if *dst_proc != self.proc {
                    continue;
                }
                let mut i = 0;
                while i < frames.len() {
                    if frames[i].0 <= now {
                        let (_, mut msg) = frames.remove(i);
                        // The frame sat in limbo past its stamped
                        // arrival; it lands now.
                        msg.arrival = msg.arrival.max(now);
                        due.push((*logical, msg));
                    } else {
                        i += 1;
                    }
                }
            }
            limbo.retain(|_, v| !v.is_empty());
            due
        };
        for (logical, msg) in due {
            self.deliver_resolved(rel, plan, self.proc, logical, msg);
        }
    }

    /// Drop acked entries from one of our tx channels.
    fn prune_acked(&self, rel: &RelState, chan: ChanKey, ack: u64) {
        if ack == 0 {
            return;
        }
        let mut tx = rel.tx.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(ch) = tx.get_mut(&chan) {
            ch.unacked.retain(|&seq, _| seq > ack);
        }
    }

    /// Emit a standalone NIC-level ack (fault-exempt, no rel header).
    fn send_rel_ack(
        &self,
        rel: &RelState,
        dst_proc: ProcId,
        dst_ctx: usize,
        chan_dst_ctx: u32,
        ack: u64,
    ) {
        let phys = rel.resolve(dst_proc, dst_ctx);
        let target = self.context(dst_proc, phys);
        if target.is_killed() {
            return;
        }
        let arrival = pnow(self.net.backend) + self.net.costs.wire_latency;
        target.deliver(WireMsg {
            arrival,
            src_proc: self.proc,
            src_ctx: chan_dst_ctx as usize,
            rel: None,
            payload: Payload::RelAck { ack, chan_src_ctx: dst_ctx as u32, chan_dst_ctx },
        });
    }

    /// Retransmit every timed-out unacked frame this process sent.
    /// Driven from the MPI progress loop while a plan is installed
    /// (gated there on a cached flag — the fault-free path never calls
    /// this). Retransmissions roll a *fresh* fault decision (attempt
    /// participates in the key), so a dropped frame is eventually
    /// delivered with probability → 1 while staying deterministic.
    pub fn drive_retransmits(&self) {
        let Some(plan) = self.net.fault.get() else {
            return;
        };
        let rel = self.net.rel.get().expect("rel state installed with plan");
        let now = pnow(self.net.backend);
        let mut resend: Vec<(ChanKey, u64, u64, Payload)> = Vec::new();
        {
            let mut tx = rel.tx.lock().unwrap_or_else(|e| e.into_inner());
            for (&chan, ch) in tx.iter_mut() {
                if chan.0 != self.proc {
                    continue;
                }
                for (&seq, e) in ch.unacked.iter_mut() {
                    if e.resend_at <= now {
                        e.attempts += 1;
                        e.backoff = (e.backoff * 2).min(fault::MAX_BACKOFF_NS);
                        e.resend_at = now + e.backoff;
                        resend.push((chan, seq, e.attempts, e.payload.clone()));
                    }
                }
            }
        }
        for ((_, src_ctx, dst_proc, dst_ctx), seq, attempt, payload) in resend {
            fault::bump(&plan.counters.retransmits);
            let arrival = self.charge_inject(dst_proc, payload.wire_bytes());
            let header = RelHeader {
                seq,
                checksum: payload.digest(),
                ack: self.rx_cumulative(rel, (dst_proc, dst_ctx, self.proc, src_ctx)),
                chan_dst_ctx: dst_ctx as u32,
            };
            let mut msg =
                WireMsg { arrival, src_proc: self.proc, src_ctx, rel: Some(header), payload };
            match plan.decide(self.proc, src_ctx, dst_proc, dst_ctx, seq, attempt) {
                FaultDecision::Drop => {
                    fault::bump(&plan.counters.drops);
                }
                FaultDecision::Duplicate => {
                    fault::bump(&plan.counters.dups);
                    self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg.clone());
                    self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg);
                }
                FaultDecision::Corrupt => {
                    fault::bump(&plan.counters.corrupts);
                    let bit = plan.corrupt_bit(seq ^ attempt, msg.payload.wire_bytes() * 8);
                    if !msg.payload.flip_data_bit(bit) {
                        if let Some(h) = msg.rel.as_mut() {
                            h.checksum ^= 1 << (bit % 64);
                        }
                    }
                    self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg);
                }
                FaultDecision::Delay(extra) => {
                    fault::bump(&plan.counters.delays);
                    let release = msg.arrival + extra;
                    let mut limbo = rel.limbo.lock().unwrap_or_else(|e| e.into_inner());
                    limbo.entry((dst_proc, dst_ctx)).or_default().push((release, msg));
                }
                FaultDecision::None => {
                    self.deliver_resolved(rel, plan, dst_proc, dst_ctx, msg);
                }
            }
        }
    }

    /// Has local context `ctx_index` hard-failed (FaultPlan kill whose
    /// time has passed)?
    pub fn ctx_killed(&self, ctx_index: usize) -> bool {
        self.net.procs[self.proc].ctxs[ctx_index].get().is_some_and(|c| c.is_killed())
    }

    /// Install a lane-failover redirect for one of this process's
    /// contexts: traffic addressed to `from_ctx` (including in-flight
    /// retransmits and limbo frames) is delivered to `to_ctx` instead.
    /// Reliable-channel keys stay logical, so sequence continuity is
    /// preserved across the move. No-op without a fault plan.
    pub fn install_ctx_redirect(&self, from_ctx: usize, to_ctx: usize) {
        if let Some(rel) = self.net.rel.get() {
            let mut r = rel.redirect.lock().unwrap_or_else(|e| e.into_inner());
            // Collapse chains: anything of ours already pointing at
            // `from_ctx` now points at `to_ctx`.
            for ((p, _), v) in r.iter_mut() {
                if *p == self.proc && *v == from_ctx {
                    *v = to_ctx;
                }
            }
            r.insert((self.proc, from_ctx), to_ctx);
        }
    }

    /// Whether a fault plan is installed (cached by the MPI layer to
    /// gate every chaos-only branch on one bool).
    pub fn has_fault_plan(&self) -> bool {
        self.net.fault.get().is_some()
    }

    /// Installed fault plan, if any (chaos tests read its counters).
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.net.fault.get().cloned()
    }

    /// Completion stamp for a hardware-executed RMA (IB personality):
    /// DMA + round-trip wire, no target CPU involvement.
    pub fn hw_rma_completion_time(&self, dst_proc: ProcId, bytes: usize) -> u64 {
        let costs = &self.net.costs;
        let backend = self.net.backend;
        padvance(backend, costs.nic_inject);
        let intranode = self.net.node_of(self.proc) == self.net.node_of(dst_proc);
        if intranode {
            crate::platform::pnow(backend) + costs.memcpy_cost(bytes) + costs.shm_latency
        } else {
            crate::platform::pnow(backend) + costs.dma_cost(bytes) + 2 * costs.wire_latency
        }
    }

    /// Expose window memory for remote access (a passive-target
    /// [`WinLockWord`] is registered alongside it).
    pub fn register_window(&self, win: WinId, mem: Arc<WindowMem>) {
        self.net.procs[self.proc]
            .windows
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((win, mem, WinLockWord::new()));
    }

    pub fn deregister_window(&self, win: WinId) {
        let mut w = self.net.procs[self.proc].windows.lock().unwrap_or_else(|e| e.into_inner());
        w.retain(|(id, _, _)| *id != win);
    }

    /// Like [`ProcFabric::window`], but `None` for an unknown window —
    /// used by wire-message handlers, where a malformed window id must be
    /// droppable rather than a panic.
    pub fn find_window(&self, proc: ProcId, win: WinId) -> Option<Arc<WindowMem>> {
        self.net.procs[proc]
            .windows
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .find(|(id, _, _)| *id == win)
            .map(|(_, m, _)| m.clone())
    }

    /// Resolve a (proc, window) pair to its memory — the hardware
    /// address-translation path used by IB's hardware RMA.
    pub fn window(&self, proc: ProcId, win: WinId) -> Arc<WindowMem> {
        self.find_window(proc, win)
            .unwrap_or_else(|| panic!("window {win} of proc {proc} not registered"))
    }

    /// The passive-target lock word registered with a (proc, window) pair
    /// — the NIC-atomic path IB origins acquire epochs through. `None`
    /// for an unknown window (handlers/teardown must tolerate stale ids).
    pub fn find_win_lock(&self, proc: ProcId, win: WinId) -> Option<Arc<WinLockWord>> {
        self.net.procs[proc]
            .windows
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .find(|(id, _, _)| *id == win)
            .map(|(_, _, l)| l.clone())
    }

    /// Panicking variant of [`ProcFabric::find_win_lock`], for origin
    /// paths where the window is known registered (symmetric creation).
    pub fn win_lock_word(&self, proc: ProcId, win: WinId) -> Arc<WinLockWord> {
        self.find_win_lock(proc, win)
            .unwrap_or_else(|| panic!("lock word of window {win} of proc {proc} not registered"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(limit: usize) -> Arc<Network> {
        Network::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 1,
                procs_per_node: 2,
                max_contexts_per_node: limit,
            },
            Backend::Native,
            Arc::new(CostModel::default()),
        )
    }

    #[test]
    fn context_limit_enforced_per_node() {
        let n = net(3);
        let f0 = n.proc_fabric(0);
        let f1 = n.proc_fabric(1);
        assert!(f0.open_context().is_some());
        assert!(f0.open_context().is_some());
        assert!(f1.open_context().is_some());
        // Node limit of 3 reached across both procs.
        assert!(f1.open_context().is_none());
        // Closing frees a slot.
        f0.close_context(0);
        assert!(f1.open_context().is_some());
    }

    #[test]
    fn window_registry_roundtrip() {
        let n = net(8);
        let f0 = n.proc_fabric(0);
        let f1 = n.proc_fabric(1);
        let mem = WindowMem::new(64);
        f0.register_window(42, mem.clone());
        mem.write(8, &[1, 2, 3]);
        let view = f1.window(0, 42);
        assert_eq!(view.read(8, 3), vec![1, 2, 3]);
        f0.deregister_window(42);
    }

    #[test]
    fn node_mapping() {
        let n = Network::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: 3,
                procs_per_node: 4,
                max_contexts_per_node: 16,
            },
            Backend::Native,
            Arc::new(CostModel::default()),
        );
        assert_eq!(n.node_of(0), 0);
        assert_eq!(n.node_of(3), 0);
        assert_eq!(n.node_of(4), 1);
        assert_eq!(n.node_of(11), 2);
    }

    #[test]
    fn lock_word_shared_excludes_exclusive() {
        let w = WinLockWord::new();
        assert!(w.try_acquire(false));
        assert!(w.try_acquire(false), "shared holders are concurrent");
        assert!(!w.try_acquire(true), "exclusive blocked by shared holders");
        w.release(false);
        assert!(!w.try_acquire(true));
        w.release(false);
        assert!(w.is_idle());
        assert!(w.try_acquire(true));
        assert!(!w.try_acquire(false), "shared blocked by exclusive holder");
        assert!(!w.try_acquire(true));
        w.release(true);
        assert!(w.is_idle());
    }

    #[test]
    fn window_rmw_is_exclusive() {
        let mem = WindowMem::new(8);
        mem.rmw(|b| {
            b[0] = 5;
        });
        assert_eq!(mem.read(0, 1), vec![5]);
    }
}
