//! One NIC hardware context: the parallel unit of the network interface.
//!
//! A context is the physical realization of a VCI (paper §4.2): an OFI
//! endpoint bound to a completion queue (OPA) or a UCX worker wrapping a
//! Verbs QP (IB). Injection from the owning process and delivery from
//! remote contexts both touch the context's rx queue; access costs are
//! charged via the cost model. Contexts are independent — this independence
//! is exactly what multi-VCI exploits.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::platform::{padvance, pnow, Backend};
use crate::sim::CostModel;

use super::wire::{Payload, ProcId, WireMsg};

/// Receive side of a hardware context.
pub struct HwContext {
    /// Messages from remote contexts. A real adapter's recv queue is fed
    /// by the wire with NO local software involvement — remote senders and
    /// the local poller never contend on a lock. The host mutex below only
    /// keeps the host-side data structure sane; it charges no virtual
    /// time (the explicit rx/poll costs model the CQ reads).
    rx: Mutex<VecDeque<WireMsg>>,
    backend: Backend,
}

impl HwContext {
    pub fn new(backend: Backend) -> Self {
        HwContext { rx: Mutex::new(VecDeque::new()), backend }
    }

    /// Deliver a message (called by remote injectors / the wire).
    pub fn deliver(&self, msg: WireMsg) {
        self.rx.lock().unwrap_or_else(|e| e.into_inner()).push_back(msg);
    }

    /// Poll for one arrived message. Messages still "in flight" (arrival in
    /// the virtual future) are invisible; conservative scheduling guarantees
    /// senders run first, so arrival order is globally consistent.
    pub fn poll(&self, costs: &CostModel) -> Option<WireMsg> {
        let mut q = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        let now = pnow(self.backend);
        match q.front() {
            Some(m) if m.arrival <= now => {
                padvance(self.backend, costs.nic_rx_deliver);
                q.pop_front()
            }
            Some(m) => {
                // Head-of-line message is still on the wire: model the CQ
                // read that found nothing ready.
                let _ = m;
                padvance(self.backend, costs.poll_empty);
                None
            }
            None => {
                padvance(self.backend, costs.poll_empty);
                None
            }
        }
    }

    /// Like [`HwContext::poll`], but pops the head message only when it
    /// has arrived AND satisfies `pred`. Used by the striped progress
    /// path to drain a contiguous run of re-routable messages in one
    /// sweep; a failed predicate charges nothing (the CQ entry was
    /// already read by the preceding poll of this sweep).
    pub fn poll_if(
        &self,
        costs: &CostModel,
        pred: impl FnOnce(&WireMsg) -> bool,
    ) -> Option<WireMsg> {
        let mut q = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        let now = pnow(self.backend);
        match q.front() {
            Some(m) if m.arrival <= now && pred(m) => {
                padvance(self.backend, costs.nic_rx_deliver);
                q.pop_front()
            }
            _ => None,
        }
    }

    /// Number of queued messages (arrived or in flight). Test/debug aid.
    pub fn queued(&self) -> usize {
        self.rx.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// TX path handle: injects messages into remote contexts with modeled
/// per-message cost. One `Injector` per (process, context-index); it is the
/// resource a VCI owns exclusively.
pub struct Injector {
    pub proc: ProcId,
    pub ctx_index: usize,
    backend: Backend,
    costs: Arc<CostModel>,
}

impl Injector {
    pub fn new(proc: ProcId, ctx_index: usize, backend: Backend, costs: Arc<CostModel>) -> Self {
        Injector { proc, ctx_index, backend, costs }
    }

    /// Inject `payload` toward `target` context. Charges descriptor +
    /// doorbell to the caller; DMA and wire latency accrue on the message's
    /// arrival stamp, not the caller's clock (the NIC works asynchronously).
    pub fn inject(&self, target: &HwContext, payload: Payload) {
        padvance(self.backend, self.costs.nic_inject);
        let bytes = payload.wire_bytes();
        let arrival = pnow(self.backend) + self.costs.dma_cost(bytes) + self.costs.wire_latency;
        target.deliver(WireMsg {
            arrival,
            src_proc: self.proc,
            src_ctx: self.ctx_index,
            payload,
        });
    }

    /// Time at which a hardware-executed RMA of `bytes` completes at the
    /// initiator (IB personality): DMA + wire + NIC-level ack.
    pub fn hw_rma_completion_time(&self, bytes: usize) -> u64 {
        padvance(self.backend, self.costs.nic_inject);
        pnow(self.backend) + self.costs.dma_cost(bytes) + 2 * self.costs.wire_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Sim, SimOutcome};

    #[test]
    fn inflight_messages_invisible_until_arrival() {
        let costs = Arc::new(CostModel::default());
        let ctx = Arc::new(HwContext::new(Backend::Sim));
        let inj = {
            let costs = costs.clone();
            Arc::new(Injector::new(0, 0, Backend::Sim, costs))
        };
        let mut sim = Sim::new((*costs).clone());
        let c2 = ctx.clone();
        let costs2 = costs.clone();
        sim.spawn_setup("sender", move || {
            inj.inject(&c2, Payload::SendAck { send_handle: 1 });
        });
        let c3 = ctx.clone();
        sim.spawn_setup("receiver", move || {
            // Immediately polling (clock ~0 after sender runs) must miss:
            // the message is still on the wire.
            let mut seen_early = false;
            if c3.poll(&costs2).is_some() {
                seen_early = true;
            }
            assert!(!seen_early, "message visible before wire latency elapsed");
            // Spin in virtual time until it lands.
            let mut got = None;
            for _ in 0..100 {
                crate::sim::advance(100);
                if let Some(m) = c3.poll(&costs2) {
                    got = Some(m);
                    break;
                }
            }
            let m = got.expect("message should arrive");
            assert!(crate::sim::now() >= m.arrival);
        });
        assert_eq!(sim.run().outcome, SimOutcome::Completed);
    }

    #[test]
    fn native_backend_delivers_immediately_visible() {
        // Native: pnow is wallclock; arrival stamp is in the past by the
        // time anyone polls (wire latency is sub-microsecond).
        let costs = Arc::new(CostModel::default());
        let ctx = HwContext::new(Backend::Native);
        let inj = Injector::new(0, 0, Backend::Native, costs.clone());
        inj.inject(&ctx, Payload::SendAck { send_handle: 7 });
        std::thread::sleep(std::time::Duration::from_micros(5));
        let m = ctx.poll(&costs).expect("delivered");
        assert!(matches!(m.payload, Payload::SendAck { send_handle: 7 }));
    }
}
