//! One NIC hardware context: the parallel unit of the network interface.
//!
//! A context is the physical realization of a VCI (paper §4.2): an OFI
//! endpoint bound to a completion queue (OPA) or a UCX worker wrapping a
//! Verbs QP (IB). Injection from the owning process and delivery from
//! remote contexts both touch the context's rx queue; access costs are
//! charged via the cost model. Contexts are independent — this independence
//! is exactly what multi-VCI exploits.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::platform::{padvance, pnow, Backend};
use crate::sim::CostModel;

use super::wire::{Payload, ProcId, WireMsg};

/// Rx-nonempty doorbell shared by a group of contexts (one per VCI pool):
/// bit `i` is set while context `i`'s rx queue holds messages, so a
/// progress sweep can skip contexts with nothing queued instead of paying
/// an empty CQ read per context. Models the NIC's event/interrupt
/// coalescing word: maintained by hardware (deliver) for free, read by
/// software with one load.
pub struct RxDoorbell {
    words: Vec<AtomicU64>,
}

impl RxDoorbell {
    pub fn new(slots: usize) -> Arc<Self> {
        let words = (0..slots.div_ceil(64)).map(|_| AtomicU64::new(0)).collect();
        Arc::new(RxDoorbell { words })
    }

    fn set(&self, slot: usize) {
        self.words[slot / 64].fetch_or(1 << (slot % 64), Ordering::Release);
    }

    fn clear(&self, slot: usize) {
        self.words[slot / 64].fetch_and(!(1 << (slot % 64)), Ordering::Release);
    }

    /// Is slot `i`'s bit currently set?
    pub fn is_set(&self, slot: usize) -> bool {
        self.words[slot / 64].load(Ordering::Acquire) & (1 << (slot % 64)) != 0
    }

    /// Any bit set at all? (One load per 64 slots.)
    pub fn any_set(&self) -> bool {
        self.words.iter().any(|w| w.load(Ordering::Acquire) != 0)
    }

    /// First set slot in `< n`, scanning circularly from `start`. `None`
    /// when no doorbell is rung. One atomic load per 64 slots: whole
    /// words are scanned with `trailing_zeros`, with the first word's
    /// below-`start` bits masked off and re-visited after the wrap.
    pub fn next_set(&self, start: usize, n: usize) -> Option<usize> {
        if n == 0 {
            return None;
        }
        let start = start % n;
        let nwords = self.words.len();
        let first = start / 64;
        let low_mask = !(!0u64 << (start % 64)); // bits strictly below start
        for step in 0..=nwords {
            let wi = (first + step) % nwords;
            let mut w = self.words[wi].load(Ordering::Acquire);
            if step == 0 {
                w &= !low_mask; // at or above start
            } else if step == nwords {
                w &= low_mask; // the wrapped-around remainder
            }
            if w != 0 {
                let slot = wi * 64 + w.trailing_zeros() as usize;
                // Slots >= n are never set (no context is bound there).
                debug_assert!(slot < n, "doorbell bit {slot} beyond pool size {n}");
                return Some(slot);
            }
        }
        None
    }
}

/// Receive side of a hardware context.
pub struct HwContext {
    /// Messages from remote contexts. A real adapter's recv queue is fed
    /// by the wire with NO local software involvement — remote senders and
    /// the local poller never contend on a lock. The host mutex below only
    /// keeps the host-side data structure sane; it charges no virtual
    /// time (the explicit rx/poll costs model the CQ reads).
    rx: Mutex<VecDeque<WireMsg>>,
    /// Installed by the owning VCI pool: (shared doorbell, this context's
    /// slot). Set/cleared under the rx lock, so the bit can never lag a
    /// delivery: any message pushed while the bit reads clear is pushed
    /// before the next poll observes the queue.
    doorbell: OnceLock<(Arc<RxDoorbell>, usize)>,
    /// Virtual time at which this context hard-fails (a FaultPlan
    /// `kill`); `u64::MAX` = never. Once dead, deliveries are dropped
    /// on the floor and the owning proc's progress loop fails the lane
    /// over to a survivor.
    killed_at: AtomicU64,
    backend: Backend,
}

impl HwContext {
    pub fn new(backend: Backend) -> Self {
        HwContext {
            rx: Mutex::new(VecDeque::new()),
            doorbell: OnceLock::new(),
            killed_at: AtomicU64::new(u64::MAX),
            backend,
        }
    }

    /// Schedule this context to hard-fail at virtual time `at_ns`.
    pub fn kill_at(&self, at_ns: u64) {
        self.killed_at.store(at_ns, Ordering::Release);
    }

    /// Has the scheduled hard-fail time passed?
    pub fn is_killed(&self) -> bool {
        self.killed_at.load(Ordering::Acquire) <= pnow(self.backend)
    }

    /// Bind this context's rx queue to `slot` of a pool-wide doorbell.
    /// Installing twice is a no-op (contexts bind to exactly one VCI).
    pub fn install_doorbell(&self, bell: Arc<RxDoorbell>, slot: usize) {
        let _ = self.doorbell.set((bell, slot));
    }

    /// Deliver a message (called by remote injectors / the wire).
    /// Deliveries to a hard-failed context vanish — the NIC is gone.
    /// (The fault layer counts these; this uncounted guard also covers
    /// direct `Injector` use.)
    pub fn deliver(&self, msg: WireMsg) {
        if self.is_killed() {
            return;
        }
        let mut q = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        q.push_back(msg);
        if let Some((bell, slot)) = self.doorbell.get() {
            bell.set(*slot);
        }
    }

    /// Re-admit a frame at the *front* of the rx queue — used by the
    /// reliable-delivery layer to splice parked (reordered) frames back
    /// in sequence ahead of later traffic.
    pub fn push_front(&self, msg: WireMsg) {
        let mut q = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        q.push_front(msg);
        if let Some((bell, slot)) = self.doorbell.get() {
            bell.set(*slot);
        }
    }

    /// Poll for one arrived message. Messages still "in flight" (arrival in
    /// the virtual future) are invisible; conservative scheduling guarantees
    /// senders run first, so arrival order is globally consistent.
    pub fn poll(&self, costs: &CostModel) -> Option<WireMsg> {
        let mut q = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        let now = pnow(self.backend);
        match q.front() {
            Some(m) if m.arrival <= now => {
                padvance(self.backend, costs.nic_rx_deliver);
                let msg = q.pop_front();
                if q.is_empty() {
                    self.clear_doorbell();
                }
                msg
            }
            Some(m) => {
                // Head-of-line message is still on the wire: model the CQ
                // read that found nothing ready. The doorbell stays rung —
                // the message is queued, just not yet visible.
                let _ = m;
                padvance(self.backend, costs.poll_empty);
                None
            }
            None => {
                padvance(self.backend, costs.poll_empty);
                self.clear_doorbell();
                None
            }
        }
    }

    /// Clear this context's doorbell bit. Callers hold the rx lock with
    /// the queue observed empty, so a concurrent deliver re-sets the bit
    /// only after its push — the bit never reads clear with a message
    /// sitting in the queue.
    fn clear_doorbell(&self) {
        if let Some((bell, slot)) = self.doorbell.get() {
            bell.clear(*slot);
        }
    }

    /// Number of queued messages (arrived or in flight). Test/debug aid.
    pub fn queued(&self) -> usize {
        self.rx.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// TX path handle: injects messages into remote contexts with modeled
/// per-message cost. One `Injector` per (process, context-index); it is the
/// resource a VCI owns exclusively.
pub struct Injector {
    pub proc: ProcId,
    pub ctx_index: usize,
    backend: Backend,
    costs: Arc<CostModel>,
}

impl Injector {
    pub fn new(proc: ProcId, ctx_index: usize, backend: Backend, costs: Arc<CostModel>) -> Self {
        Injector { proc, ctx_index, backend, costs }
    }

    /// Inject `payload` toward `target` context. Charges descriptor +
    /// doorbell to the caller; DMA and wire latency accrue on the message's
    /// arrival stamp, not the caller's clock (the NIC works asynchronously).
    pub fn inject(&self, target: &HwContext, payload: Payload) {
        padvance(self.backend, self.costs.nic_inject);
        let bytes = payload.wire_bytes();
        let arrival = pnow(self.backend) + self.costs.dma_cost(bytes) + self.costs.wire_latency;
        target.deliver(WireMsg {
            arrival,
            src_proc: self.proc,
            src_ctx: self.ctx_index,
            rel: None,
            payload,
        });
    }

    /// Time at which a hardware-executed RMA of `bytes` completes at the
    /// initiator (IB personality): DMA + wire + NIC-level ack.
    pub fn hw_rma_completion_time(&self, bytes: usize) -> u64 {
        padvance(self.backend, self.costs.nic_inject);
        pnow(self.backend) + self.costs.dma_cost(bytes) + 2 * self.costs.wire_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Sim, SimOutcome};

    #[test]
    fn inflight_messages_invisible_until_arrival() {
        let costs = Arc::new(CostModel::default());
        let ctx = Arc::new(HwContext::new(Backend::Sim));
        let inj = {
            let costs = costs.clone();
            Arc::new(Injector::new(0, 0, Backend::Sim, costs))
        };
        let mut sim = Sim::new((*costs).clone());
        let c2 = ctx.clone();
        let costs2 = costs.clone();
        sim.spawn_setup("sender", move || {
            inj.inject(&c2, Payload::SendAck { send_handle: 1 });
        });
        let c3 = ctx.clone();
        sim.spawn_setup("receiver", move || {
            // Immediately polling (clock ~0 after sender runs) must miss:
            // the message is still on the wire.
            let mut seen_early = false;
            if c3.poll(&costs2).is_some() {
                seen_early = true;
            }
            assert!(!seen_early, "message visible before wire latency elapsed");
            // Spin in virtual time until it lands.
            let mut got = None;
            for _ in 0..100 {
                crate::sim::advance(100);
                if let Some(m) = c3.poll(&costs2) {
                    got = Some(m);
                    break;
                }
            }
            let m = got.expect("message should arrive");
            assert!(crate::sim::now() >= m.arrival);
        });
        assert_eq!(sim.run().outcome, SimOutcome::Completed);
    }

    #[test]
    fn doorbell_tracks_rx_nonempty() {
        let costs = Arc::new(CostModel::default());
        let ctx = HwContext::new(Backend::Native);
        let bell = RxDoorbell::new(3);
        ctx.install_doorbell(bell.clone(), 2);
        assert!(!bell.any_set());
        assert_eq!(bell.next_set(0, 3), None);
        let inj = Injector::new(0, 0, Backend::Native, costs.clone());
        inj.inject(&ctx, Payload::SendAck { send_handle: 1 });
        inj.inject(&ctx, Payload::SendAck { send_handle: 2 });
        assert!(bell.is_set(2));
        assert_eq!(bell.next_set(0, 3), Some(2));
        assert_eq!(bell.next_set(2, 3), Some(2), "scan is circular from start");
        std::thread::sleep(std::time::Duration::from_micros(5));
        assert!(ctx.poll(&costs).is_some());
        assert!(bell.is_set(2), "bit stays rung while messages remain");
        assert!(ctx.poll(&costs).is_some());
        assert!(!bell.is_set(2), "draining the queue clears the bit");
        assert!(ctx.poll(&costs).is_none());
        assert!(!bell.any_set());
    }

    #[test]
    fn doorbell_multiword_slots() {
        let bell = RxDoorbell::new(130);
        bell.set(0);
        bell.set(127);
        bell.set(129);
        assert!(bell.is_set(127) && bell.is_set(129) && bell.is_set(0));
        assert_eq!(bell.next_set(1, 130), Some(127));
        bell.clear(127);
        assert_eq!(bell.next_set(1, 130), Some(129));
        bell.clear(129);
        assert_eq!(bell.next_set(1, 130), Some(0), "wraps to the low word");
    }

    #[test]
    fn native_backend_delivers_immediately_visible() {
        // Native: pnow is wallclock; arrival stamp is in the past by the
        // time anyone polls (wire latency is sub-microsecond).
        let costs = Arc::new(CostModel::default());
        let ctx = HwContext::new(Backend::Native);
        let inj = Injector::new(0, 0, Backend::Native, costs.clone());
        inj.inject(&ctx, Payload::SendAck { send_handle: 7 });
        std::thread::sleep(std::time::Duration::from_micros(5));
        let m = ctx.poll(&costs).expect("delivered");
        assert!(matches!(m.payload, Payload::SendAck { send_handle: 7 }));
    }
}
