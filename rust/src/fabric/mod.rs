//! Simulated interconnect: NIC hardware contexts, the wire, and the two
//! interconnect personalities from the paper's testbeds.
//!
//! * [`Interconnect::Opa`] — Intel Omni-Path-like (paper: OFI netmod +
//!   PSM2). RMA is **emulated in software**: a Put/Get becomes an active
//!   message that the *target-side CPU* must process by polling the target
//!   context; absent application polling, only a low-frequency PSM2-style
//!   progress thread drains it. This is what makes the paper's Figs. 13-16,
//!   24-25 and 27 behave the way they do.
//! * [`Interconnect::Ib`] — Mellanox InfiniBand EDR-like (paper: UCX netmod
//!   + Verbs). Contiguous Put/Get execute **fully in hardware**: the
//!   initiating side moves the bytes with no target CPU involvement, so RMA
//!   completes promptly regardless of what target threads are doing.
//!
//! A [`HwContext`] models one NIC hardware context (an OFI endpoint+CQ or a
//! UCX worker/QP): an rx queue fed by remote injections, with per-message
//! injection/DMA/wire costs charged in virtual time. Contexts per node are
//! limited ([`FabricConfig::max_contexts_per_node`]) like real adapters
//! (160 on the Intel HFI).

mod context;
mod fault;
mod registry;
mod wire;

pub use context::{HwContext, Injector, RxDoorbell};
pub use fault::{CtxKill, FaultDecision, FaultPlan, FaultStats};
pub use registry::{FabricConfig, Network, ProcFabric, WindowMem, WinLockWord};
pub use wire::{
    AccOp, LockKind, P2pProtocol, Payload, ProcId, RelHeader, RmaCompletion, WireMsg, WinId,
};

/// Interconnect personality (paper §3: the two testbed families).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Interconnect {
    /// Omni-Path-like: software-emulated RMA, target progress required.
    Opa,
    /// InfiniBand-like: hardware Put/Get, no target CPU involvement.
    Ib,
}
