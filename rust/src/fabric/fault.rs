//! Deterministic fabric fault injection and the reliable-delivery state
//! that survives it.
//!
//! A [`FaultPlan`] is a seeded, per-link schedule installed on the
//! [`Network`](super::Network) (config key `vcmpi_fault_plan`). Every
//! injected frame rolls one fault decision — drop, duplicate,
//! reorder-delay, corrupt, or nothing — from a SplitMix stream keyed by
//! (seed, link, wire sequence number, attempt), so a given plan produces
//! the *same* faults at the same points on every run: chaos tests are
//! bit-for-bit reproducible under the DES determinism contract.
//!
//! When a plan is installed the fabric also turns on **reliable
//! delivery** ([`RelState`]): frames carry a [`RelHeader`] with a
//! per-channel sequence number, a payload checksum, and a piggybacked
//! cumulative ack; receivers drop corrupt and duplicate frames
//! (counted, never panicking) and re-order parked frames back into
//! sequence; senders keep the unacked window and retransmit on a
//! sim-time timeout with exponential backoff. None of this state exists
//! when no plan is installed — the fault-free path is one `OnceLock`
//! load.
//!
//! Channels are keyed by the **logical** destination context index (the
//! one the sender addressed), not the physical one a failover redirect
//! resolves to: sequence continuity survives a lane failover, so the
//! survivor lane admits the dead lane's in-flight traffic in order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::mix64;

use super::wire::{ProcId, WireMsg};

/// Golden-ratio increment (SplitMix64 stream constant).
const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// Hard-fail one hardware context at a chosen sim time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CtxKill {
    pub proc: ProcId,
    pub ctx: usize,
    /// Virtual time (ns) at which the context dies. Frames delivered at
    /// or after this instant are dropped on the floor (counted).
    pub at_ns: u64,
}

/// One per-frame fault decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultDecision {
    None,
    /// Frame never delivered; the retransmit path recovers it.
    Drop,
    /// Frame delivered twice (the receiver's dedup drops the echo).
    Duplicate,
    /// Payload (or, for dataless control frames, the checksum) is
    /// bit-flipped in flight; the receiver's checksum drops it.
    Corrupt,
    /// Frame parked in limbo for this many extra ns — real reordering,
    /// since the rx queue is popped in *delivery* order.
    Delay(u64),
}

/// Injected-fault and recovery counters. All relaxed atomics: exact
/// values are deterministic under the DES (single running thread).
#[derive(Default)]
pub struct FaultCounters {
    pub drops: AtomicU64,
    pub dups: AtomicU64,
    pub corrupts: AtomicU64,
    pub delays: AtomicU64,
    /// Frames dropped because the destination context was hard-failed.
    pub kill_drops: AtomicU64,
    pub retransmits: AtomicU64,
    /// Receiver-side drops: frame already admitted (stale seq).
    pub rel_dup_drops: AtomicU64,
    /// Receiver-side drops: checksum mismatch.
    pub rel_corrupt_drops: AtomicU64,
    /// Out-of-order frames parked until the gap fills.
    pub rel_reorders: AtomicU64,
}

/// Plain snapshot of [`FaultCounters`] for bit-for-bit comparison.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub drops: u64,
    pub dups: u64,
    pub corrupts: u64,
    pub delays: u64,
    pub kill_drops: u64,
    pub retransmits: u64,
    pub rel_dup_drops: u64,
    pub rel_corrupt_drops: u64,
    pub rel_reorders: u64,
}

impl FaultCounters {
    pub fn snapshot(&self) -> FaultStats {
        FaultStats {
            drops: self.drops.load(Ordering::Relaxed),
            dups: self.dups.load(Ordering::Relaxed),
            corrupts: self.corrupts.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            kill_drops: self.kill_drops.load(Ordering::Relaxed),
            retransmits: self.retransmits.load(Ordering::Relaxed),
            rel_dup_drops: self.rel_dup_drops.load(Ordering::Relaxed),
            rel_corrupt_drops: self.rel_corrupt_drops.load(Ordering::Relaxed),
            rel_reorders: self.rel_reorders.load(Ordering::Relaxed),
        }
    }
}

/// Relaxed increment helper for fault counters.
pub(super) fn bump(which: &AtomicU64) {
    which.fetch_add(1, Ordering::Relaxed);
}

/// A seeded per-link fault schedule. Probabilities are per-mille of
/// injected frames; at most one fault fires per (frame, attempt).
#[derive(Debug)]
pub struct FaultPlan {
    pub seed: u64,
    pub drop_pm: u64,
    pub dup_pm: u64,
    pub corrupt_pm: u64,
    pub delay_pm: u64,
    /// Extra in-flight time for a `Delay` decision.
    pub delay_ns: u64,
    /// Base retransmit timeout (doubles per attempt, capped).
    pub retransmit_timeout_ns: u64,
    pub kills: Vec<CtxKill>,
    pub counters: FaultCounters,
}

impl FaultPlan {
    /// A plan with the given seed and no faults enabled; set the
    /// per-mille fields to taste (tests) or use [`FaultPlan::parse`].
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            drop_pm: 0,
            dup_pm: 0,
            corrupt_pm: 0,
            delay_pm: 0,
            delay_ns: 20_000,
            retransmit_timeout_ns: 200_000,
            kills: Vec::new(),
            counters: FaultCounters::default(),
        }
    }

    /// Parse the `vcmpi_fault_plan` spec string: comma-separated
    /// `key=value` pairs. Keys: `seed`, `drop`/`dup`/`corrupt`/`delay`
    /// (per-mille), `delay_ns`, `timeout_ns`, and repeatable
    /// `kill=<proc>:<ctx>@<at_ns>`.
    ///
    /// Example: `seed=42,drop=20,dup=5,corrupt=10,delay=15,kill=1:2@5000000`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(0);
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault plan: `{part}` is not key=value"))?;
            let num = |v: &str| -> Result<u64, String> {
                v.parse::<u64>().map_err(|_| format!("fault plan: `{key}={v}` is not a number"))
            };
            match key {
                "seed" => plan.seed = num(val)?,
                "drop" => plan.drop_pm = num(val)?,
                "dup" => plan.dup_pm = num(val)?,
                "corrupt" => plan.corrupt_pm = num(val)?,
                "delay" => plan.delay_pm = num(val)?,
                "delay_ns" => plan.delay_ns = num(val)?,
                "timeout_ns" => plan.retransmit_timeout_ns = num(val)?,
                "kill" => {
                    let (pc, at) = val
                        .split_once('@')
                        .ok_or_else(|| format!("fault plan: kill `{val}` wants proc:ctx@ns"))?;
                    let (p, c) = pc
                        .split_once(':')
                        .ok_or_else(|| format!("fault plan: kill `{val}` wants proc:ctx@ns"))?;
                    plan.kills.push(CtxKill {
                        proc: num(p)? as ProcId,
                        ctx: num(c)? as usize,
                        at_ns: num(at)?,
                    });
                }
                _ => return Err(format!("fault plan: unknown key `{key}`")),
            }
        }
        if plan.drop_pm + plan.dup_pm + plan.corrupt_pm + plan.delay_pm > 1000 {
            return Err("fault plan: per-mille probabilities exceed 1000".into());
        }
        Ok(plan)
    }

    /// Does any fault class ever fire? (Kills still count.)
    pub fn any_frame_faults(&self) -> bool {
        self.drop_pm + self.dup_pm + self.corrupt_pm + self.delay_pm > 0
    }

    /// The per-frame decision: one SplitMix draw keyed by (seed, link,
    /// seq, attempt). Attempt participates so a retransmission of a
    /// dropped frame rolls a fresh (but still reproducible) decision —
    /// otherwise a dropped seq would be dropped forever.
    pub fn decide(
        &self,
        src_proc: ProcId,
        src_ctx: usize,
        dst_proc: ProcId,
        dst_ctx: usize,
        seq: u64,
        attempt: u64,
    ) -> FaultDecision {
        let link = mix64(
            ((src_proc as u64) << 48)
                ^ ((src_ctx as u64) << 32)
                ^ ((dst_proc as u64) << 16)
                ^ (dst_ctx as u64),
        );
        let roll = mix64(
            self.seed ^ link ^ mix64(seq.wrapping_mul(GOLDEN)) ^ attempt.wrapping_mul(GOLDEN),
        );
        let r = roll % 1000;
        if r < self.drop_pm {
            FaultDecision::Drop
        } else if r < self.drop_pm + self.dup_pm {
            FaultDecision::Duplicate
        } else if r < self.drop_pm + self.dup_pm + self.corrupt_pm {
            FaultDecision::Corrupt
        } else if r < self.drop_pm + self.dup_pm + self.corrupt_pm + self.delay_pm {
            // Vary the delay a little (same stream, different lane of it)
            // so delayed frames don't all land on one instant.
            let jitter = mix64(roll.wrapping_add(GOLDEN)) % self.delay_ns.max(1);
            FaultDecision::Delay(self.delay_ns + jitter)
        } else {
            FaultDecision::None
        }
    }

    /// Which bit (of the wire payload) a `Corrupt` decision flips, drawn
    /// from the same stream as the decision itself.
    pub fn corrupt_bit(&self, seq: u64, len_bits: usize) -> usize {
        (mix64(self.seed ^ seq.wrapping_mul(GOLDEN) ^ GOLDEN) % len_bits.max(1) as u64) as usize
    }
}

/// One sender-side unacked frame.
#[derive(Debug)]
pub struct TxEntry {
    pub payload: super::wire::Payload,
    /// Next sim time at which this frame is retransmitted.
    pub resend_at: u64,
    /// Current backoff interval (doubles per attempt, capped).
    pub backoff: u64,
    /// Retransmission count so far (0 = only the original send).
    pub attempts: u64,
}

/// Sender side of one reliable channel.
#[derive(Debug, Default)]
pub struct TxChannel {
    /// Next sequence number to assign. Sequences start at 1.
    pub next_seq: u64,
    pub unacked: BTreeMap<u64, TxEntry>,
}

/// Receiver side of one reliable channel.
#[derive(Debug)]
pub struct RxChannel {
    /// Next expected sequence (cumulative delivered = `next - 1`).
    pub next: u64,
    /// Out-of-order frames waiting for the gap to fill.
    pub parked: BTreeMap<u64, WireMsg>,
}

impl Default for RxChannel {
    fn default() -> Self {
        RxChannel { next: 1, parked: BTreeMap::new() }
    }
}

/// Reliable channel key: (src proc, src ctx, dst proc, **logical** dst
/// ctx). BTreeMaps keep every iteration (retransmit scans, limbo
/// release) in deterministic order — HashMap order is randomized and
/// would break replay.
pub type ChanKey = (ProcId, usize, ProcId, usize);

/// All reliable-delivery state, allocated only when a plan is installed.
#[derive(Default)]
pub struct RelState {
    pub tx: Mutex<BTreeMap<ChanKey, TxChannel>>,
    pub rx: Mutex<BTreeMap<ChanKey, RxChannel>>,
    /// Reorder-delayed frames, keyed by (dst proc, logical dst ctx),
    /// each with its release time. Redirects resolve at release.
    pub limbo: Mutex<BTreeMap<(ProcId, usize), Vec<(u64, WireMsg)>>>,
    /// Lane-failover context redirects: (proc, logical ctx) → physical
    /// ctx. Installed by the owning proc; applied at every delivery.
    pub redirect: Mutex<BTreeMap<(ProcId, usize), usize>>,
}

impl RelState {
    /// Resolve a failover redirect (identity when none installed).
    pub fn resolve(&self, proc: ProcId, ctx: usize) -> usize {
        let r = self.redirect.lock().unwrap_or_else(|e| e.into_inner());
        *r.get(&(proc, ctx)).unwrap_or(&ctx)
    }
}

/// Cap for exponential backoff so `resend_at` can't overflow u64 even
/// under absurd virtual times.
pub const MAX_BACKOFF_NS: u64 = 1 << 40;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let p = FaultPlan::parse("seed=42, drop=20,dup=5,corrupt=10,delay=15,delay_ns=2000,timeout_ns=9000,kill=1:2@5000000,kill=0:1@7")
            .expect("parses");
        assert_eq!(p.seed, 42);
        assert_eq!(p.drop_pm, 20);
        assert_eq!(p.dup_pm, 5);
        assert_eq!(p.corrupt_pm, 10);
        assert_eq!(p.delay_pm, 15);
        assert_eq!(p.delay_ns, 2000);
        assert_eq!(p.retransmit_timeout_ns, 9000);
        assert_eq!(
            p.kills,
            vec![
                CtxKill { proc: 1, ctx: 2, at_ns: 5_000_000 },
                CtxKill { proc: 0, ctx: 1, at_ns: 7 }
            ]
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("drop").is_err());
        assert!(FaultPlan::parse("drop=many").is_err());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
        assert!(FaultPlan::parse("kill=1@2").is_err());
        assert!(FaultPlan::parse("drop=600,dup=600").is_err());
    }

    #[test]
    fn decisions_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::parse("seed=1,drop=100,dup=50,corrupt=50,delay=50").unwrap();
        let b = FaultPlan::parse("seed=1,drop=100,dup=50,corrupt=50,delay=50").unwrap();
        let c = FaultPlan::parse("seed=2,drop=100,dup=50,corrupt=50,delay=50").unwrap();
        let mut differs = false;
        for seq in 0..512 {
            let da = a.decide(0, 1, 1, 2, seq, 0);
            assert_eq!(da, b.decide(0, 1, 1, 2, seq, 0), "same seed, same decision");
            // Attempt participates: a retransmit rolls fresh.
            let _ = a.decide(0, 1, 1, 2, seq, 1);
            if da != c.decide(0, 1, 1, 2, seq, 0) {
                differs = true;
            }
        }
        assert!(differs, "different seeds should diverge somewhere in 512 draws");
    }

    #[test]
    fn decision_rates_roughly_match_per_mille() {
        let p = FaultPlan::parse("seed=7,drop=200").unwrap();
        let drops = (0..10_000)
            .filter(|&s| p.decide(0, 0, 1, 0, s, 0) == FaultDecision::Drop)
            .count();
        // 200 per mille of 10k = 2000; allow a generous band.
        assert!((1500..2500).contains(&drops), "drop rate {drops}/10000 far from 20%");
    }
}
