//! The paper's three applications (§6), one per category:
//!
//! * [`stencil`] — 2-D 5-point halo exchange (category 1: directly usable
//!   dedicated channels). Fig. 22.
//! * [`ebms`] — OpenMC energy-band RMA fetch (categories 1+2: independent
//!   gets, but shared progress on software-RMA fabrics). Figs. 24, 25.
//! * [`bspmm`] — NWChem block-sparse matmul, get-compute-update
//!   (category 3: accumulate semantics pin threads to one window).
//!   Fig. 27.
//!
//! Each module provides a sim-backend benchmark (the paper's figure) and a
//! native-backend driver with real PJRT compute (used by `examples/`).

pub mod bspmm;
pub mod ebms;
pub mod stencil;

/// App execution mode (the subset of §5 modes the app figures use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppMode {
    Everywhere,
    ParCommVcis,
    ParCommOrig,
    Endpoints,
}

impl AppMode {
    pub fn label(&self) -> &'static str {
        match self {
            AppMode::Everywhere => "everywhere",
            AppMode::ParCommVcis => "par+vcis",
            AppMode::ParCommOrig => "par+orig_mpich",
            AppMode::Endpoints => "endpoints",
        }
    }

    pub fn all() -> [AppMode; 4] {
        [AppMode::Everywhere, AppMode::ParCommVcis, AppMode::ParCommOrig, AppMode::Endpoints]
    }
}
