//! 2-D 5-point stencil halo exchange (paper §6.1, Fig. 22).
//!
//! Topology: `nodes_x * nodes_y` nodes, each running a `tx * ty` block of
//! workers (threads for MPI+threads, processes for MPI everywhere). The
//! global mesh is partitioned into per-worker blocks; each iteration
//! exchanges 1-cell halos with the four neighbors.
//!
//! * MPI+threads: internode halos go through MPI; intranode halos read
//!   shared memory directly (modeled as a memcpy charge) — the paper's
//!   setup.
//! * MPI everywhere: every halo (intra- and internode) goes through MPI;
//!   the fabric routes same-node traffic over the shm path.
//!
//! Communicator scheme for par_comm (paper Fig. 21): for each direction
//! (NS, EW) and node-parity (even, odd) there is one communicator per
//! boundary lane, so no two threads of a rank share a communicator.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, Comm, MpiConfig, Src, Tag};
use crate::platform::{pnow, Backend, PBarrier};
use crate::sim::SimOutcome;

use super::AppMode;

#[derive(Clone)]
pub struct StencilParams {
    pub mode: AppMode,
    pub interconnect: Interconnect,
    /// Node grid (paper: 3x3 = 9 nodes).
    pub nodes_x: usize,
    pub nodes_y: usize,
    /// Worker grid per node (paper: 4x4 = 16 cores).
    pub tx: usize,
    pub ty: usize,
    /// Global square mesh dimension (cells per side).
    pub mesh: usize,
    pub iters: usize,
}

impl Default for StencilParams {
    fn default() -> Self {
        StencilParams {
            mode: AppMode::ParCommVcis,
            interconnect: Interconnect::Opa,
            nodes_x: 3,
            nodes_y: 3,
            tx: 4,
            ty: 4,
            mesh: 3072,
            iters: 6,
        }
    }
}

/// Returns the mean halo-exchange time per iteration (ns, virtual).
pub fn halo_time(p: StencilParams) -> f64 {
    let threads = p.tx * p.ty;
    let nodes = p.nodes_x * p.nodes_y;
    let (ppn, tpp, cfg) = match p.mode {
        AppMode::Everywhere => (threads, 1, MpiConfig::everywhere()),
        AppMode::ParCommVcis => (1, threads, MpiConfig::optimized(17)),
        AppMode::ParCommOrig => (1, threads, MpiConfig::original()),
        AppMode::Endpoints => (1, threads, MpiConfig::optimized(threads + 1)),
    };
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: p.interconnect,
            nodes,
            procs_per_node: ppn,
            max_contexts_per_node: 64,
        },
        cfg,
        tpp,
    );
    spec.time_limit = Some(200_000_000);
    let p = Arc::new(p);
    let pp = p.clone();
    let comms: Arc<Mutex<HashMap<usize, Vec<Comm>>>> = Arc::new(Mutex::new(HashMap::new()));
    let eps: Arc<Mutex<HashMap<usize, Comm>>> = Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        for proc in 0..nodes * ppn {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
        }
    }

    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let world = proc.comm_world();
        let me = proc.rank();
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();
        let threads = p.tx * p.ty;

        // Identity: global worker coordinates on the (nodes_x*tx, nodes_y*ty)
        // worker grid.
        let (node, worker) = match p.mode {
            AppMode::Everywhere => (me / threads, me % threads),
            _ => (me, t),
        };
        let (nx, ny) = (node % p.nodes_x, node / p.nodes_x);
        let (wx, wy) = (worker % p.tx, worker / p.tx);
        let gx = nx * p.tx + wx;
        let gy = ny * p.ty + wy;
        let gw = p.nodes_x * p.tx; // global workers per row
        let gh = p.nodes_y * p.ty;
        let block = p.mesh / gw.max(1); // cells per worker side
        let halo_bytes = block * 4; // one row/col of f32

        // par_comm communicator sets (created in identical order on every
        // process): [dir 0=NS | 1=EW][parity][lane].
        if t == 0 && matches!(p.mode, AppMode::ParCommVcis | AppMode::ParCommOrig) {
            let mut v = Vec::new();
            for _dir in 0..2 {
                for _parity in 0..2 {
                    for _lane in 0..p.tx.max(p.ty) {
                        v.push(proc.comm_dup(&world));
                    }
                }
            }
            comms.lock().unwrap().insert(me, v);
        }
        if t == 0 && p.mode == AppMode::Endpoints {
            let ep = proc.create_endpoints(&world, threads);
            eps.lock().unwrap().insert(me, ep);
        }
        bar.wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();

        // Neighbor in global worker coords -> (proc, worker) identity.
        let locate = |x: isize, y: isize| -> Option<(usize, usize)> {
            if x < 0 || y < 0 || x >= gw as isize || y >= gh as isize {
                return None;
            }
            let (x, y) = (x as usize, y as usize);
            let node = (y / p.ty) * p.nodes_x + (x / p.tx);
            let worker = (y % p.ty) * p.tx + (x % p.tx);
            let proc_id = match p.mode {
                AppMode::Everywhere => node * threads + worker,
                _ => node,
            };
            Some((proc_id, worker))
        };

        // Choose the communicator for an internode exchange in direction
        // `dir` (0 = NS, 1 = EW). Both sides of an exchange must pick the
        // same communicator, so the odd/even set is selected by the parity
        // of the LOWER node of the pair along the exchange axis (the
        // paper's odd/even scheme, Fig. 21).
        let lanes = p.tx.max(p.ty);
        let comm_for = |dir: usize, lane: usize, sign: i32| -> Comm {
            let coord = if dir == 0 { ny } else { nx };
            // sign 0 = exchanging toward the negative side (lower node is
            // the neighbor), sign 1 = toward positive (lower node is us).
            let lower = if sign == 0 { coord.wrapping_sub(1) } else { coord };
            let parity = lower % 2;
            match p.mode {
                AppMode::ParCommVcis | AppMode::ParCommOrig => {
                    comms.lock().unwrap().get(&me).unwrap()
                        [dir * 2 * lanes + parity * lanes + lane]
                        .clone()
                }
                _ => world.clone(),
            }
        };

        let mut total = 0u64;
        for it in 0..p.iters {
            // Funneled barrier before each exchange (discards load
            // imbalance, as the paper does).
            if t == 0 {
                proc.barrier(&world);
            }
            bar.wait();
            let t0 = pnow(proc.backend);
            // Four directions: (dx, dy, dir, lane).
            // (dx, dy, dir, lane, sign): sign distinguishes the +/- side.
            let dirs: [(isize, isize, usize, usize, i32); 4] = [
                (0, -1, 0, wx, 0), // north
                (0, 1, 0, wx, 1),  // south
                (-1, 0, 1, wy, 0), // west
                (1, 0, 1, wy, 1),  // east
            ];
            let mut reqs = Vec::new();
            for &(dx, dy, dir, lane, sign) in &dirs {
                let Some((nproc, nworker)) = locate(gx as isize + dx, gy as isize + dy)
                else {
                    continue;
                };
                let same_node = match p.mode {
                    AppMode::Everywhere => nproc / threads == node,
                    _ => nproc == me,
                };
                if same_node && p.mode != AppMode::Everywhere {
                    // MPI+threads intranode: direct shared-memory read.
                    crate::platform::padvance(
                        proc.backend,
                        proc.costs.memcpy_cost(halo_bytes),
                    );
                    continue;
                }
                let payload = vec![0u8; halo_bytes];
                // A north-facing send matches the neighbor's south-facing
                // receive: tag by direction axis + the *sender's* side; the
                // receive uses the mirrored side.
                let base = (it % 2) as i32 * 8 + dir as i32 * 2;
                let send_tag = base + sign;
                let recv_tag = base + (1 - sign);
                match p.mode {
                    AppMode::Endpoints => {
                        let ep = eps.lock().unwrap().get(&me).unwrap().clone();
                        let to = proc.endpoint_rank(&ep, nproc, nworker);
                        reqs.push(proc.isend_ep(&ep, Some(t), to, send_tag, &payload, false));
                        reqs.push(proc.irecv_ep(&ep, Some(t), Src::Rank(to), Tag::Value(recv_tag)));
                    }
                    AppMode::Everywhere => {
                        reqs.push(proc.isend(&world, nproc, send_tag, &payload));
                        reqs.push(proc.irecv(&world, Src::Rank(nproc), Tag::Value(recv_tag)));
                    }
                    _ => {
                        let comm = comm_for(dir, lane, sign);
                        reqs.push(proc.isend(&comm, nproc, send_tag, &payload));
                        reqs.push(proc.irecv(&comm, Src::Rank(nproc), Tag::Value(recv_tag)));
                    }
                }
            }
            proc.waitall(reqs);
            bar.wait();
            if t == 0 {
                proc.barrier(&world);
            }
            bar.wait();
            total += pnow(proc.backend) - t0;
        }
        if me == 0 && t == 0 {
            crate::mpi::world::record("halo_ns", total as f64 / p.iters as f64);
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "stencil run: {:?}", r.outcome);
    r.measurements["halo_ns"]
}

/// Fig. 22 driver: halo time across mesh sizes for each mode.
pub fn fig22(meshes: &[usize], iters: usize) -> crate::bench::Csv {
    let mut csv = crate::bench::Csv::new(&["mode", "mesh", "halo_us"]);
    for mode in [AppMode::Everywhere, AppMode::ParCommOrig, AppMode::ParCommVcis, AppMode::Endpoints]
    {
        for &mesh in meshes {
            let ns = halo_time(StencilParams { mode, mesh, iters, ..Default::default() });
            csv.row(&[mode.label().into(), mesh.to_string(), format!("{:.2}", ns / 1e3)]);
        }
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_stencil_all_modes_complete() {
        for mode in AppMode::all() {
            let ns = halo_time(StencilParams {
                mode,
                nodes_x: 2,
                nodes_y: 1,
                tx: 2,
                ty: 2,
                mesh: 256,
                iters: 2,
                ..Default::default()
            });
            assert!(ns > 0.0, "{mode:?}");
        }
    }

    #[test]
    fn bigger_halos_cost_more() {
        let small = halo_time(StencilParams {
            nodes_x: 2,
            nodes_y: 1,
            tx: 2,
            ty: 2,
            mesh: 256,
            iters: 2,
            ..Default::default()
        });
        let big = halo_time(StencilParams {
            nodes_x: 2,
            nodes_y: 1,
            tx: 2,
            ty: 2,
            mesh: 4096,
            iters: 2,
            ..Default::default()
        });
        assert!(big > small, "big={big} small={small}");
    }
}
