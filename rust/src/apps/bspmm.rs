//! BSPMM: NWChem's block-sparse matmul communication pattern (paper §6.3,
//! Fig. 27) — get-compute-update with a global work counter.
//!
//! Workers fetch a work unit index via MPI_Fetch_and_op on rank 0, MPI_Get
//! the A and B tiles, multiply (compute), and MPI_Accumulate into C.
//!
//! Category 3: each thread may use its own window for gets, but MPI-3.1
//! pins all accumulates to ONE window (atomicity across windows is
//! undefined), serializing them on one VCI. Endpoints let each thread use
//! its own endpoint within that single window. The escape hatch is the
//! `accumulate_ordering=none` hint (§6.3's closing point), reproduced with
//! `relaxed_acc`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::fabric::{AccOp, FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, MpiConfig};
use crate::platform::{pcompute, pnow, Backend, PBarrier};
use crate::sim::SimOutcome;

use super::AppMode;

#[derive(Clone)]
pub struct BspmmParams {
    pub mode: AppMode,
    pub interconnect: Interconnect,
    pub nodes: usize,
    pub threads: usize,
    /// Tile dimension (f32 elements per side).
    pub tile_dim: usize,
    /// Work units per worker (on average).
    pub units_per_worker: usize,
    /// Use the accumulate_ordering=none hint (multi-VCI accumulates).
    pub relaxed_acc: bool,
    /// True passive-target mode (the hypre/NWChem idiom): thread 0 holds
    /// `win_lock_all` on the C window for the whole phase (ops still
    /// complete per-op via flush — MPI allows at most one lock epoch per
    /// (window, target) per process, so per-thread locks on the shared C
    /// window would be erroneous), and every get rides a per-access
    /// shared `win_lock`/`win_unlock` pair on the thread's get window —
    /// the unlock completes the gets, replacing the explicit flush.
    pub passive: bool,
}

impl Default for BspmmParams {
    fn default() -> Self {
        BspmmParams {
            mode: AppMode::ParCommVcis,
            interconnect: Interconnect::Opa,
            nodes: 4,
            threads: 16,
            tile_dim: 256,
            units_per_worker: 3,
            relaxed_acc: false,
            passive: false,
        }
    }
}

/// Per-phase mean times (ns): (get_init, get_flush, acc_init, acc_flush).
pub struct BspmmTimes {
    pub get_init: f64,
    pub get_flush: f64,
    pub acc_init: f64,
    pub acc_flush: f64,
    /// FNV-1a hash of each rank's local C-window bytes at the end of the
    /// run, indexed by rank. The C update is a commutative SumU64 keyed by
    /// the work-unit id, so the flush and passive arms must agree
    /// byte-for-byte regardless of which worker claimed which unit.
    pub c_hashes: Vec<u32>,
}

pub fn run_bspmm(p: BspmmParams) -> BspmmTimes {
    let (ppn, tpp, cfg) = match p.mode {
        AppMode::Everywhere => (p.threads, 1, MpiConfig::everywhere()),
        AppMode::ParCommVcis => (1, p.threads, MpiConfig::optimized(p.threads + 1)),
        AppMode::ParCommOrig => (1, p.threads, MpiConfig::original()),
        AppMode::Endpoints => (1, p.threads, MpiConfig::optimized(p.threads + 1)),
    };
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: p.interconnect,
            nodes: p.nodes,
            procs_per_node: ppn,
            max_contexts_per_node: 64,
        },
        cfg,
        tpp,
    );
    spec.time_limit = Some(1_000_000_000);
    let p = Arc::new(p);
    let pp = p.clone();
    let state: Arc<Mutex<HashMap<usize, Vec<Arc<crate::mpi::Window>>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        for proc in 0..p.nodes * ppn {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
        }
    }
    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let world = proc.comm_world();
        let me = proc.rank();
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();
        let tile_bytes = p.tile_dim * p.tile_dim * 4;
        let nprocs = proc.nprocs();
        let workers = nprocs * tpp_of(p);
        // Window layout (created in identical collective order):
        //   [0] counter window (rank 0 hosts the global counter)
        //   [1] C window (single: accumulate target)
        //   [2..2+n_get] A/B get windows (per thread in par/endpoints).
        if t == 0 {
            let mut v = Vec::new();
            v.push(proc.win_create(&world, 64)); // counter
            v.push(proc.win_create_with(&world, tile_bytes * 2, p.relaxed_acc)); // C
            let n_get = match p.mode {
                AppMode::Everywhere => 1,
                _ => p.threads,
            };
            for _ in 0..n_get {
                v.push(proc.win_create(&world, tile_bytes * 2));
            }
            state.lock().unwrap().insert(me, v);
        }
        bar.wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();
        let wins = state.lock().unwrap().get(&me).unwrap().clone();
        let counter_win = wins[0].clone();
        let c_win = wins[1].clone();
        let get_win = match p.mode {
            AppMode::Everywhere => wins[2].clone(),
            _ => wins[2 + t].clone(),
        };
        let ep_vci = match p.mode {
            AppMode::Endpoints => Some(1 + t),
            _ => None,
        };
        if p.passive && t == 0 {
            // One process-wide shared epoch to every rank for the whole
            // accumulate phase (thread 0 drives it; ops complete per-op
            // via flush inside the epoch).
            proc.win_lock_all(&c_win);
        }

        let total_units = workers * p.units_per_worker;
        let mut get_init = 0u64;
        let mut get_flush = 0u64;
        let mut acc_init = 0u64;
        let mut acc_flush = 0u64;
        let mut my_units = 0u64;
        loop {
            // Fetch a work unit from the global counter on rank 0.
            let prev =
                proc.fetch_and_op(&counter_win, 0, 0, &1u64.to_le_bytes(), AccOp::SumU64);
            let unit = u64::from_le_bytes(prev.try_into().unwrap());
            if unit >= total_units as u64 {
                break;
            }
            my_units += 1;
            // Targets derived from the unit id (round-robin tile owners).
            let ta = (unit as usize) % nprocs;
            let tb = (unit as usize + 1) % nprocs;
            let tc = (unit as usize + 2) % nprocs;

            let t0 = pnow(proc.backend);
            if p.passive {
                // Per-access shared epochs on the (per-thread) get window.
                proc.win_lock(&get_win, crate::mpi::LockKind::Shared, ta);
                if tb != ta {
                    proc.win_lock(&get_win, crate::mpi::LockKind::Shared, tb);
                }
            }
            let ha = proc.get_via(&get_win, ep_vci, ta, 0, tile_bytes);
            let hb = proc.get_via(&get_win, ep_vci, tb, tile_bytes, tile_bytes);
            let t1 = pnow(proc.backend);
            if p.passive {
                // The unlocks complete the gets (per-target flush waits).
                proc.win_unlock(&get_win, ta);
                if tb != ta {
                    proc.win_unlock(&get_win, tb);
                }
            } else {
                proc.win_flush(&get_win);
            }
            let t2 = pnow(proc.backend);
            let _a = proc.get_data(&get_win, ha);
            let _b = proc.get_data(&get_win, hb);
            // Tile multiply: ~2*dim^3 flops at ~16 flops/ns.
            pcompute(proc.backend, (2 * p.tile_dim.pow(3) / 16) as u64);
            let t3 = pnow(proc.backend);
            // C update payload: commutative SumU64 lanes keyed by the unit
            // id, so the final C bytes are order-independent — the basis
            // of the flush-vs-passive byte-identity check.
            let contrib_len = tile_bytes.min(8 * 1024) & !7;
            let mut contrib = vec![0u8; contrib_len];
            for lane in contrib.chunks_exact_mut(8) {
                lane.copy_from_slice(&(unit + 1).to_le_bytes());
            }
            proc.accumulate_via(&c_win, ep_vci, tc, 0, &contrib, AccOp::SumU64);
            let t4 = pnow(proc.backend);
            proc.win_flush(&c_win);
            let t5 = pnow(proc.backend);
            get_init += t1 - t0;
            get_flush += t2 - t1;
            acc_init += t4 - t3;
            acc_flush += t5 - t4;
        }
        bar.wait();
        if t == 0 {
            if p.passive {
                // Close the phase-long epoch before the fence; win_free
                // would trip its open-epoch assert otherwise.
                proc.win_unlock_all(&c_win);
            }
            proc.barrier(&world);
        }
        bar.wait();
        if me == 0 && t == 0 {
            let n = my_units.max(1) as f64;
            crate::mpi::world::record("get_init", get_init as f64 / n);
            crate::mpi::world::record("get_flush", get_flush as f64 / n);
            crate::mpi::world::record("acc_init", acc_init as f64 / n);
            crate::mpi::world::record("acc_flush", acc_flush as f64 / n);
        }
        if t == 0 {
            // Post-fence, every origin's accumulates to this rank are
            // complete: hash the local C bytes for the arms' byte-identity
            // check (FNV-1a 32, exact in an f64 measurement).
            let mut h: u32 = 0x811c_9dc5;
            for b in c_win.read_local(0, tile_bytes * 2) {
                h ^= u32::from(b);
                h = h.wrapping_mul(0x0100_0193);
            }
            crate::mpi::world::record(format!("c_hash_p{me}"), f64::from(h));
        }
        bar.wait();
        if t == 0 {
            // Host lock must not be held across collective win_free (see
            // ebms.rs teardown comment).
            let mine = state.lock().unwrap().remove(&me).unwrap();
            for w in mine {
                proc.win_free(&world, w);
            }
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "bspmm run: {:?}", r.outcome);
    let c_hashes = (0..p.nodes * ppn)
        .map(|i| r.measurements[&format!("c_hash_p{i}")] as u32)
        .collect();
    BspmmTimes {
        get_init: r.measurements["get_init"],
        get_flush: r.measurements["get_flush"],
        acc_init: r.measurements["acc_init"],
        acc_flush: r.measurements["acc_flush"],
        c_hashes,
    }
}

fn tpp_of(p: &BspmmParams) -> usize {
    match p.mode {
        AppMode::Everywhere => 1,
        _ => p.threads,
    }
}

/// Fig. 27: per-phase times across tile dims for each mode (plus the
/// accumulate_ordering=none ablation of §6.3's closing point).
pub fn fig27(tile_dims: &[usize], units: usize) -> crate::bench::Csv {
    let mut csv = crate::bench::Csv::new(&[
        "mode",
        "tile_dim",
        "get_init_us",
        "get_flush_us",
        "acc_init_us",
        "acc_flush_us",
    ]);
    let modes: Vec<(String, BspmmParams)> = vec![
        ("everywhere".into(), BspmmParams { mode: AppMode::Everywhere, ..Default::default() }),
        ("par+vcis".into(), BspmmParams { mode: AppMode::ParCommVcis, ..Default::default() }),
        ("endpoints".into(), BspmmParams { mode: AppMode::Endpoints, ..Default::default() }),
        (
            "par+vcis+acc_none".into(),
            BspmmParams { mode: AppMode::ParCommVcis, relaxed_acc: true, ..Default::default() },
        ),
    ];
    for (label, base) in modes {
        for &dim in tile_dims {
            let t = run_bspmm(BspmmParams {
                tile_dim: dim,
                units_per_worker: units,
                ..base.clone()
            });
            csv.row(&[
                label.clone(),
                dim.to_string(),
                format!("{:.2}", t.get_init / 1e3),
                format!("{:.2}", t.get_flush / 1e3),
                format!("{:.2}", t.acc_init / 1e3),
                format!("{:.2}", t.acc_flush / 1e3),
            ]);
        }
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_bspmm_modes_complete() {
        for mode in [AppMode::Everywhere, AppMode::ParCommVcis, AppMode::Endpoints] {
            let t = run_bspmm(BspmmParams {
                mode,
                nodes: 2,
                threads: 2,
                tile_dim: 64,
                units_per_worker: 2,
                ..Default::default()
            });
            assert!(t.get_init > 0.0, "{mode:?}");
        }
    }

    #[test]
    fn passive_arm_matches_flush_arm_bytes() {
        // The C update is a commutative SumU64 keyed by unit id, so the
        // flush-sync arm and the passive-target lock-epoch arm must leave
        // byte-identical C windows on every rank, on both interconnects.
        for interconnect in [Interconnect::Opa, Interconnect::Ib] {
            let base = BspmmParams {
                interconnect,
                nodes: 2,
                threads: 2,
                tile_dim: 64,
                units_per_worker: 2,
                ..Default::default()
            };
            let flush = run_bspmm(base.clone());
            let passive = run_bspmm(BspmmParams { passive: true, ..base });
            assert!(!flush.c_hashes.is_empty());
            assert_eq!(
                flush.c_hashes, passive.c_hashes,
                "{interconnect:?}: passive-target arm diverged from flush arm"
            );
        }
    }

    #[test]
    fn work_counter_distributes_all_units() {
        // Completion of the run itself proves every unit was claimed
        // exactly once (otherwise the loop would not terminate).
        let t = run_bspmm(BspmmParams {
            nodes: 2,
            threads: 4,
            tile_dim: 64,
            units_per_worker: 3,
            ..Default::default()
        });
        assert!(t.acc_flush >= 0.0);
    }
}
