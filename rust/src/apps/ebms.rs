//! EBMS: the OpenMC energy-band memory-server pattern (paper §6.2,
//! Figs. 24-25).
//!
//! Cross-section data is banded across nodes; each worker repeatedly
//! fetches a portion of a remote band (MPI_Get + MPI_Win_flush), then
//! tracks particles (compute), with a thread barrier between iterations.
//! Multi-window exposure (a window per thread) gives gets independent
//! streams — category 1 — but completion of software-emulated RMA needs
//! the *target* to progress the right VCI, and target threads sit in the
//! thread barrier — category 2. IB (hardware RMA) is immune.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::fabric::{FabricConfig, Interconnect};
use crate::mpi::{run_cluster, ClusterSpec, MpiConfig};
use crate::platform::{pcompute, pnow, Backend, PBarrier};
use crate::sim::SimOutcome;

use super::AppMode;

#[derive(Clone)]
pub struct EbmsParams {
    pub mode: AppMode,
    pub interconnect: Interconnect,
    pub nodes: usize,
    /// Workers per node.
    pub threads: usize,
    /// Bytes each worker fetches per remote fetch (a band portion).
    pub fetch_bytes: usize,
    /// Per-iteration particle-tracking compute (virtual ns).
    pub compute_ns: u64,
    pub iters: usize,
}

impl Default for EbmsParams {
    fn default() -> Self {
        EbmsParams {
            mode: AppMode::ParCommVcis,
            interconnect: Interconnect::Opa,
            nodes: 4,
            threads: 16,
            fetch_bytes: 64 * 1024,
            compute_ns: 20_000,
            iters: 4,
        }
    }
}

/// Result: mean (get_ns, flush_ns) per remote fetch.
pub fn fetch_time(p: EbmsParams) -> (f64, f64) {
    let (ppn, tpp, cfg) = match p.mode {
        AppMode::Everywhere => (p.threads, 1, MpiConfig::everywhere()),
        AppMode::ParCommVcis => (1, p.threads, MpiConfig::optimized(p.threads + 1)),
        AppMode::ParCommOrig => (1, p.threads, MpiConfig::original()),
        AppMode::Endpoints => (1, p.threads, MpiConfig::optimized(p.threads + 1)),
    };
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: p.interconnect,
            nodes: p.nodes,
            procs_per_node: ppn,
            max_contexts_per_node: 64,
        },
        cfg,
        tpp,
    );
    spec.time_limit = Some(2_000_000);
    let p = Arc::new(p);
    let pp = p.clone();
    let wins: Arc<Mutex<HashMap<usize, Vec<Arc<crate::mpi::Window>>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Mutex<HashMap<usize, Arc<PBarrier>>>> = Arc::new(Mutex::new(HashMap::new()));
    {
        let mut b = bars.lock().unwrap();
        for proc in 0..p.nodes * ppn {
            b.insert(proc, Arc::new(PBarrier::new(Backend::Sim, tpp)));
        }
    }
    let r = run_cluster(spec, move |proc, t| {
        let p = &*pp;
        let trace0 = std::env::var("VCMPI_TRACE").is_ok();
        let world = proc.comm_world();
        let me = proc.rank();
        if trace0 {
            eprintln!("[p{me} t{t}] body entered");
        }
        let bar = bars.lock().unwrap().get(&me).unwrap().clone();
        // Window exposure: the band lives on every node; a window per
        // worker (par/endpoints), or one shared window (everywhere per
        // proc; ser would share too).
        let n_wins = match p.mode {
            AppMode::Everywhere => 1,
            _ => p.threads,
        };
        if t == 0 {
            let v: Vec<_> =
                (0..n_wins).map(|_| proc.win_create(&world, p.fetch_bytes * 2)).collect();
            wins.lock().unwrap().insert(me, v);
        }
        if trace0 {
            eprintln!("[p{me} t{t}] windows created");
        }
        bar.wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();
        if trace0 {
            eprintln!("[p{me} t{t}] setup barrier done");
        }

        let widx = if n_wins == 1 { 0 } else { t };
        let win = wins.lock().unwrap().get(&me).unwrap()[widx].clone();
        // Endpoint VCI for direct control (endpoints mode).
        let ep_vci = match p.mode {
            AppMode::Endpoints => Some(1 + t),
            _ => None,
        };
        // Remote target: next node, same worker slot.
        let target = match p.mode {
            AppMode::Everywhere => (me + p.threads) % (p.nodes * p.threads),
            _ => (me + 1) % p.nodes,
        };

        let trace = std::env::var("VCMPI_TRACE").is_ok();
        let mut get_total = 0u64;
        let mut flush_total = 0u64;
        for it in 0..p.iters {
            if trace {
                eprintln!("[p{me} t{t}] iter {it} start @{}", pnow(proc.backend));
            }
            let t0 = pnow(proc.backend);
            let h = proc.get_via(&win, ep_vci, target, 0, p.fetch_bytes);
            let t1 = pnow(proc.backend);
            if trace {
                eprintln!("[p{me} t{t}] got handle, flushing @{t1}");
            }
            proc.win_flush(&win);
            let t2 = pnow(proc.backend);
            if trace {
                eprintln!("[p{me} t{t}] flushed @{t2}");
            }
            let _data = proc.get_data(&win, h);
            get_total += t1 - t0;
            flush_total += t2 - t1;
            // Track particles through the fetched band.
            pcompute(proc.backend, p.compute_ns);
            // Thread barrier between iterations (the paper's pattern — the
            // source of the stalled target VCIs on OPA).
            bar.wait();
        }
        if trace0 {
            eprintln!("[p{me} t{t}] loop done, entering final barrier");
        }
        bar.wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bar.wait();
        if trace0 {
            eprintln!("[p{me} t{t}] final barrier done");
        }
        if me == 0 && t == 0 {
            crate::mpi::world::record("get_ns", get_total as f64 / p.iters as f64);
            crate::mpi::world::record("flush_ns", flush_total as f64 / p.iters as f64);
        }
        bar.wait();
        if t == 0 {
            // Take the list OUT of the host mutex before the collective
            // win_free: holding a host lock across a parking sim operation
            // deadlocks the scheduler (other procs block on the host lock
            // while holding the baton).
            let mine = wins.lock().unwrap().remove(&me).unwrap();
            for (i, w) in mine.into_iter().enumerate() {
                if trace0 {
                    eprintln!("[p{me} t{t}] freeing win {i}");
                }
                proc.win_free(&world, w);
            }
        }
        if trace0 {
            eprintln!("[p{me} t{t}] teardown done");
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "ebms run: {:?}", r.outcome);
    (r.measurements["get_ns"], r.measurements["flush_ns"])
}

/// Fig. 24: remote-fetch time across band sizes, both fabrics.
pub fn fig24(sizes: &[usize], iters: usize) -> crate::bench::Csv {
    let mut csv = crate::bench::Csv::new(&["fabric", "mode", "fetch_kib", "fetch_us"]);
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        for mode in [AppMode::Everywhere, AppMode::ParCommVcis, AppMode::Endpoints] {
            for &bytes in sizes {
                let (g, f) = fetch_time(EbmsParams {
                    mode,
                    interconnect: ic,
                    fetch_bytes: bytes,
                    iters,
                    ..Default::default()
                });
                csv.row(&[
                    format!("{ic:?}"),
                    mode.label().into(),
                    (bytes / 1024).to_string(),
                    format!("{:.2}", (g + f) / 1e3),
                ]);
            }
        }
    }
    csv
}

/// Fig. 25: Get vs Flush split on the software-RMA fabric.
pub fn fig25(sizes: &[usize], iters: usize) -> crate::bench::Csv {
    let mut csv = crate::bench::Csv::new(&["mode", "fetch_kib", "get_us", "flush_us"]);
    for mode in [AppMode::Everywhere, AppMode::ParCommVcis, AppMode::Endpoints] {
        for &bytes in sizes {
            let (g, f) = fetch_time(EbmsParams {
                mode,
                interconnect: Interconnect::Opa,
                fetch_bytes: bytes,
                iters,
                ..Default::default()
            });
            csv.row(&[
                mode.label().into(),
                (bytes / 1024).to_string(),
                format!("{:.2}", g / 1e3),
                format!("{:.2}", f / 1e3),
            ]);
        }
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_ebms_all_modes_complete() {
        for mode in AppMode::all() {
            let (g, f) = fetch_time(EbmsParams {
                mode,
                nodes: 2,
                threads: 2,
                fetch_bytes: 4096,
                iters: 2,
                compute_ns: 1000,
                ..Default::default()
            });
            assert!(g > 0.0 && f >= 0.0, "{mode:?}");
        }
    }

    #[test]
    fn opa_flush_dominates_ib_flush() {
        let mk = |ic| EbmsParams {
            interconnect: ic,
            nodes: 2,
            threads: 4,
            fetch_bytes: 64 * 1024,
            iters: 2,
            compute_ns: 50_000,
            ..Default::default()
        };
        let (_, f_ib) = fetch_time(mk(Interconnect::Ib));
        let (_, f_opa) = fetch_time(mk(Interconnect::Opa));
        // In this mini-config both sides progress concurrently, so the
        // gap is modest; the full busy-target separation is asserted in
        // tests/rma_semantics.rs (opa_put_needs_target_progress...).
        assert!(
            f_opa > f_ib,
            "software RMA flush should cost more: opa={f_opa} ib={f_ib}"
        );
    }
}
