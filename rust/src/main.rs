//! `repro` — CLI entrypoint for the reproduction.
//!
//! Subcommands (hand-rolled parser; no clap in the offline environment):
//!   repro figures <id>|all [--scale N]   regenerate a paper figure/table
//!   repro train [opts]                   end-to-end training driver
//!   repro app <stencil|ebms|bspmm>       application drivers
//!   repro list                           list figure ids

use vcmpi::bench::figures;

fn arg_val(args: &[String], key: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("list") => {
            for id in figures::all_ids() {
                println!("{id}");
            }
        }
        Some("figures") => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            let scale = arg_val(&args, "--scale", 1);
            if id == "all" {
                for id in figures::all_ids() {
                    println!("### {id}");
                    figures::run_figure(id, scale).unwrap().print();
                    println!();
                }
            } else {
                match figures::run_figure(id, scale) {
                    Some(csv) => csv.print(),
                    None => {
                        eprintln!("unknown figure id: {id} (try `repro list`)");
                        std::process::exit(2);
                    }
                }
            }
        }
        Some("train") => {
            let cfg = vcmpi::coordinator::TrainConfig {
                steps: arg_val(&args, "--steps", 300),
                workers: arg_val(&args, "--workers", 2),
                buckets: arg_val(&args, "--buckets", 4),
                ..Default::default()
            };
            match vcmpi::coordinator::train(cfg) {
                Ok(r) => {
                    println!(
                        "loss {:.4} -> {:.4} over {} steps ({} params, {:.1} ms/step, \
                         allreduce {:.1} ms = {:.1} blocked + {:.1} overlapped)",
                        r.first_loss,
                        r.final_loss,
                        r.losses.len(),
                        r.params,
                        r.step_ms,
                        r.allreduce_ms,
                        r.allreduce_blocked_ms,
                        r.allreduce_overlap_ms
                    );
                }
                Err(e) => {
                    eprintln!("train failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        Some("app") => {
            let scale = arg_val(&args, "--scale", 1);
            match args.get(1).map(String::as_str) {
                Some("stencil") => figures::run_figure("fig22", scale).unwrap().print(),
                Some("ebms") => {
                    figures::run_figure("fig24", scale).unwrap().print();
                    figures::run_figure("fig25", scale).unwrap().print();
                }
                Some("bspmm") => figures::run_figure("fig27", scale).unwrap().print(),
                other => {
                    eprintln!("usage: repro app <stencil|ebms|bspmm>, got {other:?}");
                    std::process::exit(2);
                }
            }
        }
        Some(cmd) => {
            eprintln!("unknown command: {cmd}");
            std::process::exit(2);
        }
        None => {
            eprintln!("usage: repro <figures|train|app|list> ...");
            std::process::exit(2);
        }
    }
}
