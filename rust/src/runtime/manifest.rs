//! Parse `artifacts/manifest.tsv` (the JSON twin exists for humans; the
//! offline crate set has no serde, so aot.py also emits this TSV).
//!
//! Format:
//!   #model_config\tk=v\tk=v...
//!   name\tfile\tSHAPE:dtype;SHAPE:dtype...\tSHAPE:dtype...
//! where SHAPE is `d0xd1x...` (empty for scalars).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub model_config: HashMap<String, i64>,
    entries: Vec<ArtifactSpec>,
}

fn parse_tensor(s: &str) -> Result<TensorSpec> {
    let (shape_s, dtype) = s.rsplit_once(':').ok_or_else(|| anyhow!("bad tensor spec {s}"))?;
    let shape = if shape_s.is_empty() {
        Vec::new()
    } else {
        shape_s
            .split('x')
            .map(|d| d.parse::<usize>().with_context(|| format!("bad dim in {s}")))
            .collect::<Result<_>>()?
    };
    Ok(TensorSpec { shape, dtype: dtype.to_string() })
}

fn parse_tensor_list(s: &str) -> Result<Vec<TensorSpec>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(';').map(parse_tensor).collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for line in text.lines() {
            let line = line.trim_end();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("#model_config\t") {
                for kv in rest.split('\t') {
                    if let Some((k, v)) = kv.split_once('=') {
                        if let Ok(v) = v.parse::<i64>() {
                            m.model_config.insert(k.to_string(), v);
                        }
                    }
                }
                continue;
            }
            let mut cols = line.split('\t');
            let name = cols.next().ok_or_else(|| anyhow!("missing name"))?;
            let file = cols.next().ok_or_else(|| anyhow!("missing file"))?;
            let ins = cols.next().unwrap_or("");
            let outs = cols.next().unwrap_or("");
            m.entries.push(ArtifactSpec {
                name: name.to_string(),
                file: file.to_string(),
                inputs: parse_tensor_list(ins)?,
                outputs: parse_tensor_list(outs)?,
            });
        }
        Ok(m)
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn entries(&self) -> &[ArtifactSpec] {
        &self.entries
    }

    /// Model-config value (e.g. "param_count"), if present.
    pub fn config(&self, key: &str) -> Option<i64> {
        self.model_config.get(key).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "#model_config\tbatch=8\tparam_count=3297792\n\
train_sgd_step\ttrain_sgd_step.hlo.txt\t3297792:float32;3297792:float32;:float32\t3297792:float32\n\
stencil_block\tstencil_block.hlo.txt\t66x66:float32\t64x64:float32\n";

    #[test]
    fn parses_config_and_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config("batch"), Some(8));
        assert_eq!(m.config("param_count"), Some(3_297_792));
        assert_eq!(m.entries().len(), 2);
        let sgd = m.entry("train_sgd_step").unwrap();
        assert_eq!(sgd.inputs.len(), 3);
        assert_eq!(sgd.inputs[2].shape, Vec::<usize>::new(), "scalar lr");
        let st = m.entry("stencil_block").unwrap();
        assert_eq!(st.inputs[0].shape, vec![66, 66]);
        assert_eq!(st.outputs[0].shape, vec![64, 64]);
    }

    #[test]
    fn missing_entry_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.entry("nope").is_none());
    }
}
