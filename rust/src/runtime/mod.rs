//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the CPU PJRT client, and
//! execute them from the (native-backend) hot path. Python never runs here.
//!
//! The PJRT backend (the `xla` crate) is not available in the offline
//! build image, so it is gated behind the `pjrt` cargo feature. The
//! default build ships the same public API backed by a stub whose
//! `Runtime::open` / `SharedRuntime::open` fail gracefully — manifest
//! parsing and the [`Tensor`] host type remain fully functional either
//! way, and callers (the training coordinator, `repro train`) surface the
//! error instead of failing to build.
//!
//! With `pjrt` enabled, the pattern follows /opt/xla-example/load_hlo:
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5's 64-bit-id serialized protos); graphs are lowered with
//! `return_tuple=True`, so results come back as one tuple literal.

mod manifest;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

/// Host tensor passed to / returned from executables.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    //! The real PJRT backend (requires the vendored `xla` crate).

    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    use super::{ArtifactSpec, Manifest, Tensor, TensorSpec};

    /// A compiled executable plus its manifest signature.
    pub struct Executable {
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    /// Loads artifacts lazily and caches compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, usize>>,
        compiled: Mutex<Vec<std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Open the artifacts directory (expects `manifest.tsv` inside).
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::load(dir.join("manifest.tsv"))?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime {
                client,
                dir,
                manifest,
                cache: Mutex::new(HashMap::new()),
                compiled: Mutex::new(Vec::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch the cached) executable for a manifest entry.
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(&i) = self.cache.lock().unwrap().get(name) {
                return Ok(self.compiled.lock().unwrap()[i].clone());
            }
            let spec = self
                .manifest
                .entry(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            let arc = std::sync::Arc::new(Executable { spec, exe });
            let mut compiled = self.compiled.lock().unwrap();
            compiled.push(arc.clone());
            self.cache.lock().unwrap().insert(name.to_string(), compiled.len() - 1);
            Ok(arc)
        }
    }

    fn to_literal(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        let lit = match t {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape f32 literal: {e:?}"))?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape i32 literal: {e:?}"))?,
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        let t = match spec.dtype.as_str() {
            "float32" => Tensor::F32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))?,
            },
            "int32" => Tensor::I32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))?,
            },
            other => return Err(anyhow!("unsupported dtype {other}")),
        };
        Ok(t)
    }

    impl Executable {
        /// Execute with host tensors; returns the tuple elements as tensors.
        pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
            anyhow::ensure!(
                args.len() == self.spec.inputs.len(),
                "{}: expected {} args, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
            for (a, s) in args.iter().zip(&self.spec.inputs) {
                anyhow::ensure!(
                    a.shape() == s.shape.as_slice(),
                    "{}: arg shape {:?} != manifest {:?}",
                    self.spec.name,
                    a.shape(),
                    s.shape
                );
            }
            let lits: Vec<xla::Literal> =
                args.iter().map(to_literal).collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.name))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            // return_tuple=True: decompose the tuple into per-output literals.
            let parts = result.to_tuple().map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
            anyhow::ensure!(
                parts.len() == self.spec.outputs.len(),
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
            parts
                .iter()
                .zip(&self.spec.outputs)
                .map(|(l, s)| from_literal(l, s))
                .collect()
        }
    }

    /// A `Send + Sync` wrapper serializing ALL PJRT access through one
    /// mutex.
    ///
    /// The `xla` crate's handles are `Rc`-based (not thread-safe to clone
    /// or drop concurrently), but the underlying PJRT CPU client is fine
    /// with serialized access from multiple threads. Every operation —
    /// loading, executing, and finally dropping — happens while holding
    /// the mutex, so the `Rc` reference counts are never raced. On this
    /// single-core testbed serialization costs nothing.
    pub struct SharedRuntime {
        inner: Mutex<Runtime>,
    }

    // SAFETY: all access to the non-Send internals is serialized by
    // `inner`; nothing borrows out of the mutex (run() copies tensors in
    // and out).
    unsafe impl Send for SharedRuntime {}
    unsafe impl Sync for SharedRuntime {}

    impl SharedRuntime {
        pub fn open(dir: impl AsRef<Path>) -> Result<SharedRuntime> {
            Ok(SharedRuntime { inner: Mutex::new(Runtime::open(dir)?) })
        }

        /// Pre-compile an artifact (avoids paying compile time mid-benchmark).
        pub fn warm(&self, name: &str) -> Result<()> {
            let rt = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            rt.load(name).map(|_| ())
        }

        /// Execute artifact `name` with `args`.
        pub fn run(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
            let rt = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            let exe = rt.load(name)?;
            exe.run(args)
        }

        pub fn config(&self, key: &str) -> Option<i64> {
            let rt = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            rt.manifest.config(key)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub backend: same public surface, fails at `open` time.

    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{anyhow, Result};

    use super::{ArtifactSpec, Manifest, Tensor};

    const UNAVAILABLE: &str =
        "built without the `pjrt` feature: PJRT execution is unavailable in this environment";

    pub struct Executable {
        pub spec: ArtifactSpec,
    }

    impl Executable {
        pub fn run(&self, _args: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(anyhow!("{UNAVAILABLE}"))
        }
    }

    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn open(_dir: impl AsRef<Path>) -> Result<Runtime> {
            Err(anyhow!("{UNAVAILABLE}"))
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
            Err(anyhow!("load {name}: {UNAVAILABLE}"))
        }
    }

    pub struct SharedRuntime {
        _private: (),
    }

    impl SharedRuntime {
        pub fn open(_dir: impl AsRef<Path>) -> Result<SharedRuntime> {
            Err(anyhow!("{UNAVAILABLE}"))
        }

        pub fn warm(&self, name: &str) -> Result<()> {
            Err(anyhow!("warm {name}: {UNAVAILABLE}"))
        }

        pub fn run(&self, name: &str, _args: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(anyhow!("run {name}: {UNAVAILABLE}"))
        }

        pub fn config(&self, _key: &str) -> Option<i64> {
            None
        }
    }
}

pub use backend::{Executable, Runtime, SharedRuntime};

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_gracefully() {
        let err = Runtime::open("nonexistent").err().expect("stub must not open");
        assert!(format!("{err}").contains("pjrt"), "{err}");
        assert!(SharedRuntime::open("nonexistent").is_err());
    }
}
