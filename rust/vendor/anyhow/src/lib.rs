//! A tiny, API-compatible subset of the `anyhow` crate for the offline
//! build environment (no registry access). Implements exactly what this
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`ensure!`] /
//! [`bail!`] macros, and the [`Context`] extension trait for `Result` and
//! `Option`.
//!
//! Error values are eagerly rendered to strings; context frames are
//! prepended `"{context}: {cause}"` like real anyhow's single-line
//! (`{:#}`) formatting.

use std::fmt;

/// A string-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and `{:#}` both render the full single-line chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors real anyhow: any std error converts into `Error` (which itself
// intentionally does NOT implement `std::error::Error`, avoiding a
// conflicting blanket impl).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("boom {}", 42))
    }

    #[test]
    fn macro_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
    }

    #[test]
    fn context_frames_prepend() {
        let r: Result<()> = fails().context("outer");
        assert_eq!(format!("{}", r.unwrap_err()), "outer: boom 42");
        let o: Option<u8> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            let s: u32 = "7".parse()?; // std error -> Error via From
            Ok(x + s)
        }
        assert_eq!(f(1).unwrap(), 8);
        assert!(f(11).is_err());
    }
}
