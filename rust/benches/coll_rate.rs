//! Bench: the collectives lane — segmented multi-lane allreduce vs the
//! seed lockstep ring, and the dedicated-lane arm under a concurrent
//! striped p2p storm, on the 2x2-proc topology. Deterministic DES runs;
//! values are exact per configuration.
//!
//! Environment (mirrors the message_rate/rma_rate benches):
//!  * `BENCH_REPS`   — allreduces per arm (default 8).
//!  * `BENCH_JSON`   — write a machine-readable report (rates + counters +
//!    gate ratios) to this path.
//!  * `BENCH_GATE=1` — exit nonzero if a gate fails (segmented multi-lane
//!    <= lockstep, the storm degrading the dedicated arm below 0.9x, a
//!    dedicated lane not pinned during the run, or not released at free).

use vcmpi::bench::{coll_rate_run, CollMode, CollRateParams, RateReport};

struct Scenario {
    name: &'static str,
    threads: usize,
    report: RateReport,
}

const COUNTER_KEYS: [&str; 4] =
    ["stale_ctrl_drops", "policy_mismatch", "coll_lane_pinned", "coll_lane_released"];

fn scenario_json(s: &Scenario) -> String {
    let counters: Vec<String> = COUNTER_KEYS
        .iter()
        .map(|k| format!("\"{}\": {}", k, s.report.sum_stat(k) as u64))
        .collect();
    format!(
        "    {{\"name\": \"{}\", \"threads\": {}, \"rate_msgs_per_sec\": {:.1}, \
         \"counters\": {{{}}}}}",
        s.name,
        s.threads,
        s.report.rate,
        counters.join(", ")
    )
}

fn main() {
    let reps: usize =
        std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let reps = reps.clamp(2, 64);
    let threads = 8;
    let base = CollRateParams {
        threads,
        elems: 32 * 1024,
        reps,
        segments: 8,
        storm_msgs: 256,
        ..Default::default()
    };

    println!("== coll_rate: 128 KiB f32 allreduce, 2x2 procs, {reps} reps ==");
    println!("{:<22} {:>16}", "scenario", "Melem/s");
    let lockstep = Scenario {
        name: CollMode::CollLockstep.label(),
        threads,
        report: coll_rate_run(CollRateParams { mode: CollMode::CollLockstep, ..base.clone() }),
    };
    let striped = Scenario {
        name: CollMode::CollStriped.label(),
        threads,
        report: coll_rate_run(CollRateParams { mode: CollMode::CollStriped, ..base.clone() }),
    };
    let quiet = Scenario {
        name: CollMode::CollDedicated.label(),
        threads,
        report: coll_rate_run(CollRateParams { mode: CollMode::CollDedicated, ..base.clone() }),
    };
    let storm = Scenario {
        name: CollMode::CollDedicatedStorm.label(),
        threads,
        report: coll_rate_run(CollRateParams {
            mode: CollMode::CollDedicatedStorm,
            ..base
        }),
    };
    let scenarios = [&lockstep, &striped, &quiet, &storm];
    for s in scenarios {
        println!("{:<22} {:>16.3}", s.name, s.report.rate / 1e6);
    }

    // ---- regression gate (same ratios the unit tests assert, strict) ----
    let coll_striped_over_lockstep = striped.report.rate / lockstep.report.rate;
    let dedicated_storm_over_quiet = storm.report.rate / quiet.report.rate;
    let dedicated_lane_lifecycle = storm.report.sum_stat("coll_lane_pinned") == 4.0
        && storm.report.sum_stat("coll_lane_released") == 4.0
        && storm.report.sum_stat("policy_mismatch") == 0.0;
    let pass = coll_striped_over_lockstep > 1.0
        && dedicated_storm_over_quiet >= 0.9
        && dedicated_lane_lifecycle;
    println!(
        "\ngate: coll_striped/coll_lockstep = {coll_striped_over_lockstep:.3} (> 1.0 required)"
    );
    println!(
        "gate: dedicated_storm/dedicated_quiet = {dedicated_storm_over_quiet:.3} (>= 0.9 required)"
    );
    println!("gate: dedicated lane pinned + released = {dedicated_lane_lifecycle}");
    println!("gate: {}", if pass { "PASS" } else { "FAIL" });

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let body = format!(
            "{{\n  \"bench\": \"coll_rate\",\n  \"reps\": {reps},\n  \
             \"scenarios\": [\n{}\n  ],\n  \"gate\": {{\n    \
             \"coll_striped_over_lockstep\": {coll_striped_over_lockstep:.4},\n    \
             \"dedicated_storm_over_quiet\": {dedicated_storm_over_quiet:.4},\n    \
             \"dedicated_lane_lifecycle\": {dedicated_lane_lifecycle},\n    \
             \"pass\": {pass}\n  }}\n}}\n",
            scenarios.into_iter().map(scenario_json).collect::<Vec<_>>().join(",\n"),
        );
        std::fs::write(&path, body).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote {path}");
    }

    let gate_enforced = std::env::var("BENCH_GATE").map(|v| v == "1").unwrap_or(false);
    if gate_enforced && !pass {
        eprintln!("coll_rate regression gate FAILED");
        std::process::exit(1);
    }
}
