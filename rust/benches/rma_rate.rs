//! Bench: the §7 one-sided rate lane — one origin thread's accumulate
//! rate on a striped window vs the ordered-window baseline, plus the
//! program-order correctness probe. Deterministic DES runs; values are
//! exact per configuration.
//!
//! Environment (mirrors the message_rate bench):
//!  * `BENCH_MSGS`   — accumulates issued by the origin thread (default 256).
//!  * `BENCH_JSON`   — write a machine-readable report (rates + counters +
//!    gate ratios) to this path.
//!  * `BENCH_GATE=1` — exit nonzero if a gate fails (striped <= ordered,
//!    or the ordered window reordered same-location accumulates).

use vcmpi::bench::{
    ordered_window_program_order_preserved, rma_rate_run, RateReport, RmaRateParams, WinMode,
};

struct Scenario {
    name: &'static str,
    threads: usize,
    report: RateReport,
}

const COUNTER_KEYS: [&str; 4] =
    ["stale_ctrl_drops", "empty_polls", "doorbell_skips", "win_lane_pinned"];

fn scenario_json(s: &Scenario) -> String {
    let counters: Vec<String> = COUNTER_KEYS
        .iter()
        .map(|k| format!("\"{}\": {}", k, s.report.sum_stat(k) as u64))
        .collect();
    format!(
        "    {{\"name\": \"{}\", \"threads\": {}, \"rate_msgs_per_sec\": {:.1}, \
         \"counters\": {{{}}}}}",
        s.name,
        s.threads,
        s.report.rate,
        counters.join(", ")
    )
}

fn main() {
    let msgs: usize =
        std::env::var("BENCH_MSGS").ok().and_then(|v| v.parse().ok()).unwrap_or(256);
    let msgs = msgs.clamp(64, 1024) / 32 * 32; // multiple of the flush window
    let threads = 8;
    let base = RmaRateParams {
        threads,
        msgs_per_core: msgs,
        msg_size: 4096,
        window: 32,
        ..Default::default()
    };

    println!("== rma_rate: 4 KiB SumU64 accumulates, 1 origin thread, {msgs} ops ==");
    println!("{:<16} {:>14}", "scenario", "Mmsg/s");
    let ordered = Scenario {
        name: "win_ordered",
        threads,
        report: rma_rate_run(RmaRateParams { mode: WinMode::WinOrdered, ..base.clone() }),
    };
    let striped = Scenario {
        name: "win_striped",
        threads,
        report: rma_rate_run(RmaRateParams { mode: WinMode::WinStriped, ..base }),
    };
    let scenarios = [&ordered, &striped];
    for s in scenarios {
        println!("{:<16} {:>14.3}", s.name, s.report.rate / 1e6);
    }

    // ---- regression gate ----
    let win_striped_over_ordered = striped.report.rate / ordered.report.rate;
    let program_order = ordered_window_program_order_preserved();
    let pass = win_striped_over_ordered > 1.0 && program_order;
    println!("\ngate: win_striped/win_ordered = {win_striped_over_ordered:.3} (> 1.0 required)");
    println!("gate: ordered window program order preserved = {program_order}");
    println!("gate: {}", if pass { "PASS" } else { "FAIL" });

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let body = format!(
            "{{\n  \"bench\": \"rma_rate\",\n  \"msgs_per_core\": {msgs},\n  \
             \"scenarios\": [\n{}\n  ],\n  \"gate\": {{\n    \
             \"win_striped_over_ordered\": {win_striped_over_ordered:.4},\n    \
             \"ordered_window_program_order_preserved\": {program_order},\n    \
             \"pass\": {pass}\n  }}\n}}\n",
            scenarios.into_iter().map(scenario_json).collect::<Vec<_>>().join(",\n"),
        );
        std::fs::write(&path, body).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote {path}");
    }

    let gate_enforced = std::env::var("BENCH_GATE").map(|v| v == "1").unwrap_or(false);
    if gate_enforced && !pass {
        eprintln!("rma_rate regression gate FAILED");
        std::process::exit(1);
    }
}
