//! Bench: the §7 one-sided rate lane — one origin thread's accumulate
//! rate on a striped window vs the ordered-window baseline, the
//! program-order correctness probe, and the passive-target lock-epoch
//! arms (shared-striped vs exclusive-ordered vs `mpi_assert_no_locks`
//! elision). Deterministic DES runs; values are exact per configuration.
//!
//! Environment (mirrors the message_rate bench):
//!  * `BENCH_MSGS`   — accumulates issued by the origin thread (default 256).
//!  * `BENCH_JSON`   — write a machine-readable report (rates + counters +
//!    gate ratios) to this path.
//!  * `BENCH_GATE=1` — exit nonzero if a gate fails (striped <= ordered,
//!    the ordered window reordered same-location accumulates, the
//!    no_locks elision failed to pay, or epochs erased the striping win).

use vcmpi::bench::{
    ordered_window_program_order_preserved, rma_rate_run, RateReport, RmaRateParams, WinMode,
};

struct Scenario {
    name: &'static str,
    threads: usize,
    report: RateReport,
}

const COUNTER_KEYS: [&str; 6] = [
    "stale_ctrl_drops",
    "empty_polls",
    "doorbell_skips",
    "win_lane_pinned",
    "lock_elisions",
    "lock_wire_reqs",
];

fn scenario_json(s: &Scenario) -> String {
    let counters: Vec<String> = COUNTER_KEYS
        .iter()
        .map(|k| format!("\"{}\": {}", k, s.report.sum_stat(k) as u64))
        .collect();
    format!(
        "    {{\"name\": \"{}\", \"threads\": {}, \"rate_msgs_per_sec\": {:.1}, \
         \"counters\": {{{}}}}}",
        s.name,
        s.threads,
        s.report.rate,
        counters.join(", ")
    )
}

fn main() {
    let msgs: usize =
        std::env::var("BENCH_MSGS").ok().and_then(|v| v.parse().ok()).unwrap_or(256);
    let msgs = msgs.clamp(64, 1024) / 32 * 32; // multiple of the flush window
    let threads = 8;
    let base = RmaRateParams {
        threads,
        msgs_per_core: msgs,
        msg_size: 4096,
        window: 32,
        ..Default::default()
    };

    println!("== rma_rate: 4 KiB SumU64 accumulates, 1 origin thread, {msgs} ops ==");
    println!("{:<16} {:>14}", "scenario", "Mmsg/s");
    let run = |mode: WinMode| Scenario {
        name: mode.label(),
        threads,
        report: rma_rate_run(RmaRateParams { mode, ..base.clone() }),
    };
    let ordered = run(WinMode::WinOrdered);
    let striped = run(WinMode::WinStriped);
    let passive_shared = run(WinMode::PassiveShared);
    let passive_excl = run(WinMode::PassiveExclusive);
    let passive_no_locks = run(WinMode::PassiveNoLocks);
    let scenarios = [&ordered, &striped, &passive_shared, &passive_excl, &passive_no_locks];
    for s in scenarios {
        println!("{:<16} {:>14.3}", s.name, s.report.rate / 1e6);
    }

    // ---- regression gates ----
    let win_striped_over_ordered = striped.report.rate / ordered.report.rate;
    let program_order = ordered_window_program_order_preserved();
    // The mpi_assert_no_locks elision must pay: the same epoch-based
    // program text on the same striped window, minus the lock protocol.
    let no_locks_over_locked = passive_no_locks.report.rate / passive_shared.report.rate;
    // Striping must survive lock epochs: shared epochs on the striped
    // window beat exclusive epochs on the ordered window.
    let passive_striped_over_exclusive = passive_shared.report.rate / passive_excl.report.rate;
    let pass = win_striped_over_ordered > 1.0
        && program_order
        && no_locks_over_locked >= 1.0
        && passive_striped_over_exclusive > 1.0;
    println!("\ngate: win_striped/win_ordered = {win_striped_over_ordered:.3} (> 1.0 required)");
    println!("gate: ordered window program order preserved = {program_order}");
    println!("gate: passive_no_locks/passive_shared = {no_locks_over_locked:.3} (>= 1.0 required)");
    println!(
        "gate: passive_shared/passive_excl = {passive_striped_over_exclusive:.3} (> 1.0 required)"
    );
    println!("gate: {}", if pass { "PASS" } else { "FAIL" });

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let body = format!(
            "{{\n  \"bench\": \"rma_rate\",\n  \"msgs_per_core\": {msgs},\n  \
             \"scenarios\": [\n{}\n  ],\n  \"gate\": {{\n    \
             \"win_striped_over_ordered\": {win_striped_over_ordered:.4},\n    \
             \"ordered_window_program_order_preserved\": {program_order},\n    \
             \"no_locks_over_locked\": {no_locks_over_locked:.4},\n    \
             \"passive_striped_over_exclusive\": {passive_striped_over_exclusive:.4},\n    \
             \"pass\": {pass}\n  }}\n}}\n",
            scenarios.into_iter().map(scenario_json).collect::<Vec<_>>().join(",\n"),
        );
        std::fs::write(&path, body).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote {path}");
    }

    let gate_enforced = std::env::var("BENCH_GATE").map(|v| v == "1").unwrap_or(false);
    if gate_enforced && !pass {
        eprintln!("rma_rate regression gate FAILED");
        std::process::exit(1);
    }
}
