//! Bench: smoke-regenerate a representative subset of the paper
//! figures/tables at reduced scale — proving the evaluation pipeline end
//! to end while keeping `cargo bench` bounded on the 1-core host.
//! (`repro figures all --scale 2` regenerates EVERYTHING; its output is
//! committed as figures_output.txt.)

use vcmpi::bench::figures;

const SMOKE: &[&str] =
    &["fig2", "fig4", "table1", "fig8", "fig17", "headline", "ablate-policy"];

fn main() {
    let t0 = std::time::Instant::now();
    for id in SMOKE.iter().copied() {
        let f0 = std::time::Instant::now();
        let csv = figures::run_figure(id, 1).expect("known id");
        println!(
            "### {id} ({} rows, {:.1}s)",
            csv.rows.len(),
            f0.elapsed().as_secs_f64()
        );
        csv.print();
        println!();
    }
    println!("smoke subset regenerated in {:.1}s (full set: repro figures all)", t0.elapsed().as_secs_f64());
}
