//! Bench: the §5 message-rate benchmark across all six execution modes —
//! the end-to-end series behind Figs. 10/11/13. Deterministic DES runs;
//! values are exact per configuration.

use vcmpi::bench::{message_rate, Mode, Op, RateParams};
use vcmpi::fabric::Interconnect;

fn main() {
    let msgs = std::env::var("BENCH_MSGS").ok().and_then(|v| v.parse().ok()).unwrap_or(1024);
    println!("== message_rate: 8-byte Isend, 2 nodes, {msgs} msgs/core ==");
    println!("{:<24} {:>8} {:>14}", "mode", "threads", "Mmsg/s");
    for mode in Mode::all() {
        for threads in [1usize, 4, 16] {
            let r = message_rate(RateParams {
                mode,
                threads,
                msgs_per_core: msgs,
                ..Default::default()
            });
            println!("{:<24} {:>8} {:>14.3}", mode.label(), threads, r / 1e6);
        }
    }
    println!("\n== message_rate: 8-byte Isend, ONE hot communicator ==");
    println!("(striped = per-message VCI striping + receiver-side seq reordering)");
    println!("{:<24} {:>8} {:>14}", "mode", "threads", "Mmsg/s");
    for mode in [Mode::SerCommVcis, Mode::SerCommStriped, Mode::ParCommVcis, Mode::Endpoints] {
        for threads in [4usize, 16] {
            let r = message_rate(RateParams {
                mode,
                threads,
                msgs_per_core: msgs,
                ..Default::default()
            });
            println!("{:<24} {:>8} {:>14.3}", mode.label(), threads, r / 1e6);
        }
    }

    println!("\n== message_rate: 8-byte Put, 16 cores ==");
    println!("{:<24} {:>10} {:>14}", "mode", "fabric", "Mmsg/s");
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for mode in [Mode::Everywhere, Mode::ParCommVcis, Mode::Endpoints] {
            let r = message_rate(RateParams {
                mode,
                interconnect: ic,
                threads: 16,
                op: Op::Put,
                msgs_per_core: (msgs / 4).max(64),
                ..Default::default()
            });
            println!("{:<24} {:>10} {:>14.3}", mode.label(), format!("{ic:?}"), r / 1e6);
        }
    }
}
