//! Bench: the §5 message-rate benchmark across all six execution modes —
//! the end-to-end series behind Figs. 10/11/13 — plus the striping gate
//! scenarios (striped / sharded / wildcard-storm). Deterministic DES runs;
//! values are exact per configuration.
//!
//! Environment:
//!  * `BENCH_MSGS`  — messages per core (default 1024).
//!  * `BENCH_JSON`  — write a machine-readable report (rates + engine
//!    counters + regression ratios) to this path.
//!  * `BENCH_GATE=1`— exit nonzero if a regression-gate ratio fails
//!    (striped <= single-VCI baseline, sharded <= home engine, or the
//!    streamed arm <= its locked par_comm twin / not lock-free).
//!  * `BENCH_QUICK=1` — skip the printed figure tables and run only the
//!    gate scenarios (what the CI `bench` job does).

use vcmpi::bench::{message_rate, message_rate_run, Mode, Op, RateParams, RateReport};
use vcmpi::fabric::Interconnect;

struct Scenario {
    name: &'static str,
    threads: usize,
    report: RateReport,
}

const COUNTER_KEYS: [&str; 7] = [
    "stale_ctrl_drops",
    "dup_seq_drops",
    "epoch_flips",
    "epoch_unflips",
    "wildcard_posts",
    "empty_polls",
    "doorbell_skips",
];

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn scenario_json(s: &Scenario) -> String {
    let counters: Vec<String> = COUNTER_KEYS
        .iter()
        .map(|k| format!("\"{}\": {}", k, s.report.sum_stat(k) as u64))
        .collect();
    format!(
        "    {{\"name\": \"{}\", \"threads\": {}, \"rate_msgs_per_sec\": {:.1}, \
         \"counters\": {{{}}}}}",
        json_escape(s.name),
        s.threads,
        s.report.rate,
        counters.join(", ")
    )
}

fn main() {
    let msgs: usize =
        std::env::var("BENCH_MSGS").ok().and_then(|v| v.parse().ok()).unwrap_or(1024);
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    if !quick {
        println!("== message_rate: 8-byte Isend, 2 nodes, {msgs} msgs/core ==");
        println!("{:<24} {:>8} {:>14}", "mode", "threads", "Mmsg/s");
        for mode in Mode::all() {
            for threads in [1usize, 4, 16] {
                let r = message_rate(RateParams {
                    mode,
                    threads,
                    msgs_per_core: msgs,
                    ..Default::default()
                });
                println!("{:<24} {:>8} {:>14.3}", mode.label(), threads, r / 1e6);
            }
        }
        println!("\n== message_rate: 8-byte Put, 16 cores ==");
        println!("{:<24} {:>10} {:>14}", "mode", "fabric", "Mmsg/s");
        for ic in [Interconnect::Opa, Interconnect::Ib] {
            for mode in [Mode::Everywhere, Mode::ParCommVcis, Mode::Endpoints] {
                let r = message_rate(RateParams {
                    mode,
                    interconnect: ic,
                    threads: 16,
                    op: Op::Put,
                    msgs_per_core: (msgs / 4).max(64),
                    ..Default::default()
                });
                println!("{:<24} {:>10} {:>14.3}", mode.label(), format!("{ic:?}"), r / 1e6);
            }
        }
    }

    // ---- gate scenarios: ONE hot communicator, fixed iteration budget ----
    vcmpi::mpi::instrument::reset_proc_counters();
    let gate_msgs = msgs.clamp(128, 512) / 32 * 32; // multiple of the window
    let threads = 8;
    let base = RateParams {
        threads,
        msgs_per_core: gate_msgs,
        window: 32,
        ..Default::default()
    };
    println!("\n== message_rate: striping gate ({gate_msgs} msgs/core, {threads} threads) ==");
    println!("{:<26} {:>14}", "scenario", "Mmsg/s");
    let single = Scenario {
        name: "ser_comm+vcis",
        threads,
        report: message_rate_run(RateParams { mode: Mode::SerCommVcis, ..base.clone() }),
    };
    let striped = Scenario {
        name: "ser_comm+striped",
        threads,
        report: message_rate_run(RateParams { mode: Mode::SerCommStriped, ..base.clone() }),
    };
    let sharded = Scenario {
        name: "ser_comm+striped_sharded",
        threads,
        report: message_rate_run(RateParams {
            mode: Mode::SerCommStripedSharded,
            ..base.clone()
        }),
    };
    let home = Scenario {
        name: "ser_comm+striped_sharded/home_engine",
        threads,
        report: message_rate_run(RateParams {
            mode: Mode::SerCommStripedSharded,
            cfg_override: Some(vcmpi::mpi::MpiConfig::striped(threads + 1)),
            ..base.clone()
        }),
    };
    let wildcard = Scenario {
        name: "ser_comm+striped_wildcard",
        threads: 4,
        report: message_rate_run(RateParams {
            mode: Mode::SerCommStripedWildcard,
            threads: 4,
            msgs_per_core: gate_msgs.min(256),
            window: 32,
            ..Default::default()
        }),
    };
    let mixed = Scenario {
        name: "ser_comm+mixed_policy",
        threads,
        report: message_rate_run(RateParams { mode: Mode::SerCommMixedPolicy, ..base.clone() }),
    };
    let locked = Scenario {
        name: "par_comm+vcis",
        threads,
        report: message_rate_run(RateParams { mode: Mode::ParCommVcis, ..base.clone() }),
    };
    let streamed = Scenario {
        name: "par_comm+streamed",
        threads,
        report: message_rate_run(RateParams { mode: Mode::SerCommStreamed, ..base.clone() }),
    };
    let scenarios = [&single, &striped, &sharded, &home, &wildcard, &mixed, &locked, &streamed];
    for s in scenarios {
        println!("{:<26} {:>14.3}", s.name, s.report.rate / 1e6);
    }

    // ---- Table 1: per-op critical-path cost, locked twin vs stream ----
    // Both arms run the identical topology (per-thread comms, one VCI
    // each); the probe brackets only the measured phase, so the columns
    // are exact per-(isend|irecv|wait-progress) acquisition counts.
    let t1_ops = (2 * threads * gate_msgs) as f64;
    let per_op = |s: &Scenario, k: &str| s.report.sum_stat(k) / t1_ops;
    println!("\n== Table 1: critical-path acquisitions per posted op ==");
    println!(
        "{:<20} {:>10} {:>10} {:>12} {:>12} {:>14}",
        "arm", "vci_lock", "req_lock", "global_lock", "stream_ops", "freelist_hits"
    );
    for s in [&locked, &streamed] {
        println!(
            "{:<20} {:>10.3} {:>10.3} {:>12.3} {:>12.3} {:>14.3}",
            s.name,
            per_op(s, "t1_vci_locks"),
            per_op(s, "t1_request_locks"),
            per_op(s, "t1_global_locks"),
            per_op(s, "t1_stream_ops"),
            per_op(s, "t1_freelist_hits"),
        );
    }

    // ---- regression gate (same ratios the unit tests assert) ----
    let striped_over_single = striped.report.rate / single.report.rate;
    let sharded_over_home = sharded.report.rate / home.report.rate;
    let epochs_resolved = wildcard.report.sum_stat("epoch_flips")
        == wildcard.report.sum_stat("epoch_unflips")
        && wildcard.report.sum_stat("epoch_flips") > 0.0;
    // Per-comm policy gate: the info-keyed striped comm, coexisting with
    // an ordered comm in the same process, must hold >= 90% of the pure
    // striped_sharded arm's rate — and the ordered comm must never grow a
    // sharded engine (its path stays serialized on its own VCI).
    let mixed_over_sharded = mixed.report.rate / sharded.report.rate;
    let mixed_ordered_serialized = mixed.report.sum_stat("ordered_striped_engine") == 0.0
        && mixed.report.sum_stat("policy_mismatch") == 0.0
        && mixed.report.sum_stat("striped_engine") > 0.0;
    // Stream gate (PR 8): the single-writer fast path must beat its locked
    // twin AND take literally zero VCI/Request/Global locks in the
    // measured window while actually riding the stream entry.
    let streamed_over_locked = streamed.report.rate / locked.report.rate;
    let streamed_lock_free = streamed.report.sum_stat("t1_vci_locks") == 0.0
        && streamed.report.sum_stat("t1_request_locks") == 0.0
        && streamed.report.sum_stat("t1_global_locks") == 0.0
        && streamed.report.sum_stat("t1_stream_ops") > 0.0;
    let pass = striped_over_single > 1.0
        && sharded_over_home > 1.0
        && epochs_resolved
        && mixed_over_sharded >= 0.9
        && mixed_ordered_serialized
        && streamed_over_locked > 1.0
        && streamed_lock_free;
    println!("\ngate: striped/single_vci = {striped_over_single:.3} (> 1.0 required)");
    println!("gate: sharded/home_engine = {sharded_over_home:.3} (> 1.0 required)");
    println!("gate: wildcard epochs resolved = {epochs_resolved}");
    println!("gate: mixed_policy/striped_sharded = {mixed_over_sharded:.3} (>= 0.9 required)");
    println!("gate: mixed ordered comm serialized = {mixed_ordered_serialized}");
    println!("gate: streamed/locked = {streamed_over_locked:.3} (> 1.0 required)");
    println!("gate: streamed arm lock-free = {streamed_lock_free}");
    println!("gate: {}", if pass { "PASS" } else { "FAIL" });

    if let Ok(path) = std::env::var("BENCH_JSON") {
        // Process-wide engine counters over the whole gate section
        // (`mpi::instrument`), alongside the per-scenario sums.
        let pc = vcmpi::mpi::instrument::proc_counters();
        let body = format!(
            "{{\n  \"bench\": \"message_rate\",\n  \"msgs_per_core\": {gate_msgs},\n  \
             \"scenarios\": [\n{}\n  ],\n  \"process_counters\": {{\n    \
             \"stale_ctrl_drops\": {},\n    \"dup_seq_drops\": {},\n    \
             \"epoch_flips\": {},\n    \"epoch_unflips\": {},\n    \
             \"wildcard_posts\": {},\n    \"empty_polls\": {},\n    \
             \"doorbell_skips\": {}\n  }},\n  \"gate\": {{\n    \
             \"striped_over_single_vci\": {striped_over_single:.4},\n    \
             \"sharded_over_home_engine\": {sharded_over_home:.4},\n    \
             \"wildcard_epochs_resolved\": {epochs_resolved},\n    \
             \"mixed_over_striped_sharded\": {mixed_over_sharded:.4},\n    \
             \"mixed_ordered_serialized\": {mixed_ordered_serialized},\n    \
             \"streamed_over_locked\": {streamed_over_locked:.4},\n    \
             \"streamed_lock_free\": {streamed_lock_free},\n    \
             \"pass\": {pass}\n  }}\n}}\n",
            scenarios.into_iter().map(scenario_json).collect::<Vec<_>>().join(",\n"),
            pc.stale_ctrl_drops,
            pc.dup_seq_drops,
            pc.epoch_flips,
            pc.epoch_unflips,
            pc.wildcard_posts,
            pc.empty_polls,
            pc.doorbell_skips,
        );
        std::fs::write(&path, body).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote {path}");
    }

    let gate_enforced = std::env::var("BENCH_GATE").map(|v| v == "1").unwrap_or(false);
    if gate_enforced && !pass {
        eprintln!("bench regression gate FAILED");
        std::process::exit(1);
    }
}
