//! Bench: the train-step lane — overlapped bucket allreduce (iallreduce
//! issued during the backward pass, waited at step end) vs blocking
//! bucket-by-bucket, on the 2x2-proc topology. Deterministic DES runs;
//! values are exact per configuration.
//!
//! Environment (mirrors the message_rate/rma_rate/coll_rate benches):
//!  * `BENCH_REPS`   — train steps per arm (default 8).
//!  * `BENCH_JSON`   — write a machine-readable report (rates + counters +
//!    gate ratios) to this path.
//!  * `BENCH_GATE=1` — exit nonzero if a gate fails (overlap <= blocking,
//!    no communication actually hidden, dedicated bucket lanes colliding,
//!    or a wire-contract violation).

use vcmpi::bench::{train_step_run, RateReport, StepMode, TrainStepParams};

struct Scenario {
    name: &'static str,
    threads: usize,
    report: RateReport,
}

const COUNTER_KEYS: [&str; 3] =
    ["stale_ctrl_drops", "policy_mismatch", "distinct_coll_lanes"];

fn scenario_json(s: &Scenario) -> String {
    let counters: Vec<String> = COUNTER_KEYS
        .iter()
        .map(|k| format!("\"{}\": {}", k, s.report.sum_stat(k) as u64))
        .collect();
    format!(
        "    {{\"name\": \"{}\", \"threads\": {}, \"rate_msgs_per_sec\": {:.1}, \
         \"counters\": {{{}}}}}",
        s.name,
        s.threads,
        s.report.rate,
        counters.join(", ")
    )
}

fn main() {
    let reps: usize =
        std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let reps = reps.clamp(2, 64);
    let threads = 8;
    let buckets = 4;
    let base = TrainStepParams {
        threads,
        buckets,
        elems: 32 * 1024,
        compute_ns: 50_000,
        steps: reps,
        ..Default::default()
    };

    println!("== train_step: 128 KiB f32 grads, {buckets} buckets, 2x2 procs, {reps} steps ==");
    println!("{:<22} {:>16}", "scenario", "Melem/s");
    let blocking = Scenario {
        name: StepMode::StepBlocking.label(),
        threads,
        report: train_step_run(TrainStepParams { mode: StepMode::StepBlocking, ..base.clone() }),
    };
    let overlap = Scenario {
        name: StepMode::StepOverlap.label(),
        threads,
        report: train_step_run(TrainStepParams { mode: StepMode::StepOverlap, ..base }),
    };
    let scenarios = [&blocking, &overlap];
    for s in scenarios {
        println!("{:<22} {:>16.3}", s.name, s.report.rate / 1e6);
    }

    // ---- regression gate (same ratios the unit test asserts, strict) ----
    let overlap_over_blocking = overlap.report.rate / blocking.report.rate;
    let overlap_hidden_ns = overlap.report.measurements["coll_overlap_ns"];
    // 4 procs x `buckets` dedicated comms, each on its own lane.
    let distinct_lanes_ok =
        overlap.report.sum_stat("distinct_coll_lanes") == (4 * buckets) as f64;
    let wire_contract_ok = overlap.report.sum_stat("policy_mismatch") == 0.0
        && overlap.report.sum_stat("stale_ctrl_drops") == 0.0;
    let pass = overlap_over_blocking > 1.0
        && overlap_hidden_ns > 0.0
        && distinct_lanes_ok
        && wire_contract_ok;
    println!("\ngate: step_overlap/step_blocking = {overlap_over_blocking:.3} (> 1.0 required)");
    println!("gate: coll_overlap_ns = {overlap_hidden_ns:.0} (> 0 required)");
    println!("gate: distinct dedicated bucket lanes = {distinct_lanes_ok}");
    println!("gate: wire contract clean = {wire_contract_ok}");
    println!("gate: {}", if pass { "PASS" } else { "FAIL" });

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let body = format!(
            "{{\n  \"bench\": \"train_step\",\n  \"reps\": {reps},\n  \
             \"scenarios\": [\n{}\n  ],\n  \"gate\": {{\n    \
             \"overlap_over_blocking\": {overlap_over_blocking:.4},\n    \
             \"coll_overlap_ns\": {overlap_hidden_ns:.0},\n    \
             \"distinct_coll_lanes\": {distinct_lanes_ok},\n    \
             \"pass\": {pass}\n  }}\n}}\n",
            scenarios.into_iter().map(scenario_json).collect::<Vec<_>>().join(",\n"),
        );
        std::fs::write(&path, body).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote {path}");
    }

    let gate_enforced = std::env::var("BENCH_GATE").map(|v| v == "1").unwrap_or(false);
    if gate_enforced && !pass {
        eprintln!("train_step regression gate FAILED");
        std::process::exit(1);
    }
}
