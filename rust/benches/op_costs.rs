//! Bench: per-operation virtual-time costs of the MPI critical path under
//! each critical-section mode — the microscopic view behind Table 1 and
//! Figs. 2/12. Custom harness (criterion is unavailable offline): each
//! measurement is a deterministic DES run, so a single sample is exact.

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, Src, Tag};
use vcmpi::platform::pnow;
use vcmpi::sim::SimOutcome;

fn op_costs(label: &str, cfg: MpiConfig) {
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        cfg,
        1,
    );
    let label2 = label.to_string();
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        const N: u64 = 256;
        if proc.rank() == 0 {
            // Immediate isend+wait cost (amortized over N).
            let t0 = pnow(proc.backend);
            for _ in 0..N {
                let req = proc.isend(&world, 1, 1, &[0u8; 8]);
                proc.wait(req);
            }
            let isend_ns = (pnow(proc.backend) - t0) / N;
            // Irecv post cost (no traffic yet for these tags).
            let t0 = pnow(proc.backend);
            let reqs: Vec<_> =
                (0..N).map(|_| proc.irecv(&world, Src::Rank(1), Tag::Value(2))).collect();
            let irecv_ns = (pnow(proc.backend) - t0) / N;
            // Tell rank 1 to send the matching messages, then drain.
            proc.send(&world, 1, 9, &[]);
            proc.waitall(reqs);
            // One empty progress iteration.
            let t0 = pnow(proc.backend);
            for _ in 0..N {
                proc.progress_for_request(0);
            }
            let progress_ns = (pnow(proc.backend) - t0) / N;
            println!(
                "{label2:24} isend+wait(imm) {isend_ns:5} ns | irecv-post {irecv_ns:5} ns | progress-iter {progress_ns:5} ns"
            );
        } else {
            for _ in 0..N {
                let _ = proc.recv(&world, Src::Rank(0), Tag::Value(1));
            }
            let _ = proc.recv(&world, Src::Rank(0), Tag::Value(9));
            for _ in 0..N {
                proc.send(&world, 0, 2, &[0u8; 8]);
            }
        }
        proc.barrier(&world);
    });
    assert_eq!(r.outcome, SimOutcome::Completed);
}

fn main() {
    println!("== op_costs: single-threaded critical-path costs (virtual ns) ==");
    op_costs("global (original)", MpiConfig::original());
    op_costs("fg single-vci", MpiConfig::fg_single_vci());
    op_costs("fg+all-opts (16 vci)", MpiConfig::optimized(16));
    let mut unsafe_cfg = MpiConfig::optimized(16);
    unsafe_cfg.unsafe_no_thread_safety = true;
    op_costs("no locks/atomics", unsafe_cfg);
}
