//! SimSan seeded-violation tests: each test plants one deliberate
//! discipline violation in an otherwise tiny simulated program and asserts
//! that the sanitizer reports it deterministically (as
//! `SimOutcome::Panicked("SimSan: ...")`), plus positive controls showing
//! the sanctioned patterns run silent. Only meaningful with the checker
//! compiled in, hence the file-level feature gate (the default build has
//! it; release benches run `--no-default-features`).
#![cfg(feature = "simsan")]

use std::sync::{Arc, Mutex};

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::instrument::{HostMutex, LockClass};
use vcmpi::mpi::{run_cluster, ClusterSpec, Comm, Info, MpiConfig};
use vcmpi::platform::{Backend, PMutex};
use vcmpi::sim::{self, CostModel, Sim, SimAtomicU64, SimCell, SimMutex, SimOutcome};

fn expect_simsan(r: vcmpi::sim::SimReport, needle: &str) {
    expect_simsan_outcome(&r.outcome, needle);
}

fn expect_simsan_outcome(outcome: &SimOutcome, needle: &str) {
    match outcome {
        SimOutcome::Panicked(m) if m.contains("SimSan") && m.contains(needle) => {}
        other => panic!("expected a SimSan report containing {needle:?}, got {other:?}"),
    }
}

/// Seeded violation (a): acquiring `cs.global` (rank 10) while holding
/// `vci.state` (rank 30) inverts the declared hierarchy — the mirror image
/// of the sanctioned Global -> Vci nesting — and must be reported at the
/// acquisition attempt, before anything can park.
#[test]
fn seeded_lock_order_inversion_is_detected() {
    let outer = PMutex::new(Backend::Sim, ());
    let inner = PMutex::new(Backend::Sim, ());
    let mut s = Sim::new(CostModel::default());
    s.spawn_setup("inverted", move || {
        let _vci = outer.lock_class(LockClass::Vci);
        let _global = inner.lock_class(LockClass::Global); // rank 10 under rank 30
        unreachable!("SimSan must reject the inverted acquisition");
    });
    expect_simsan(s.run(), "lock-order violation");
}

/// Seeded violation (b): a host `std::sync` mutex held across a scheduler
/// interaction. The DES runs one OS thread at a time, so a baton handoff
/// with a host lock held can deadlock the *host* process — SimSan reports
/// it at the interaction point instead.
#[test]
fn seeded_host_lock_across_park_is_detected() {
    let table = HostMutex::new(0u64);
    let mut s = Sim::new(CostModel::default());
    s.spawn_setup("holder", move || {
        let _g = table.lock(LockClass::HostComms);
        sim::yield_now(); // interaction with the host lock still held
        unreachable!("SimSan must reject the yield under a host lock");
    });
    expect_simsan(s.run(), "host lock");
}

/// Seeded violation (c): two simulated threads touch a plain `SimCell`
/// with no simulated sync edge between them. Baton order makes the access
/// memory-safe but not meaningful — the modeled program has a data race,
/// and the second access must be reported against the first thread's
/// last-writer epoch.
#[test]
fn seeded_plain_cell_race_is_detected() {
    let cell = Arc::new(SimCell::new(0u64));
    let mut s = Sim::new(CostModel::default());
    let w = cell.clone();
    s.spawn_setup("writer", move || {
        *w.get() = 1;
        sim::advance(10);
        sim::yield_now();
    });
    s.spawn_setup("racer", move || {
        sim::advance(5);
        sim::yield_now();
        let _ = *cell.get(); // no happens-before edge from the writer
    });
    expect_simsan(s.run(), "data race");
}

/// Positive control: the same cross-thread cell traffic, ordered through a
/// `SimMutex` (release -> acquire vector-clock edge), runs silent — SimSan
/// flags missing edges, not cross-thread sharing itself.
#[test]
fn mutex_ordered_cell_traffic_is_clean() {
    let cell = Arc::new(SimCell::new(0u64));
    let gate = Arc::new(SimMutex::new(()));
    let mut s = Sim::new(CostModel::default());
    let (w, wg) = (cell.clone(), gate.clone());
    s.spawn_setup("writer", move || {
        let g = wg.lock();
        *w.get() = 7;
        drop(g); // release edge carries the write epoch
        sim::advance(10);
        sim::yield_now();
    });
    s.spawn_setup("reader", move || {
        sim::advance(25); // stay behind the writer until it releases
        let g = gate.lock(); // acquire edge joins the writer's clock
        assert_eq!(*cell.get(), 7);
        drop(g);
    });
    let r = s.run();
    assert_eq!(r.outcome, SimOutcome::Completed, "sanctioned pattern must run silent");
}

/// Positive + negative control for the `multi` class: the stop-the-world
/// all-shard sweep (ascending ordinals) is the sanctioned pattern; the
/// descending sweep is a latent ABBA deadlock and must be rejected.
#[test]
fn shard_ordinal_sweeps_check_direction() {
    let ascending = {
        let a = PMutex::new(Backend::Sim, ());
        let b = PMutex::new(Backend::Sim, ());
        let mut s = Sim::new(CostModel::default());
        s.spawn_setup("sweep", move || {
            let _s0 = a.lock_ordinal(LockClass::Shard, 0);
            let _s1 = b.lock_ordinal(LockClass::Shard, 1);
        });
        s.run()
    };
    assert_eq!(ascending.outcome, SimOutcome::Completed, "ascending sweep is sanctioned");

    let descending = {
        let a = PMutex::new(Backend::Sim, ());
        let b = PMutex::new(Backend::Sim, ());
        let mut s = Sim::new(CostModel::default());
        s.spawn_setup("sweep", move || {
            let _s1 = a.lock_ordinal(LockClass::Shard, 1);
            let _s0 = b.lock_ordinal(LockClass::Shard, 0);
            unreachable!("SimSan must reject the descending sweep");
        });
        s.run()
    };
    expect_simsan(descending, "lock-order violation");
}

/// Seeded violation (d): a second thread touches a stream-owned VCI. The
/// owner binds a `vcmpi_stream=local` communicator's lane into
/// single-writer mode and publishes the lane index; the intruder then
/// drives progress on that lane — a locked `with_state` entry from a
/// foreign thread — and the ownership tripwire must fire before any state
/// is read (ISSUE 8's deterministic cross-thread detection).
#[test]
fn seeded_cross_thread_stream_touch_is_detected() {
    let fabric =
        FabricConfig { interconnect: Interconnect::Ib, nodes: 1, procs_per_node: 1, max_contexts_per_node: 16 };
    let mut spec = ClusterSpec::new(fabric, MpiConfig::optimized(4), 2);
    spec.time_limit = Some(10_000_000);
    spec.service_threads = false;
    let lane_plus_one = Arc::new(SimAtomicU64::new(0));
    let flag = lane_plus_one.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let streamed =
                proc.comm_dup_with_info(&world, &Info::new().with("vcmpi_stream", "local"));
            let lane = proc.stream_bind(&streamed);
            flag.store(lane as u64 + 1); // release: publish the bound lane
            // Keep the stream bound; the intruder panics before we get here
            // in any run that reaches the barrier.
            sim::advance(1_000);
        } else {
            let mut lane;
            loop {
                lane = lane_plus_one.load(); // acquire: join the owner's bind
                if lane != 0 {
                    break;
                }
                sim::advance(50);
                sim::yield_now();
            }
            proc.progress_vci(lane as usize - 1); // foreign with_state entry
            unreachable!("SimSan must reject the cross-thread stream touch");
        }
    });
    expect_simsan_outcome(&r.outcome, "stream-owned VCI");
}

/// Positive control for the stream layer: bind → unbind → rebind by a
/// *different* thread is the sanctioned handoff. The unbind/bind
/// transitions run under the VCI lock, whose release→acquire edge carries
/// the first owner's plain-cell history (freelist, witness cell) into the
/// second owner's clock — so the second owner's lock-free entries carry
/// real happens-before edges and run silent.
#[test]
fn stream_handoff_between_threads_is_clean() {
    let fabric =
        FabricConfig { interconnect: Interconnect::Ib, nodes: 1, procs_per_node: 1, max_contexts_per_node: 16 };
    let mut spec = ClusterSpec::new(fabric, MpiConfig::optimized(4), 2);
    spec.time_limit = Some(10_000_000);
    spec.service_threads = false;
    let stash: Arc<Mutex<Option<Comm>>> = Arc::new(Mutex::new(None));
    let handoff = Arc::new(SimAtomicU64::new(0));
    let (stash2, handoff2) = (stash.clone(), handoff.clone());
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let streamed =
                proc.comm_dup_with_info(&world, &Info::new().with("vcmpi_stream", "local"));
            let lane = proc.stream_bind(&streamed); // prefill: plain-cell writes
            assert!(proc.stream_lane_owned(lane));
            proc.stream_unbind(&streamed); // drain + locked transition (release)
            *stash2.lock().unwrap() = Some(streamed);
            handoff2.store(1);
            sim::advance(1_000);
        } else {
            loop {
                if handoff.load() != 0 {
                    break;
                }
                sim::advance(50);
                sim::yield_now();
            }
            let streamed = stash.lock().unwrap().clone().unwrap();
            let lane = proc.stream_bind(&streamed); // locked transition (acquire)
            assert!(proc.stream_lane_owned(lane));
            proc.comm_free(streamed); // teardown unbinds for us
            assert!(!proc.stream_lane_owned(lane));
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "sanctioned stream handoff must run silent");
}

/// Seeded violation (e), the satellite-1 fix: `SimAtomicU64::store` is a
/// *release*, not a fence. A racing thread that merely stores to the same
/// atomic must NOT inherit the first thread's plain-write history (the old
/// fence semantics laundered exactly this app-level race), so its
/// subsequent plain read of the cell is a data race and must be reported.
#[test]
fn seeded_atomic_store_store_does_not_launder_a_race() {
    let cell = Arc::new(SimCell::new(0u64));
    let flag = Arc::new(SimAtomicU64::new(0));
    let mut s = Sim::new(CostModel::default());
    let (wc, wf) = (cell.clone(), flag.clone());
    s.spawn_setup("publisher", move || {
        *wc.get() = 1;
        wf.store(1); // release: joins the flag's clock, acquires nothing back
        sim::advance(10);
        sim::yield_now();
    });
    s.spawn_setup("store-racer", move || {
        sim::advance(500); // stay strictly behind the publisher
        flag.store(2); // store-store: no acquire edge from the publisher
        let _ = *cell.get(); // publisher's plain write is NOT in our clock
    });
    expect_simsan(s.run(), "data race");
}

/// Positive control for satellite 1: the sanctioned message-passing shape
/// — plain write, `store` (release), spin `load` (acquire), plain read —
/// carries the write's epoch through the atomic and runs silent.
#[test]
fn atomic_release_acquire_publication_is_clean() {
    let cell = Arc::new(SimCell::new(0u64));
    let flag = Arc::new(SimAtomicU64::new(0));
    let mut s = Sim::new(CostModel::default());
    let (wc, wf) = (cell.clone(), flag.clone());
    s.spawn_setup("publisher", move || {
        *wc.get() = 7;
        wf.store(1); // release carries the write epoch
        sim::advance(10);
        sim::yield_now();
    });
    s.spawn_setup("consumer", move || {
        loop {
            if flag.load() != 0 {
                break; // acquire joined the publisher's clock
            }
            sim::advance(25);
            sim::yield_now();
        }
        assert_eq!(*cell.get(), 7);
    });
    let r = s.run();
    assert_eq!(r.outcome, SimOutcome::Completed, "release/acquire publication must run silent");
}
