//! SimSan seeded-violation tests: each test plants one deliberate
//! discipline violation in an otherwise tiny simulated program and asserts
//! that the sanitizer reports it deterministically (as
//! `SimOutcome::Panicked("SimSan: ...")`), plus positive controls showing
//! the sanctioned patterns run silent. Only meaningful with the checker
//! compiled in, hence the file-level feature gate (the default build has
//! it; release benches run `--no-default-features`).
#![cfg(feature = "simsan")]

use std::sync::Arc;

use vcmpi::mpi::instrument::{HostMutex, LockClass};
use vcmpi::platform::{Backend, PMutex};
use vcmpi::sim::{self, CostModel, Sim, SimCell, SimMutex, SimOutcome};

fn expect_simsan(r: vcmpi::sim::SimReport, needle: &str) {
    match r.outcome {
        SimOutcome::Panicked(ref m) if m.contains("SimSan") && m.contains(needle) => {}
        ref other => panic!("expected a SimSan report containing {needle:?}, got {other:?}"),
    }
}

/// Seeded violation (a): acquiring `cs.global` (rank 10) while holding
/// `vci.state` (rank 30) inverts the declared hierarchy — the mirror image
/// of the sanctioned Global -> Vci nesting — and must be reported at the
/// acquisition attempt, before anything can park.
#[test]
fn seeded_lock_order_inversion_is_detected() {
    let outer = PMutex::new(Backend::Sim, ());
    let inner = PMutex::new(Backend::Sim, ());
    let mut s = Sim::new(CostModel::default());
    s.spawn_setup("inverted", move || {
        let _vci = outer.lock_class(LockClass::Vci);
        let _global = inner.lock_class(LockClass::Global); // rank 10 under rank 30
        unreachable!("SimSan must reject the inverted acquisition");
    });
    expect_simsan(s.run(), "lock-order violation");
}

/// Seeded violation (b): a host `std::sync` mutex held across a scheduler
/// interaction. The DES runs one OS thread at a time, so a baton handoff
/// with a host lock held can deadlock the *host* process — SimSan reports
/// it at the interaction point instead.
#[test]
fn seeded_host_lock_across_park_is_detected() {
    let table = HostMutex::new(0u64);
    let mut s = Sim::new(CostModel::default());
    s.spawn_setup("holder", move || {
        let _g = table.lock(LockClass::HostComms);
        sim::yield_now(); // interaction with the host lock still held
        unreachable!("SimSan must reject the yield under a host lock");
    });
    expect_simsan(s.run(), "host lock");
}

/// Seeded violation (c): two simulated threads touch a plain `SimCell`
/// with no simulated sync edge between them. Baton order makes the access
/// memory-safe but not meaningful — the modeled program has a data race,
/// and the second access must be reported against the first thread's
/// last-writer epoch.
#[test]
fn seeded_plain_cell_race_is_detected() {
    let cell = Arc::new(SimCell::new(0u64));
    let mut s = Sim::new(CostModel::default());
    let w = cell.clone();
    s.spawn_setup("writer", move || {
        *w.get() = 1;
        sim::advance(10);
        sim::yield_now();
    });
    s.spawn_setup("racer", move || {
        sim::advance(5);
        sim::yield_now();
        let _ = *cell.get(); // no happens-before edge from the writer
    });
    expect_simsan(s.run(), "data race");
}

/// Positive control: the same cross-thread cell traffic, ordered through a
/// `SimMutex` (release -> acquire vector-clock edge), runs silent — SimSan
/// flags missing edges, not cross-thread sharing itself.
#[test]
fn mutex_ordered_cell_traffic_is_clean() {
    let cell = Arc::new(SimCell::new(0u64));
    let gate = Arc::new(SimMutex::new(()));
    let mut s = Sim::new(CostModel::default());
    let (w, wg) = (cell.clone(), gate.clone());
    s.spawn_setup("writer", move || {
        let g = wg.lock();
        *w.get() = 7;
        drop(g); // release edge carries the write epoch
        sim::advance(10);
        sim::yield_now();
    });
    s.spawn_setup("reader", move || {
        sim::advance(25); // stay behind the writer until it releases
        let g = gate.lock(); // acquire edge joins the writer's clock
        assert_eq!(*cell.get(), 7);
        drop(g);
    });
    let r = s.run();
    assert_eq!(r.outcome, SimOutcome::Completed, "sanctioned pattern must run silent");
}

/// Positive + negative control for the `multi` class: the stop-the-world
/// all-shard sweep (ascending ordinals) is the sanctioned pattern; the
/// descending sweep is a latent ABBA deadlock and must be rejected.
#[test]
fn shard_ordinal_sweeps_check_direction() {
    let ascending = {
        let a = PMutex::new(Backend::Sim, ());
        let b = PMutex::new(Backend::Sim, ());
        let mut s = Sim::new(CostModel::default());
        s.spawn_setup("sweep", move || {
            let _s0 = a.lock_ordinal(LockClass::Shard, 0);
            let _s1 = b.lock_ordinal(LockClass::Shard, 1);
        });
        s.run()
    };
    assert_eq!(ascending.outcome, SimOutcome::Completed, "ascending sweep is sanctioned");

    let descending = {
        let a = PMutex::new(Backend::Sim, ());
        let b = PMutex::new(Backend::Sim, ());
        let mut s = Sim::new(CostModel::default());
        s.spawn_setup("sweep", move || {
            let _s1 = a.lock_ordinal(LockClass::Shard, 1);
            let _s0 = b.lock_ordinal(LockClass::Shard, 0);
            unreachable!("SimSan must reject the descending sweep");
        });
        s.run()
    };
    expect_simsan(descending, "lock-order violation");
}
